"""Pytest rootdir hook: put the repo root on sys.path.

The suite imports sibling top-level packages (``from benchmarks import
throughput`` in tests/test_benchmarks.py). ``python -m pytest`` gets this
for free (cwd goes on sys.path); the ``pytest`` console script does not —
without this file collection fails before a single test runs.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
