"""Substrate tests: data pipeline determinism/resharding, optimizer,
gradient compression, checkpoint 2-phase commit + elastic restore, control
plane services, trainer integration (train -> crash -> restore -> resume)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import registry
from repro.data.pipeline import DataConfig, Prefetcher, ShardLease, SyntheticLM
from repro.optim import adamw, compression
from repro.optim.adamw import AdamWConfig
from repro.runtime.controlplane import ControlPlane
from repro.runtime.trainer import Trainer, TrainerConfig


# ------------------------------------------------------------------- data


def test_data_deterministic_and_reshardable():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=7)
    whole = SyntheticLM(cfg, shard_id=0, n_shards=1).batch_at(5)
    halves = [SyntheticLM(cfg, shard_id=i, n_shards=2).batch_at(5) for i in range(2)]
    rejoined = np.concatenate([h["tokens"] for h in halves], axis=0)
    np.testing.assert_array_equal(whole["tokens"], rejoined)
    # Same (step, shard) always yields identical data.
    again = SyntheticLM(cfg, shard_id=0, n_shards=1).batch_at(5)
    np.testing.assert_array_equal(whole["labels"], again["labels"])


def test_data_prefetcher_order():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2)
    direct = [SyntheticLM(cfg).batch_at(i)["tokens"] for i in range(4)]
    pre = Prefetcher(SyntheticLM(cfg), depth=2)
    for i in range(4):
        np.testing.assert_array_equal(next(pre)["tokens"], direct[i])


def test_shard_lease_rebalance_minimal_moves():
    lease = ShardLease.balanced(["h0", "h1", "h2"], 6)
    new = lease.rebalance(["h0", "h2"])  # h1 died
    assert set(new.owners.values()) <= {"h0", "h2"}
    moved = sum(1 for s in lease.owners if lease.owners[s] != new.owners[s])
    assert moved == 2  # only h1's shards moved


# ------------------------------------------------------------------ optim


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0,
                      clip_norm=10.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init(cfg, params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = adamw.update(cfg, grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adamw_clips_global_norm():
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0, total_steps=10)
    params = {"w": jnp.zeros(4)}
    state = adamw.init(cfg, params)
    g = {"w": jnp.full((4,), 100.0)}
    p1, _ = adamw.update(cfg, g, state, params)
    g2 = {"w": jnp.full((4,), 1e6)}
    p2, _ = adamw.update(cfg, g2, state, params)
    # After clipping, wildly different magnitudes give the same step.
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]), rtol=1e-5)


def test_compression_error_feedback_unbiased():
    tree = {"a": jnp.asarray(np.random.RandomState(0).randn(64) * 0.1, jnp.float32)}
    res = compression.init_residual(tree)
    acc_q = jnp.zeros(64)
    acc_t = jnp.zeros(64)
    for i in range(50):
        g = {"a": tree["a"] * (1 + 0.01 * i)}
        q, s, res = compression.quantize(g, res)
        acc_q = acc_q + compression.dequantize(q, s)["a"]
        acc_t = acc_t + g["a"]
    # Error feedback keeps the ACCUMULATED signal nearly exact.
    np.testing.assert_allclose(np.asarray(acc_q), np.asarray(acc_t), atol=2e-3)


# -------------------------------------------------------------- checkpoint


def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    tree = {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones(3)}
    for step in (1, 2, 3):
        mgr.save(step, {"state": jax.tree_util.tree_map(lambda x: x * step, tree)},
                 async_=False)
    assert mgr.committed_steps() == [2, 3]  # GC kept last 2
    step, out = mgr.restore({"state": tree})
    assert step == 3
    np.testing.assert_allclose(np.asarray(out["state"]["w"]), np.asarray(tree["w"]) * 3)


def test_checkpoint_uncommitted_invisible(tmp_path):
    """If the consensus commit fails, the checkpoint must not exist."""
    mgr = CheckpointManager(str(tmp_path), commit_fn=lambda rec: False)
    mgr.save(5, {"state": {"w": jnp.ones(2)}}, async_=False)
    assert mgr.latest_step() is None
    with pytest.raises(FileNotFoundError):
        mgr.restore({"state": {"w": jnp.ones(2)}})


def test_checkpoint_commit_through_fastraft(tmp_path):
    cp = ControlPlane(n_nodes=3, seed=42)
    mgr = CheckpointManager(str(tmp_path), commit_fn=cp.checkpoint_commit_fn())
    mgr.save(7, {"state": {"w": jnp.ones(2)}}, async_=False)
    assert mgr.latest_step() == 7
    assert any(c.startswith("ckpt:7:") for c in cp.applied)


# ------------------------------------------------------------ controlplane


def test_controlplane_leases_and_stragglers():
    cp = ControlPlane(n_nodes=3, seed=1)
    lease = cp.assign_leases(["h0", "h1"], n_shards=4)
    assert lease.shards_of("h0") == [0, 2]
    lease2 = cp.rebalance_leases(["h1"])
    assert set(lease2.owners.values()) == {"h1"}
    for _ in range(3):
        cp.report_straggler("h9", step=1)
    assert "h9" in cp.excluded
    # All records traveled the fast track (proposed via a non-leader).
    assert cp.metrics().counters.get("fast_proposals", 0) >= 3


# ---------------------------------------------------------------- trainer


@pytest.mark.slow  # end-to-end Trainer: multi-step XLA compile + train
def test_trainer_loss_decreases():
    cfg = TrainerConfig(
        arch=registry.get("qwen3-1.7b", reduced=True),
        steps=8, global_batch=4, seq_len=32,
        opt=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=8),
    )
    logs = Trainer(cfg).train()
    assert logs[-1]["loss"] < logs[0]["loss"]
    assert all(l["committed"] == 1.0 for l in logs)


@pytest.mark.slow  # end-to-end Trainer: multi-step XLA compile + train
def test_trainer_checkpoint_restart_resumes(tmp_path):
    """Train 6 steps w/ ckpt@3, 'crash', build a NEW trainer, resume: the
    resumed run must land on the same final step count and a consistent
    loss trajectory (deterministic data by step index)."""
    common = dict(
        arch=registry.get("qwen3-1.7b", reduced=True),
        global_batch=4, seq_len=32,
        opt=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=6),
        ckpt_dir=str(tmp_path), ckpt_every=3,
    )
    full = Trainer(TrainerConfig(steps=6, **common)).train()

    # Fresh directory: crash after 3 steps (simulated by steps=3).
    crash_dir = str(tmp_path / "crashy")
    common["ckpt_dir"] = crash_dir
    Trainer(TrainerConfig(steps=3, **common)).train()
    resumed_trainer = Trainer(TrainerConfig(steps=6, **common))
    resumed = resumed_trainer.train()
    assert resumed[0]["data_step"] == 3  # resumed from the committed step
    np.testing.assert_allclose(resumed[-1]["loss"], full[-1]["loss"], rtol=1e-4)


@pytest.mark.slow  # end-to-end Trainer: multi-step XLA compile + train
def test_trainer_consensus_checkpoint_integration(tmp_path):
    cp = ControlPlane(n_nodes=3, seed=9)
    cfg = TrainerConfig(
        arch=registry.get("granite-moe-1b-a400m", reduced=True),
        steps=4, global_batch=4, seq_len=16,
        opt=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=4),
        ckpt_dir=str(tmp_path), ckpt_every=2,
    )
    logs = Trainer(cfg, control=cp).train()
    assert len(logs) == 4
    assert any(c.startswith("ckpt:") for c in cp.applied)
    assert any(c.startswith("lease:") for c in cp.applied)


@pytest.mark.slow  # end-to-end Trainer: multi-step XLA compile + train
def test_trainer_classic_track_also_works():
    cfg = TrainerConfig(
        arch=registry.get("qwen3-1.7b", reduced=True),
        steps=3, global_batch=4, seq_len=16, track="classic",
        opt=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=3),
    )
    logs = Trainer(cfg).train()
    assert all(l["committed"] == 1.0 for l in logs)
