"""Named regression traces minted by the protocol fuzzer.

Each JSON file under ``tests/regressions/`` is a shrunk, fully-resolved
fault schedule (see ``src/repro/core/fuzzer.py`` for the trace format)
that either reproduced a real pre-hardening failure or pins a hardened
behavior we never want to regress:

* ``rejoining_removed_node_storm`` — a node partitioned away before its
  removal commits rejoins believing it is a voter. Pre-hardening its
  campaigning inflated terms cluster-wide (observed: term 84); PreVote +
  the out-of-config vote refusal keep every term at 1 and the leader
  unchanged.
* ``partitioned_leader_stale_lease`` — a leader isolated from its quorum
  must CheckQuorum-step-down within one election timeout instead of
  serving from a stale bubble; the read-freshness oracle guards the lease
  path throughout.
* ``election_storm_flapping_partition`` — four partition/heal flaps of one
  follower. Pre-hardening: 5 leaderships, terms to 25. With PreVote: one
  leadership, term 1.
* ``corrupt_snapshot_chunks`` — a bit-flipping adversary on a chunked
  snapshot transfer; every flip must be CRC-detected (treated as loss) and
  the install must still complete through retransmission.
* ``fifo_relay_flush_before_leader`` — minted by the fuzzer (shrunk from
  seed 8): client batches queued before any leader exists were flushed as
  per-entry relay RPCs that raced through link jitter, breaking
  single-batch FIFO (observed commit order [4, 3, 1, 2]); the flush now
  rides one relay RPC.
* ``restore_lost_acked_log`` — minted by the read-enabled fuzzer (shrunk
  from seed 7): ``restart_from_store`` restores hard state + snapshot but
  NOT the log, so a node that had acked entries into a commit quorum came
  back empty-logged and elected a candidate missing them (observed: a
  term-barrier noop overwriting committed index 4). The persisted
  acked-log floor now makes the restored node refuse such vote grants.
* ``thin_link_delta_catchup`` — two lag/catch-up cycles on a
  serialization-limited 60 B/ms link with the wire-efficiency knobs on
  (``delta_snapshots`` + ``ack_piggyback``): the first catch-up is a full
  snapshot stream, the second MUST negotiate a delta against the base the
  follower advertised after installing the first (counters pin
  ``delta_snapshots_sent/installed >= 1`` with ZERO fallbacks), while
  folded acks and suppressed heartbeats stay observable — the
  bandwidth-frugal stack end-to-end under the link model it exists for.
* ``coalesced_read_dead_lease`` — a coalesced leader read admitted after
  the leader's lease died behind a partition (CheckQuorum off, a rival
  quorum having already committed a newer value) must fall back to a
  ReadIndexProbe at window close — never serve the stale local state —
  and completes with the rival's value only after the heal.

Promoting a new fuzzer find is one step: copy the shrunk trace the CI
artifact (or ``python -m repro.core.fuzzer``) produced into this directory.
"""
import glob
import os

import pytest

from repro.core.fuzzer import replay_trace_file

TRACE_DIR = os.path.join(os.path.dirname(__file__), "regressions")
TRACES = sorted(glob.glob(os.path.join(TRACE_DIR, "*.json")))


def test_regression_corpus_present():
    assert len(TRACES) >= 4, "regression corpus went missing"


@pytest.mark.parametrize(
    "path", TRACES, ids=[os.path.splitext(os.path.basename(p))[0] for p in TRACES]
)
def test_regression_trace(path):
    report = replay_trace_file(path)
    assert report.ok, report.error
