"""Linearizable read path: ReadIndex quorum reads + leader leases.

Covers the tentpole's contract end to end:

- basic ReadIndex semantics (leader + follower-forwarded reads, no log
  growth, queries never mutate or dedup-record);
- zero-round lease reads (no probe traffic under a fresh lease);
- the fresh-leader read barrier (lazy __noop__ commit before serving);
- staleness under partition / leader change (a deposed leader must not
  serve reads it can no longer prove fresh; origins fail over);
- fast-track visibility (a fast-committed write acked before a read was
  issued is always visible to that read);
- lease safety under skewed + drifting clocks (chaos, zero stale reads —
  validated by the read oracle in tests/commit_history.py);
- pipelined chunked snapshot transfer under loss and blackout;
- hierarchy: pod-local reads complete without any global-tier commits.
"""
from __future__ import annotations

import random

import pytest

from repro.core.raft import RaftConfig
from repro.core.sim import Cluster, MembershipError
from repro.core.statemachine import KVMachine
from repro.core.hierarchy import HierarchicalCluster

from commit_history import (
    check_commit_history,
    check_kv_consistency,
    check_read_oracle,
    committed_acks,
)


def kv_factory(nid):
    return KVMachine()


def _mk(n=5, protocol="fastraft", seed=1, lease=False, **kw):
    cfg = kw.pop("config", None) or RaftConfig(
        lease_duration_ms=800.0 if lease else 0.0,
        clock_skew_ms=10.0 if lease else 0.0,
    )
    c = Cluster(n=n, protocol=protocol, seed=seed, config=cfg,
                state_machine_factory=kv_factory, **kw)
    assert c.run_until_leader(60_000) is not None
    c.run(500)
    return c


# --------------------------------------------------------------- ReadIndex


def test_readindex_basic_leader_and_follower():
    c = _mk(seed=3)
    lead = c.leader()
    writes = []
    eid = c.submit("SET a alpha", via=lead)
    writes.append((eid, "SET a alpha"))
    assert c.run_until_committed([eid])
    log_len_before = c.nodes[lead].last_log_index()

    r1 = c.read("GET a", via=lead)
    follower = [n for n in c.nodes if n != lead][0]
    r2 = c.read("GET a", via=follower)
    assert c.run_until_reads([r1, r2], 10_000)
    assert c.reads[r1]["value"] == "alpha"
    assert c.reads[r2]["value"] == "alpha"
    # Reads never ride the log.
    assert c.nodes[lead].last_log_index() == log_len_before
    assert c.metrics.counters.get("readindex_reads", 0) == 2
    assert check_read_oracle(c, writes) == 2


def test_reads_do_not_mutate_or_dedup_record():
    c = _mk(seed=4)
    lead = c.leader()
    eid = c.submit("SET k v1", via=lead)
    assert c.run_until_committed([eid])
    node = c.nodes[lead]
    snap_before = node.state_machine.snapshot()
    rids = [c.read("GET k", via=lead) for _ in range(3)]
    assert c.run_until_reads(rids, 10_000)
    # Same value, no state change, no dedup entries for read ids.
    assert all(c.reads[r]["value"] == "v1" for r in rids)
    assert node.state_machine.snapshot() == snap_before
    for r in rids:
        assert not node.has_applied(r), "read id leaked into the dedup table"
    # A GET through query() must not bump versions (unlike CAS/SET).
    assert node.state_machine.version("k") == 1


def test_read_before_any_leader_or_write():
    """A read submitted into a leaderless cluster waits, then the fresh
    leader commits its __noop__ barrier and serves (value: key absent)."""
    cfg = RaftConfig()
    c = Cluster(n=3, protocol="fastraft", seed=9, config=cfg,
                state_machine_factory=kv_factory)
    rid = c.read("GET nothing", via="n0")  # no leader exists yet
    assert c.run_until_reads([rid], 20_000), c.reads[rid]
    assert c.reads[rid]["ok"] and c.reads[rid]["value"] is None
    assert c.metrics.counters.get("read_barrier_noops", 0) >= 1
    # The barrier no-op rode the log exactly once per elected term.
    assert c.metrics.counters["read_barrier_noops"] <= len(
        c.metrics.leaders
    ), c.metrics.counters


def test_read_retries_under_loss():
    c = _mk(seed=6, loss=0.15, jitter=2.0)
    lead = c.leader()
    writes = []
    eid = c.submit("SET x lossy", via=lead)
    writes.append((eid, "SET x lossy"))
    assert c.run_until_committed([eid], 60_000)
    follower = [n for n in c.nodes if n != lead][0]
    rids = [c.read("GET x", via=follower) for _ in range(8)]
    assert c.run_until_reads(rids, 60_000)
    assert all(c.reads[r]["value"] == "lossy" for r in rids)
    check_read_oracle(c, writes)


# ------------------------------------------------------------------ leases


def test_lease_reads_zero_rounds():
    c = _mk(seed=5, lease=True)
    lead = c.leader()
    eid = c.submit("SET b beta", via=lead)
    assert c.run_until_committed([eid])
    c.run(300)  # heartbeat quorum establishes the lease
    probes_before = c.metrics.counters.get("read_probes", 0)
    t0 = c.sim.now
    rids = [c.read("GET b", via=lead) for _ in range(5)]
    assert c.run_until_reads(rids, 5_000)
    assert all(c.reads[r]["value"] == "beta" for r in rids)
    # Zero message rounds: served instantly, no probe traffic.
    assert all(c.reads[r]["completed_at"] == t0 for r in rids)
    assert c.metrics.counters.get("read_probes", 0) == probes_before
    assert c.metrics.counters.get("lease_reads", 0) >= 5


def test_lease_expires_without_quorum():
    """A leader cut off from its quorum stops serving lease reads once the
    lease runs out instead of serving unprovably-fresh state."""
    c = _mk(seed=8, lease=True)
    lead = c.leader()
    eid = c.submit("SET c gamma", via=lead)
    assert c.run_until_committed([eid])
    c.run(300)
    minority = [lead, [n for n in c.nodes if n != lead][0]]
    majority = [n for n in c.nodes if n not in minority]
    c.partition(minority, majority)
    # Let the lease (capped at election_timeout_min=150ms) expire.
    c.run(400)
    rid = c.read("GET c", via=lead)
    c.run(1_500)
    assert c.reads[rid]["completed_at"] is None, (
        "partitioned ex-leader served a read without quorum or lease"
    )
    c.heal()
    assert c.run_until_reads([rid], 30_000)
    assert c.reads[rid]["value"] == "gamma"


# ------------------------------------------- partitions and leader changes


def test_reads_fail_over_to_new_leader():
    c = _mk(seed=11)
    lead = c.leader()
    writes = []
    e1 = c.submit("SET k before", via=lead)
    writes.append((e1, "SET k before"))
    assert c.run_until_committed([e1])
    # Cut the leader (with one follower) away from the majority.
    minority = [lead, [n for n in c.nodes if n != lead][0]]
    majority = [n for n in c.nodes if n not in minority]
    c.partition(minority, majority)
    rid = c.read("GET k", via=lead)  # pends: no quorum reachable
    c.run(2_000)
    assert c.reads[rid]["completed_at"] is None
    new_lead = c.leader()
    assert new_lead in majority
    e2 = c.submit("SET k after", via=new_lead)
    writes.append((e2, "SET k after"))
    assert c.run_until_committed([e2], 30_000)
    c.heal()
    assert c.run_until_reads([rid], 30_000)
    # Served after the old leader stepped down — by the new leader, whose
    # state includes the newer write. Both freshness and validity hold.
    assert c.reads[rid]["value"] == "after"
    check_read_oracle(c, writes)
    check_commit_history(c, committed_acks(c, [e1, e2]))


def test_fast_track_commits_visible_to_immediate_reads():
    """Fast-track visibility rule: the instant a fast-committed write is
    acked, a lease read at the leader must observe it (zero-round reads are
    the strictest case — no probe round to hide latency in)."""
    c = _mk(seed=13, lease=True)
    c.run(300)
    writes = []
    for i in range(10):
        lead = c.leader()
        follower = [n for n in c.nodes if n != lead][0]
        cmd = f"SET hot v{i}"
        eid = c.submit(cmd, via=follower)  # non-leader proposer: fast track
        writes.append((eid, cmd))
        assert c.run_until_committed([eid], 30_000)
        rid = c.read("GET hot", via=lead)
        assert c.run_until_reads([rid], 30_000)
        assert c.reads[rid]["value"] == f"v{i}", (
            f"read after ack of v{i} returned {c.reads[rid]['value']!r}"
        )
    assert c.metrics.counters.get("fast_commits", 0) > 0
    check_read_oracle(c, writes)


# ------------------------------------------------------ clock-skew + chaos


def test_read_oracle_chaos_skewed_clocks_and_churn():
    """Lease mode with skewed, drifting clocks, loss, crashes and
    partitions: every completed read must pass the linearizability oracle
    (zero stale reads), and the write history must stay consistent."""
    rng = random.Random(1234)
    cfg = RaftConfig(lease_duration_ms=500.0, clock_skew_ms=15.0)
    c = Cluster(n=5, protocol="fastraft", seed=21, loss=0.05, jitter=2.0,
                config=cfg, state_machine_factory=kv_factory,
                clock_skew_ms=40.0, clock_drift=0.02)
    assert c.run_until_leader(60_000) is not None
    c.run(500)
    writes, rids, crashed = [], [], []
    wi = 0
    for phase in range(8):
        alive = [n for n, node in c.nodes.items() if node.alive]
        for _ in range(4):
            via = rng.choice(alive)
            cmd = f"SET key{rng.randrange(5)} v{wi}"
            wi += 1
            eid = c.submit(cmd, via=via)
            writes.append((eid, cmd))
        c.run(rng.uniform(100, 400))
        alive = [n for n, node in c.nodes.items() if node.alive]
        for _ in range(4):
            rids.append(c.read(f"GET key{rng.randrange(5)}", via=rng.choice(alive)))
        c.run(rng.uniform(100, 400))
        kind = phase % 4
        if kind == 0:
            lead = c.leader()
            if lead is not None:
                c.crash(lead)
                crashed.append(lead)
        elif kind == 1 and crashed:
            c.restart(crashed.pop())
        elif kind == 2:
            nodes = list(c.nodes)
            rng.shuffle(nodes)
            c.partition(nodes[:2], nodes[2:])
            c.run(rng.uniform(200, 600))
            c.heal()
        # kind == 3: quiet phase
    c.heal()
    for n in crashed:
        c.restart(n)
    c.run(8_000)  # settle: retries drain, stragglers commit
    completed = [r for r in rids if c.reads[r]["completed_at"] is not None]
    assert len(completed) >= len(rids) // 2, (
        f"only {len(completed)}/{len(rids)} reads completed"
    )
    n_checked = check_read_oracle(c, writes)
    assert n_checked == len(completed)
    check_commit_history(c, committed_acks(c, [e for e, _ in writes]))
    check_kv_consistency(c)


# ----------------------------------------------- pipelined chunk transfer


@pytest.mark.parametrize("window", [1, 4])
def test_pipelined_chunk_transfer_loss_and_blackout(window):
    """Windowed chunk streaming under per-packet loss, including a mid-
    transfer blackout (crash + restart rewinds the follower cursor): the
    replacement converges to identical state either way."""
    cfg = RaftConfig(snapshot_chunk_bytes=600, snapshot_chunk_window=window,
                     max_batch_entries=8)
    c = Cluster(n=3, protocol="raft", seed=17, loss=0.25, base_latency=5.0,
                jitter=1.0, bytes_per_ms=1500.0, mtu_bytes=700.0, config=cfg,
                state_machine_factory=kv_factory)
    assert c.run_until_leader(60_000) is not None
    c.run(1000)
    lead = c.leader()
    victim = [n for n in c.nodes if n != lead][0]
    c.partition([victim], [n for n in c.nodes if n != victim])
    c.crash(victim)
    eids = [c.submit(f"SET key{i % 7} {'x' * 60}-{i}", via=lead)
            for i in range(48)]
    assert c.run_until_committed(eids, 600_000)

    def settled():
        return all(
            (not n.alive) or n.last_applied >= 48 for n in c.nodes.values()
        )

    c.sim.run_until(c.sim.now + 120_000, stop=settled)
    assert settled()
    for node in c.nodes.values():
        if node.alive:
            node.compact()
    c.heal()
    c.restart(victim)
    c.run(150)   # transfer starts...
    c.crash(victim)   # ...blackout mid-stream
    c.run(300)
    c.restart(victim)  # cursor legitimately rewinds; stream resumes

    def caught_up():
        return c.nodes[victim].commit_index >= 48

    c.sim.run_until(c.sim.now + 300_000, stop=caught_up)
    assert caught_up(), "victim never caught up through windowed transfer"
    check_kv_consistency(c)
    if window > 1:
        assert c.metrics.counters.get("snapshot_chunks_sent", 0) > 0


def test_pipelined_faster_than_serial_at_zero_loss():
    """The ROADMAP gap this closes: a serial stream pays one RTT per chunk
    even on a clean link; a window amortizes it."""
    def catch_up_time(window):
        cfg = RaftConfig(snapshot_chunk_bytes=1200, snapshot_chunk_window=window,
                         max_batch_entries=8)
        c = Cluster(n=3, protocol="raft", seed=5, loss=0.0, base_latency=5.0,
                    jitter=1.0, bytes_per_ms=1500.0, mtu_bytes=1400.0,
                    config=cfg)
        assert c.run_until_leader(60_000) is not None
        c.run(1000)
        lead = c.leader()
        victim = [n for n in c.nodes if n != lead][0]
        c.partition([victim], [n for n in c.nodes if n != victim])
        c.crash(victim)
        eids = [c.submit("v" * 200 + f"-{i}", via=lead) for i in range(80)]
        assert c.run_until_committed(eids, 600_000)
        for node in c.nodes.values():
            if node.alive:
                node.compact()
        t0 = c.sim.now
        c.heal()
        c.restart(victim)

        def caught_up():
            return c.nodes[victim].commit_index >= 80

        c.sim.run_until(c.sim.now + 300_000, stop=caught_up)
        assert caught_up()
        return c.sim.now - t0

    serial = catch_up_time(1)
    pipelined = catch_up_time(8)
    assert pipelined < serial, (serial, pipelined)


# ------------------------------------------- replica (watermark) reads


def test_replica_read_serves_locally_zero_leader_rounds():
    """A follower serves a linearizable replica read from the published
    watermark: correct value, no forward to the leader, no probe round."""
    c = _mk(seed=23)
    lead = c.leader()
    writes = []
    eid = c.submit("SET r rho", via=lead)
    writes.append((eid, "SET r rho"))
    assert c.run_until_committed([eid])
    c.run(200)  # a post-commit round certifies + publishes the watermark
    probes = c.metrics.counters.get("read_probes", 0)
    forwards = c.metrics.counters.get("read_forwards", 0)
    followers = [n for n in c.nodes if n != lead][:2]
    rids = [c.read("GET r", via=f, mode="replica") for f in followers]
    assert c.run_until_reads(rids, 10_000)
    for r in rids:
        assert c.reads[r]["value"] == "rho"
        assert c.reads[r]["wm_index"] is not None
    # Zero leader involvement: no probes, no forwards beyond the baseline.
    assert c.metrics.counters.get("read_probes", 0) == probes
    assert c.metrics.counters.get("read_forwards", 0) == forwards
    assert c.metrics.counters.get("replica_reads_served", 0) >= 2
    assert check_read_oracle(c, writes) == 2


def test_replica_read_via_learner():
    """A learner (non-voting, full replication) is first-class replica-read
    capacity — exactly the scale-out story."""
    c = _mk(seed=24)
    c.add_learner("l0")
    assert c.run_until_membership()
    lead = c.leader()
    writes = []
    eid = c.submit("SET lk learned", via=lead)
    writes.append((eid, "SET lk learned"))
    assert c.run_until_committed([eid])
    rid = c.read("GET lk", via="l0", mode="replica")
    assert c.run_until_reads([rid], 15_000)
    assert c.reads[rid]["value"] == "learned"
    assert c.nodes["l0"].cluster_config.is_learner("l0")
    check_read_oracle(c, writes)


def test_replica_read_partitioned_replica_blocks_until_heal():
    """A partitioned follower holds no fresh-enough watermark, so a
    linearizable replica read pends rather than serving stale state; on
    heal it serves the write that committed DURING the partition.

    pre_vote keeps the rejoining victim from deposing the healthy leader
    (an idle-cluster leader change would otherwise leave no certified
    watermark until the next write — that edge has its own test)."""
    c = _mk(seed=25, config=RaftConfig(pre_vote=True))
    lead = c.leader()
    writes = []
    e1 = c.submit("SET p v1", via=lead)
    writes.append((e1, "SET p v1"))
    assert c.run_until_committed([e1])
    c.run(200)
    victim = [n for n in c.nodes if n != lead][0]
    c.partition([victim], [n for n in c.nodes if n != victim])
    c.run(100)
    e2 = c.submit("SET p v2", via=lead)
    writes.append((e2, "SET p v2"))
    assert c.run_until_committed([e2], 30_000)
    rid = c.read("GET p", via=victim, mode="replica")
    c.run(2_000)
    assert c.reads[rid]["completed_at"] is None, (
        "partitioned replica served a linearizable read on a stale watermark"
    )
    c.heal()
    assert c.run_until_reads([rid], 30_000)
    assert c.reads[rid]["value"] == "v2"
    check_read_oracle(c, writes)


def test_replica_read_across_snapshot_jump():
    """InstallSnapshot advances last_applied past individually-applied
    entries; the watermark target must be satisfied by the jump (a snapshot
    is a prefix of the committed log, so it can only help freshness)."""
    cfg = RaftConfig(snapshot_threshold=16)
    c = Cluster(n=3, protocol="fastraft", seed=26, config=cfg,
                state_machine_factory=kv_factory)
    assert c.run_until_leader(60_000) is not None
    c.run(500)
    lead = c.leader()
    victim = [n for n in c.nodes if n != lead][0]
    c.partition([victim], [n for n in c.nodes if n != victim])
    c.crash(victim)
    writes = []
    for i in range(40):
        cmd = f"SET s v{i}"
        writes.append((c.submit(cmd, via=lead), cmd))
    assert c.run_until_committed([e for e, _ in writes], 120_000)
    c.run(500)  # leader auto-compacts past the threshold
    c.heal()
    c.restart(victim)
    rid = c.read("GET s", via=victim, mode="replica")
    assert c.run_until_reads([rid], 60_000)
    assert c.reads[rid]["value"] == "v39"
    assert c.metrics.counters.get("snapshots_installed", 0) >= 1
    check_read_oracle(c, writes)


def test_replica_read_after_leader_change_idle_cluster():
    """Leader churn invalidates the watermark (the old leader may have
    certified under leadership it since lost). With election_noop the new
    leader's barrier commit re-certifies on an IDLE cluster — no write
    traffic needed for replica reads to resume."""
    cfg = RaftConfig(election_noop=True)
    c = Cluster(n=5, protocol="fastraft", seed=27, config=cfg,
                state_machine_factory=kv_factory)
    assert c.run_until_leader(60_000) is not None
    c.run(500)
    lead = c.leader()
    writes = []
    eid = c.submit("SET lc v1", via=lead)
    writes.append((eid, "SET lc v1"))
    assert c.run_until_committed([eid])
    c.run(200)
    c.crash(lead)
    new_lead = c.run_until_leader(60_000)
    assert new_lead is not None and new_lead != lead
    # No writes since the crash: only the election no-op re-certifies.
    replica = [n for n in c.nodes if n not in (lead, new_lead)][0]
    rid = c.read("GET lc", via=replica, mode="replica")
    assert c.run_until_reads([rid], 30_000)
    assert c.reads[rid]["value"] == "v1"
    check_read_oracle(c, writes)


def test_bounded_staleness_contract():
    """max_staleness_ms > 0: a partitioned replica may serve from an aged
    watermark WITHIN the bound (missing a newer write is allowed by the
    contract) but a linearizable read at the same replica must block.
    pre_vote: the rejoining victim must not depose the idle leader."""
    c = _mk(seed=28, config=RaftConfig(pre_vote=True))
    lead = c.leader()
    writes = []
    e1 = c.submit("SET bs old", via=lead)
    writes.append((e1, "SET bs old"))
    assert c.run_until_committed([e1])
    c.run(300)
    victim = [n for n in c.nodes if n != lead][0]
    c.partition([victim], [n for n in c.nodes if n != victim])
    c.run(100)
    e2 = c.submit("SET bs new", via=lead)
    writes.append((e2, "SET bs new"))
    assert c.run_until_committed([e2], 30_000)
    # Bounded-stale read: the pre-partition watermark is within 60s.
    r_stale = c.read("GET bs", via=victim, mode="replica",
                     max_staleness_ms=60_000.0)
    c.run(500)
    assert c.reads[r_stale]["completed_at"] is not None
    assert c.reads[r_stale]["value"] == "old"  # within contract
    assert c.metrics.counters.get("stale_reads_served", 0) >= 1
    # Linearizable read at the same partitioned replica: blocks.
    r_lin = c.read("GET bs", via=victim, mode="replica")
    c.run(1_500)
    assert c.reads[r_lin]["completed_at"] is None
    c.heal()
    assert c.run_until_reads([r_lin], 30_000)
    assert c.reads[r_lin]["value"] == "new"
    check_read_oracle(c, writes)


# --------------------------------- coalesce window x lease expiry (edges)


def test_coalesced_reads_never_served_under_dead_lease():
    """A leader whose lease dies while reads sit in the coalesce window
    must fall back to the probe round — which cannot confirm across the
    partition — so the reads complete only after failover, reflecting the
    write the NEW leader committed meanwhile."""
    cfg = RaftConfig(lease_duration_ms=800.0, clock_skew_ms=10.0,
                     read_coalesce_window=200.0)
    c = Cluster(n=5, protocol="fastraft", seed=29, config=cfg,
                state_machine_factory=kv_factory)
    assert c.run_until_leader(60_000) is not None
    c.run(500)
    lead = c.leader()
    writes = []
    e1 = c.submit("SET cw before", via=lead)
    writes.append((e1, "SET cw before"))
    assert c.run_until_committed([e1])
    minority = [lead, [n for n in c.nodes if n != lead][0]]
    c.partition(minority, [n for n in c.nodes if n not in minority])
    c.run(400)  # lease (capped at election_timeout_min) expires
    rid = c.read("GET cw", via=lead)
    c.run(1_000)
    assert c.reads[rid]["completed_at"] is None, (
        "coalesced read served under a dead lease"
    )
    new_lead = c.leader()
    assert new_lead not in minority
    e2 = c.submit("SET cw after", via=new_lead)
    writes.append((e2, "SET cw after"))
    assert c.run_until_committed([e2], 30_000)
    c.heal()
    assert c.run_until_reads([rid], 30_000)
    assert c.reads[rid]["value"] == "after"
    check_read_oracle(c, writes)


def test_coalesce_window_close_revalidates_live_lease():
    """The window-close fast path: a read admitted while a confirmation
    round was in flight (lease momentarily expired) is lease-served at
    window close — the round's ack revalidated the lease — with no extra
    probe. The lease check happens AT SERVE TIME, never at admission."""
    cfg = RaftConfig(heartbeat_interval=400.0, election_timeout_min=1200.0,
                     election_timeout_max=1600.0, lease_duration_ms=200.0,
                     read_coalesce_window=50.0)
    c = Cluster(n=3, protocol="fastraft", seed=30, config=cfg,
                base_latency=12.0, state_machine_factory=kv_factory)
    assert c.run_until_leader(60_000) is not None
    lead = c.leader()
    eid = c.submit("SET cv val", via=lead)
    assert c.run_until_committed([eid], 30_000)
    node = c.nodes[lead]
    # Catch the race: a heartbeat round in flight (acks pending), lease
    # currently dead. lease span (200ms) < heartbeat interval (400ms)
    # guarantees a dead zone before every round; base_latency (12ms one
    # way) keeps the round's acks in flight across tick boundaries. The
    # round must have been sent STRICTLY before the read arrives — a
    # same-instant round would confirm the read the ordinary ReadIndex
    # way and never exercise the window-close path.
    caught = None
    for _ in range(2_000):
        c.run(10)
        assert c.leader() == lead
        if (node._hb_round > node._quorum_round
                and node._round_sent.get(node._hb_round, (c.sim.now,))[0]
                < c.sim.now
                and not node._lease_valid(c.sim.now)
                and node._term_barrier_ok()):
            rid = c.read("GET cv", via=lead)
            if c.reads[rid]["completed_at"] is None and node._reads_pending:
                caught = rid
                break
    assert caught is not None, "never caught a round-in-flight dead lease"
    probes = c.metrics.counters.get("read_probes", 0)
    lease_reads = c.metrics.counters.get("lease_reads", 0)
    assert c.run_until_reads([caught], 5_000)
    assert c.reads[caught]["value"] == "val"
    # Served by the revalidated lease at window close: no probe round.
    assert c.metrics.counters.get("read_probes", 0) == probes
    assert c.metrics.counters.get("lease_reads", 0) == lease_reads + 1


# ------------------------------------------- read targeting (via= edges)


def test_read_via_removed_host_raises_membership_error():
    c = _mk(seed=32)
    lead = c.leader()
    gone = [n for n in c.nodes if n != lead][0]
    c.remove_node(gone, pop=True)
    assert c.run_until_membership()
    with pytest.raises(MembershipError):
        c.read("GET x", via=gone)
    with pytest.raises(MembershipError):
        c.read("GET x", via="never-existed")


def test_read_via_crashed_host_fails_fast():
    c = _mk(seed=33)
    lead = c.leader()
    down = [n for n in c.nodes if n != lead][0]
    c.crash(down)
    t0 = c.sim.now
    rid = c.read("GET x", via=down)
    rec = c.reads[rid]
    assert rec["ok"] is False
    assert rec["error"] == f"host down: {down}"
    assert rec["completed_at"] == t0  # failed immediately, no silent hang


def test_read_retry_fails_over_to_live_host():
    c = _mk(seed=34)
    lead = c.leader()
    eid = c.submit("SET fo live", via=lead)
    assert c.run_until_committed([eid])
    down = [n for n in c.nodes if n != lead][0]
    c.crash(down)
    rid = c.read("GET fo", via=down, retry_ms=100.0)
    assert c.run_until_reads([rid], 30_000)
    rec = c.reads[rid]
    assert rec["ok"] and rec["value"] == "live"
    assert len(rec["attempts"]) > 1
    assert c.metrics.counters.get("read_client_failovers", 0) >= 1


# --------------------------------------------------------------- hierarchy


def test_hierarchy_pod_local_reads_no_global_traffic():
    h = HierarchicalCluster(n_pods=2, hosts_per_pod=3, seed=3,
                            state_machine_factory=kv_factory)
    h.bootstrap()
    pod = h.pod_ids[0]
    local = h.pods[pod]
    lead = local.leader()
    eid = local.submit("SET pk podval", via=lead)
    assert local.run_until_committed([eid], 30_000)
    global_commits_before = {
        p: n.commit_index for p, n in h.global_nodes.items()
    }
    rids = [h.read_pod(pod, "GET pk") for _ in range(3)]
    assert h.run_until_pod_reads(pod, rids, 30_000)
    assert all(local.reads[r]["value"] == "podval" for r in rids)
    # Served entirely in-domain: the global tier committed nothing for them.
    assert {
        p: n.commit_index for p, n in h.global_nodes.items()
    } == global_commits_before
    h.check_consistency()


def test_hierarchy_replica_reads_and_removed_host():
    """read_pod(mode="replica") fans out across the pod's non-leader
    replicas; targeting a host the pod no longer has raises
    MembershipError instead of silently hanging."""
    h = HierarchicalCluster(n_pods=2, hosts_per_pod=3, seed=7,
                            state_machine_factory=kv_factory)
    h.bootstrap()
    pod = h.pod_ids[0]
    local = h.pods[pod]
    lead = local.leader()
    eid = local.submit("SET rk replicated", via=lead)
    assert local.run_until_committed([eid], 30_000)
    local.run(300)
    rids = [h.read_pod(pod, "GET rk", mode="replica") for _ in range(3)]
    assert h.run_until_pod_reads(pod, rids, 30_000)
    for r in rids:
        rec = local.reads[r]
        assert rec["value"] == "replicated"
        assert rec["via"] != lead  # fanned out to a non-leader replica
    # A dead replica host fails the read fast with a clear reason.
    down = [n for n in local.nodes if n != lead][0]
    local.crash(down)
    rid = h.read_pod(pod, "GET rk", via_host=down)
    assert local.reads[rid]["ok"] is False
    assert local.reads[rid]["error"] == f"host down: {down}"
    # A host that was never pod membership raises, not hangs.
    with pytest.raises(MembershipError):
        h.read_pod(pod, "GET rk", via_host="no-such-host")
