"""Witness (quorum-only member) edge cases.

A witness lives INSIDE the voter set with a marker
(`ClusterConfig.witnesses`): it votes in elections, acks replication
rounds and fast-track slots, but stores only log *skeletons* (entry id +
term, payload elided), runs no state machine, never campaigns, and never
serves reads. These tests pin the edges: joint-config transitions,
election non-participation, fast-quorum counting, snapshot-stream
elision, and the commit path when the quorum leans on witness acks.
"""

import pytest

from repro.core.raft import WITNESS_ELIDED, RaftConfig, skeleton_entry
from repro.core.sim import Cluster
from repro.core.statemachine import KVMachine
from repro.core.types import ClusterConfig, Entry, EntryId, Role, fast_quorum

from commit_history import (
    check_commit_history,
    check_config_oracle,
    check_kv_consistency,
    committed_acks,
)


def kv_factory(nid):
    return KVMachine()


# ------------------------------------------------------------ config model


def test_cluster_config_witness_marker_and_quorums():
    cfg = ClusterConfig.of(("a", "b", "c", "d", "e"), witnesses=("d", "e"))
    assert cfg.is_witness("d") and cfg.is_witness("e")
    assert not cfg.is_witness("a")
    # Witnesses are real voters: majority quorum counts them.
    assert cfg.election_won({"a", "d", "e"})
    assert not cfg.election_won({"a", "b"})
    # Fast quorum ceil(3V/4) = 4 of 5 counts witness votes too.
    assert fast_quorum(5) == 4
    assert cfg.fast_ok({"a", "b", "d", "e"})
    assert not cfg.fast_ok({"a", "b", "d"})
    # The marker survives canonicalization but only for actual voters.
    cfg2 = ClusterConfig.of(("a", "b", "c"), witnesses=("c", "zzz"))
    assert cfg2.witnesses == ("c",)


def test_skeleton_entry_preserves_identity_elides_payload():
    e = Entry(3, "put k=v", EntryId("n0", 7), 100.0)
    s = skeleton_entry(e)
    assert s.command == WITNESS_ELIDED
    assert s.same_entry(e) and s.term == e.term
    # Config and noop entries pass through un-elided: witnesses must be
    # able to act on membership changes and barriers.
    cfg_e = Entry(3, "__config__:whatever", EntryId("n0", 8), 100.0)
    assert skeleton_entry(cfg_e).command == cfg_e.command
    # Idempotent: re-eliding an already-elided entry is a no-op.
    assert skeleton_entry(s).command == WITNESS_ELIDED


# --------------------------------------------------- founding-set witnesses


def test_witness_counts_toward_commit_quorum():
    """3 full + 2 witnesses: crash both non-leader full replicas; the
    remaining quorum is leader + 2 witnesses and commits MUST proceed on
    witness skeleton acks."""
    c = Cluster(n=5, protocol="raft", seed=201, witnesses=["n3", "n4"],
                state_machine_factory=kv_factory)
    lead = c.run_until_leader()
    assert lead is not None and not c.nodes[lead].is_witness()
    for nid in ("n0", "n1", "n2"):
        if nid != lead:
            c.crash(nid)
    eids = [c.submit(f"put wq{i}=1", via=lead) for i in range(5)]
    assert c.run_until_committed(eids, 30_000)
    c.run(1000)  # commit index reaches the witnesses on the next heartbeat
    # The payload lives only on the leader; the witnesses hold skeletons.
    for w in ("n3", "n4"):
        node = c.nodes[w]
        assert node.commit_index >= 5
        for idx in range(1, node.commit_index + 1):
            s = node.slot(idx)
            if s is not None and not s.entry.command.startswith("__"):
                assert s.entry.command == WITNESS_ELIDED
    check_commit_history(c, acked=committed_acks(c, eids))


def test_witness_never_campaigns_and_never_wins_prevote():
    """An isolated voter would start elections and climb terms; an
    isolated witness must do neither (with or without PreVote)."""
    for pre_vote in (False, True):
        cfg = RaftConfig(pre_vote=pre_vote)
        c = Cluster(n=3, protocol="raft", seed=202, witnesses=["n2"], config=cfg)
        lead = c.run_until_leader()
        assert lead is not None and lead != "n2"
        term0 = c.nodes[lead].term
        c.partition(["n2"], [n for n in c.nodes if n != "n2"])
        c.run(20_000)
        w = c.nodes["n2"]
        assert w.role is Role.FOLLOWER
        assert w.term <= c.nodes[lead].term
        # The two full members never saw a disruption: same leader, same term.
        assert c.leader() == lead and c.nodes[lead].term == term0
        c.heal()
        c.run(2000)
        assert c.leader() == lead


def test_fast_track_commits_with_witness_votes():
    """Fast-track finalization needs ceil(3*5/4)=4 votes — with two
    witnesses, every fast commit necessarily counted at least one
    witness FastVote."""
    c = Cluster(n=5, protocol="fastraft", seed=203, witnesses=["n3", "n4"])
    lead = c.run_until_leader()
    assert lead is not None
    c.run(500)
    proposer = [n for n in c.nodes if n != lead and not c.nodes[n].is_witness()][0]
    eids = [c.submit(f"f{i}", via=proposer) for i in range(6)]
    assert c.run_until_committed(eids, 30_000)
    assert c.metrics.counters.get("fast_commits", 0) > 0
    check_commit_history(c, acked=committed_acks(c, eids))


def test_witness_refuses_replica_reads():
    c = Cluster(n=3, protocol="raft", seed=204, witnesses=["n2"],
                state_machine_factory=kv_factory)
    lead = c.run_until_leader()
    assert lead is not None
    e = c.submit("SET rk rv", via=lead)
    assert c.run_until_committed([e])
    rid = c.read("GET rk", via="n2", mode="replica")
    c.run(2000)
    rec = c.reads[rid]
    assert not rec["ok"] and "witness" in (rec.get("error") or "")
    # Leader-mode reads submitted AT a witness still work: they forward.
    rid2 = c.read("GET rk", via="n2", mode="leader")
    assert c.run_until_reads([rid2], 10_000)
    assert c.reads[rid2]["ok"] and c.reads[rid2]["value"] == "rv"


# --------------------------------------------------------- snapshot elision


def test_snapshot_stream_skips_witness():
    """A witness that falls behind the leader's compaction horizon is
    caught up by a payload-free base marker, not a chunked snapshot
    stream — and its own compaction never feeds the snapshot store."""
    cfg = RaftConfig(snapshot_threshold=8, snapshot_chunk_bytes=256)
    c = Cluster(n=3, protocol="raft", seed=205, witnesses=["n2"],
                state_machine_factory=kv_factory, config=cfg)
    lead = c.run_until_leader()
    assert lead is not None
    c.partition(["n2"], [n for n in c.nodes if n != "n2"])
    eids = [c.submit(f"put s{i}={i}", via=lead) for i in range(30)]
    assert c.run_until_committed(eids, 60_000)
    c.run(2000)  # let the leader compact past the witness's log
    assert c.nodes[lead].snapshot_last_index > 0
    c.heal()
    c.run(10_000)
    w = c.nodes["n2"]
    assert w.commit_index >= 30
    assert c.metrics.counters.get("witness_base_advances", 0) >= 1
    # No snapshot payload ever crossed the wire to (or from) the witness.
    assert w.snapshot.state is None
    assert not w.state_machine.snapshot()  # KV machine never saw a payload
    assert not w.committed_entries()
    check_commit_history(c, acked=committed_acks(c, eids))
    check_kv_consistency(c)


# ------------------------------------------------------ joint-config paths


def test_add_witness_joint_transition_under_load():
    """Promoting a learner to witness runs through joint consensus under
    continuous load: config oracle + zero acked loss throughout."""
    c = Cluster(n=3, protocol="raft", seed=206, state_machine_factory=kv_factory)
    lead = c.run_until_leader()
    assert lead is not None
    c.add_witness("n3")
    eids = []
    for i in range(20):
        eids.append(c.submit(f"put j{i}={i}", via=lead))
        c.run(150)
    assert c.run_until_membership(120_000), "witness promotion did not finish"
    committed = c.nodes[c.leader()].cluster_config
    assert committed.is_witness("n3") and "n3" in committed.voters
    more = [c.submit(f"put j2{i}={i}", via=c.leader()) for i in range(5)]
    assert c.run_until_committed(more, 30_000)
    c.run(2000)
    check_commit_history(c, acked=committed_acks(c, eids + more))
    check_config_oracle(c)
    check_kv_consistency(c)
    # The witness went through the learner phase without ever absorbing
    # payloads into its state machine.
    assert not c.nodes["n3"].committed_entries()


def test_remove_witness_joint_transition():
    c = Cluster(n=5, protocol="raft", seed=207, witnesses=["n4"])
    lead = c.run_until_leader()
    assert lead is not None
    eids = [c.submit(f"r{i}", via=lead) for i in range(5)]
    assert c.run_until_committed(eids)
    c.remove_node("n4")
    assert c.run_until_membership(120_000)
    cfg = c.nodes[c.leader()].cluster_config
    assert "n4" not in cfg.voters and not cfg.witnesses
    more = [c.submit(f"r2{i}", via=c.leader()) for i in range(3)]
    assert c.run_until_committed(more)
    check_commit_history(c, acked=committed_acks(c, eids + more))
    check_config_oracle(c)


def test_witness_survives_leader_crash_during_transition():
    """Crash the leader while the witness promotion is mid-joint: the new
    leader finishes (or safely abandons) the change; no acked loss, and
    the final config is coherent."""
    c = Cluster(n=3, protocol="raft", seed=208, state_machine_factory=kv_factory)
    lead = c.run_until_leader()
    assert lead is not None
    c.add_witness("n3")
    eids = []
    for i in range(8):
        eids.append(c.submit(f"put t{i}={i}", via=lead))
        c.run(120)
    c.crash(lead)
    c.run(8000)
    assert c.run_until_leader(60_000) is not None
    c.run_until_membership(180_000)
    c.nodes[lead].restart(c.sim.now)
    c.run(5000)
    check_commit_history(c, acked=committed_acks(c, eids))
    check_config_oracle(c)
    check_kv_consistency(c)


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
