"""Fast Raft tests: fast-track commit, quorum math, conflicts, fallback,
recovery of fast-committed entries across leader crashes (paper section 2.2)."""
import pytest

from repro.core.sim import Cluster
from repro.core.types import EntryId, fast_quorum, majority, recovery_threshold


def test_quorum_math():
    assert fast_quorum(3) == 3
    assert fast_quorum(4) == 3
    assert fast_quorum(5) == 4
    assert fast_quorum(8) == 6
    assert fast_quorum(16) == 12
    for m in range(3, 64):
        fq, mj = fast_quorum(m), majority(m)
        # Fast quorum is at least a majority.
        assert fq >= mj
        # Two fast quorums intersect in at least a majority.
        assert 2 * fq - m >= mj - 1
        # Recovery threshold is positive and unambiguous within a majority.
        t = recovery_threshold(m)
        assert t >= 1
        assert 2 * t > mj


def test_fast_commit_from_non_leader():
    c = Cluster(n=5, protocol="fastraft", seed=21)
    lead = c.run_until_leader()
    prop = [n for n in c.nodes if n != lead][0]
    eids = [c.submit(f"f{i}", via=prop) for i in range(8)]
    assert c.run_until_committed(eids)
    for e in eids:
        assert c.metrics.traces[e].mode == "fast"
        assert c.metrics.traces[e].fallbacks == 0
    assert c.metrics.counters.get("fast_commits", 0) >= 8
    c.run(1000)
    c.check_log_consistency()


def test_fast_track_is_faster_than_classic_forwarding():
    """The paper's core claim: fewer message rounds from a non-leader
    proposer. With constant one-way latency L and no loss, fast commit is
    observed at the leader after 2L (propose + vote) versus 3L for the
    classic track (forward + append + ack)."""
    L = 5.0
    lat = {}
    for proto in ("raft", "fastraft"):
        c = Cluster(n=5, protocol=proto, seed=22, base_latency=L, jitter=0.0)
        lead = c.run_until_leader()
        c.run(500)  # steady state: everyone knows the leader
        prop = [n for n in c.nodes if n != lead][0]
        eids = [c.submit(f"{proto}{i}", via=prop) for i in range(5)]
        assert c.run_until_committed(eids)
        lat[proto] = c.metrics.mean_latency()
    assert lat["fastraft"] == pytest.approx(2 * L, abs=1e-6)
    assert lat["raft"] == pytest.approx(3 * L, abs=1e-6)


def test_conflicting_proposals_fall_back_and_all_commit():
    """Concurrent proposals from different nodes race for the same slot; the
    losers must still commit exactly once via the classic track."""
    c = Cluster(n=4, protocol="fastraft", seed=23)
    lead = c.run_until_leader()
    others = [n for n in c.nodes if n != lead]
    # Same tick: all three non-leaders propose -> identical slot choice.
    eids = [c.submit(f"conflict-{n}", via=n) for n in others]
    assert c.run_until_committed(eids, 30_000)
    c.run(2000)
    c.check_log_consistency()
    # Each command appears exactly once in the committed log.
    log = c.nodes[lead].committed_commands()
    for n in others:
        assert log.count(f"conflict-{n}") == 1


def test_duplicate_submission_commits_once():
    c = Cluster(n=3, protocol="fastraft", seed=24)
    lead = c.run_until_leader()
    prop = [n for n in c.nodes if n != lead][0]
    node = c.nodes[prop]
    eid = EntryId(prop, 12345)
    c.dispatch(prop, node.client_request("dup", c.sim.now, entry_id=eid))
    c.run(50)
    c.dispatch(prop, node.client_request("dup", c.sim.now, entry_id=eid))
    assert c.run_until_committed([eid])
    c.run(2000)
    assert c.nodes[lead].committed_commands().count("dup") == 1


def test_lossy_network_fast_raft_commits():
    c = Cluster(n=5, protocol="fastraft", seed=25, loss=0.08, jitter=2.0)
    lead = c.run_until_leader(20_000)
    assert lead is not None
    prop = [n for n in c.nodes if n != lead][0]
    eids = [c.submit(f"l{i}", via=prop) for i in range(10)]
    assert c.run_until_committed(eids, 60_000)
    c.run(2000)
    c.check_log_consistency()


def test_leader_crash_recovers_fast_committed_entry():
    """A fast-committed entry (>= ceil(3M/4) votes) must survive leader
    failure: the next leader recovers it from vote-reply tails."""
    c = Cluster(n=4, protocol="fastraft", seed=26)
    lead = c.run_until_leader()
    prop = [n for n in c.nodes if n != lead][0]
    eid = c.submit("must-survive", via=prop)
    assert c.run_until_committed([eid])
    # Crash the leader immediately after commit, before heartbeats spread
    # the commit index everywhere.
    c.crash(lead)
    c.run(10_000)
    new_lead = c.leader()
    assert new_lead is not None
    c.run(3000)
    assert "must-survive" in c.nodes[new_lead].committed_commands()
    c.check_log_consistency()


def test_leader_crash_mid_vote_no_loss_no_duplicate():
    """Crash the leader while fast votes are in flight; after recovery the
    command commits exactly once (either recovered or re-proposed)."""
    c = Cluster(n=5, protocol="fastraft", seed=27, base_latency=5.0)
    lead = c.run_until_leader()
    prop = [n for n in c.nodes if n != lead][0]
    eid = c.submit("in-flight", via=prop)
    c.run(6)  # proposal delivered, votes still travelling
    c.crash(lead)
    c.run(30_000)
    new_lead = c.leader()
    assert new_lead is not None
    logs = c.nodes[new_lead].committed_commands()
    assert logs.count("in-flight") <= 1
    # Liveness: the entry eventually commits (recovery readopt or proposer
    # classic retry).
    assert c.run_until_committed([eid], 60_000)
    c.run(2000)
    c.check_log_consistency()


def test_mixed_fast_and_classic_traffic():
    c = Cluster(n=5, protocol="fastraft", seed=28)
    lead = c.run_until_leader()
    others = [n for n in c.nodes if n != lead]
    eids = []
    for i in range(12):
        via = lead if i % 3 == 0 else others[i % len(others)]
        eids.append(c.submit(f"mix{i}", via=via))
        c.run(7)
    assert c.run_until_committed(eids, 60_000)
    c.run(2000)
    c.check_log_consistency()
    log = c.nodes[lead].committed_commands()
    for i in range(12):
        assert log.count(f"mix{i}") == 1


def test_fast_raft_membership_add():
    c = Cluster(n=3, protocol="fastraft", seed=29)
    lead = c.run_until_leader()
    eids = [c.submit(f"m{i}", via=lead) for i in range(3)]
    assert c.run_until_committed(eids)
    c.add_node("n3")
    c.run(5000)
    assert "n3" in c.nodes[lead].members
    # Fast quorum size reflects the new membership on the leader.
    assert fast_quorum(c.nodes[lead].m) == fast_quorum(4)
    prop = "n3"
    e = c.submit("from-new-node", via=prop)
    assert c.run_until_committed([e], 30_000)
    c.check_log_consistency()
