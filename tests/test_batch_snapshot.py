"""Batched + pipelined replication and snapshot/log compaction tests.

Covers the new subsystem end to end: multi-entry AppendEntries batches,
multi-slot FastPropose windows, the leader replication pipeline, compaction
at ``snapshot_threshold``, InstallSnapshot catch-up, snapshot persistence
through :class:`repro.checkpoint.manager.SnapshotStore`, and the chaos
interactions (snapshot while partitioned, restart from snapshot, batched
fast track under loss). Every scenario validates the full client contract
via :func:`commit_history.check_commit_history`.
"""
import pytest

from commit_history import check_commit_history, committed_acks

from repro.checkpoint.manager import SnapshotStore
from repro.core.raft import RaftConfig
from repro.core.sim import Cluster


# --------------------------------------------------------------- batching


def test_batched_fast_track_commits_in_one_window():
    """A whole burst rides ONE FastPropose window and commits on the fast
    track in the same 2 rounds a single entry takes."""
    L = 5.0
    c = Cluster(n=5, protocol="fastraft", seed=71, base_latency=L, jitter=0.0)
    lead = c.run_until_leader()
    c.run(500)
    prop = [n for n in c.nodes if n != c.leader()][0]
    eids = c.submit_batch([f"w{i}" for i in range(16)], via=prop)
    assert c.run_until_committed(eids, 60_000)
    # Entire window fast-committed, none fell back, 2 rounds flat.
    for e in eids:
        t = c.metrics.traces[e]
        assert t.mode == "fast" and t.fallbacks == 0
        assert t.latency == pytest.approx(2 * L, abs=1e-6)
    c.run(2000)
    check_commit_history(c, acked=eids, fifo_origins=[prop])


def test_batched_classic_forwarding_single_rpc():
    """Classic track: a follower burst moves in one relay RPC and one
    multi-entry AppendEntries broadcast."""
    c = Cluster(n=5, protocol="raft", seed=72)
    lead = c.run_until_leader()
    c.run(500)
    prop = [n for n in c.nodes if n != c.leader()][0]
    forwards_before = c.metrics.counters.get("forwards", 0)
    eids = c.submit_batch([f"f{i}" for i in range(32)], via=prop)
    assert c.run_until_committed(eids, 60_000)
    assert c.metrics.counters.get("forwards", 0) == forwards_before + 1
    c.run(2000)
    check_commit_history(c, acked=eids, fifo_origins=[prop])


def test_leader_batch_window_coalesces_broadcasts():
    """With batch_window > 0 the leader buffers client commands and appends
    them as one batch at the flush deadline."""
    cfg = RaftConfig(batch_window=30.0, max_batch_entries=64)
    c = Cluster(n=3, protocol="raft", seed=73, config=cfg)
    lead = c.run_until_leader()
    c.run(500)
    lead = c.leader()
    eids = [c.submit(f"z{i}", via=lead) for i in range(10)]
    # Nothing appended yet: commands are coalescing in the buffer.
    assert c.nodes[lead].last_log_index() < 10
    assert c.run_until_committed(eids, 60_000)
    c.run(2000)
    check_commit_history(c, acked=eids, fifo_origins=[lead])


def test_pipelined_catchup_of_lagging_follower():
    """A follower that missed a large log tail catches up through pipelined
    multi-batch AppendEntries (no snapshot involved)."""
    cfg = RaftConfig(max_batch_entries=16, max_inflight_batches=4)
    c = Cluster(n=3, protocol="raft", seed=74, config=cfg)
    lead = c.run_until_leader()
    c.run(500)
    lead = c.leader()
    victim = [n for n in c.nodes if n != lead][0]
    c.crash(victim)
    eids = [c.submit(f"p{i}", via=lead) for i in range(200)]
    assert c.run_until_committed(eids, 120_000)
    c.restart(victim)
    c.run(10_000)
    assert c.nodes[victim].commit_index >= 200
    check_commit_history(c, acked=eids, fifo_origins=[lead])


def test_fast_track_batches_under_loss():
    """Batched fast-track windows under 10% loss: every command still
    commits exactly once (window proposals re-route per-slot through
    fallback / retry like single proposals do)."""
    c = Cluster(n=5, protocol="fastraft", seed=75, loss=0.10, jitter=2.0)
    lead = c.run_until_leader(30_000)
    assert lead is not None
    c.run(1000)
    others = [n for n in c.nodes if n != c.leader()]
    eids = []
    for b in range(4):
        eids += c.submit_batch([f"l{b}_{i}" for i in range(8)],
                               via=others[b % len(others)])
        c.run(500)
    assert c.run_until_committed(eids, 240_000)
    c.run(5000)
    check_commit_history(c, acked=eids)


# ------------------------------------------------------------- snapshots


def test_compaction_truncates_log_and_preserves_state():
    cfg = RaftConfig(snapshot_threshold=10)
    c = Cluster(n=3, protocol="fastraft", seed=76, config=cfg)
    lead = c.run_until_leader()
    c.run(500)
    lead = c.leader()
    eids = [c.submit(f"c{i}", via=lead) for i in range(25)]
    assert c.run_until_committed(eids, 60_000)
    c.run(3000)
    n = c.nodes[lead]
    assert n.snapshot is not None and n.snapshot.last_index >= 10
    assert len(n.log) < 25  # prefix actually dropped from the live log
    assert n.committed_commands()[:25] == [f"c{i}" for i in range(25)]
    check_commit_history(c, acked=eids, fifo_origins=[lead])


def test_restarted_follower_converges_via_install_snapshot():
    """Acceptance scenario: leader compacts while a follower is down; the
    restarted follower cannot be caught up by AppendEntries (entries are
    gone) and converges via InstallSnapshot."""
    cfg = RaftConfig(snapshot_threshold=10)
    c = Cluster(n=3, protocol="raft", seed=77, config=cfg)
    lead = c.run_until_leader()
    c.run(500)
    lead = c.leader()
    victim = [n for n in c.nodes if n != lead][0]
    c.crash(victim)
    eids = [c.submit(f"s{i}", via=lead) for i in range(40)]
    assert c.run_until_committed(eids, 120_000)
    assert c.nodes[lead].snapshot is not None
    assert c.nodes[lead].snapshot.last_index > c.nodes[victim].last_log_index()
    c.restart(victim)
    c.run(30_000)
    assert c.metrics.counters.get("snapshots_installed", 0) >= 1
    assert c.nodes[victim].commit_index >= 40
    check_commit_history(c, acked=eids, fifo_origins=[lead])


def test_snapshot_while_partitioned():
    """Chaos: a follower is partitioned away, the majority keeps committing
    and compacts PAST the partition point, then the partition heals — the
    stale follower must converge (snapshot, then pipelined tail)."""
    cfg = RaftConfig(snapshot_threshold=8, max_batch_entries=8)
    c = Cluster(n=5, protocol="fastraft", seed=78, config=cfg)
    lead = c.run_until_leader()
    c.run(500)
    lead = c.leader()
    isolated = [n for n in c.nodes if n != lead][0]
    rest = [n for n in c.nodes if n != isolated]
    c.partition([isolated], rest)
    eids = [c.submit(f"m{i}", via=lead) for i in range(30)]
    assert c.run_until_committed(eids, 120_000)
    assert c.nodes[lead].snapshot is not None
    c.heal()
    c.run(30_000)
    assert c.nodes[isolated].commit_index >= 30
    check_commit_history(c, acked=eids, fifo_origins=[lead])


def test_restart_from_snapshot_store(tmp_path):
    """Full host replacement: a node loses everything but the persisted
    snapshot (checkpoint volume), cold-starts from the SnapshotStore, and
    rejoins the cluster."""
    store = SnapshotStore(str(tmp_path))
    cfg = RaftConfig(snapshot_threshold=8)
    c = Cluster(n=3, protocol="fastraft", seed=79, config=cfg,
                snapshot_store=store)
    lead = c.run_until_leader()
    c.run(500)
    lead = c.leader()
    eids = [c.submit(f"r{i}", via=lead) for i in range(20)]
    assert c.run_until_committed(eids, 60_000)
    c.run(3000)
    victim = [n for n in c.nodes if n != c.leader()][0]
    persisted = store.latest_index(victim)
    assert persisted >= 8, "compaction never persisted a snapshot"
    c.crash(victim)
    c.run(1000)
    c.restart_from_store(victim)
    # The fresh node starts from the persisted snapshot, not an empty log.
    assert c.nodes[victim].commit_index == persisted
    more = [c.submit(f"post{i}", via=c.leader()) for i in range(5)]
    assert c.run_until_committed(more, 60_000)
    c.run(10_000)
    assert c.nodes[victim].commit_index >= 25
    check_commit_history(c, acked=eids + more)


def test_hierarchy_snapshot_during_pod_partition():
    """Hierarchy chaos: one pod host is isolated, the pod keeps committing
    (local + down-propagated global traffic), every live host force-compacts
    mid-partition, then the partition heals — the stale host converges via
    InstallSnapshot and global delivery stays prefix-consistent."""
    from repro.core.hierarchy import HierarchicalCluster

    h = HierarchicalCluster(n_pods=2, hosts_per_pod=3, seed=81,
                            config=RaftConfig(snapshot_threshold=6))
    h.bootstrap()
    pod = h.pod_ids[0]
    lead = h.pods[pod].leader()
    stale = [n for n in h.pods[pod].nodes if n != lead][0]
    h.isolate_pod_host(pod, stale)
    eids = [h.propose_global(f"g{i}") for i in range(10)]
    assert h.run_until_globally_committed(eids, 240_000)
    h.run(10_000)
    h.compact_pod(pod)
    h.heal_pod_hosts(pod)
    h.run(60_000)
    stale_node = h.pods[pod].nodes[stale]
    live_lead = h.pods[pod].leader()
    assert live_lead is not None
    assert stale_node.commit_index >= h.pods[pod].nodes[live_lead].commit_index - 2
    h.check_consistency()


def test_restore_hard_state_no_seq_reuse_no_double_vote(tmp_path):
    """Regression: a host replaced via the store must restore Raft hard
    state (term, voted_for, burned seqs), not just the snapshot. Seqs
    burned AFTER the last compaction must not be re-minted (a fresh command
    would collide with an old EntryId and be swallowed as a retry), and the
    restored term must not regress below the pre-crash term (double-vote)."""
    store = SnapshotStore(str(tmp_path))
    cfg = RaftConfig(snapshot_threshold=8)
    c = Cluster(n=3, protocol="fastraft", seed=82, config=cfg,
                snapshot_store=store)
    lead = c.run_until_leader()
    c.run(500)
    lead = c.leader()
    victim = [n for n in c.nodes if n != lead][0]
    # Burn seqs at the victim BEYOND the compaction horizon: snapshot covers
    # ~8-16 entries, then more submissions burn higher seqs.
    eids = [c.submit(f"pre{i}", via=victim) for i in range(12)]
    assert c.run_until_committed(eids, 60_000)
    c.run(3000)
    pre_term = c.nodes[victim].term
    pre_seq = c.nodes[victim]._seq
    assert store.latest_index(victim) < 12 or True  # snapshot lags the tail
    c.crash(victim)
    c.run(1000)
    c.restart_from_store(victim)
    node = c.nodes[victim]
    assert node._seq >= pre_seq, (node._seq, pre_seq)
    assert node.term >= pre_term, (node.term, pre_term)
    # Fresh commands from the restored host must commit as NEW entries.
    new = [c.submit(f"post{i}", via=victim) for i in range(3)]
    assert c.run_until_committed(new, 60_000)
    c.run(5000)
    log = c.nodes[c.leader()].committed_commands()
    for i in range(3):
        assert log.count(f"post{i}") == 1, (i, log)
    check_commit_history(c, acked=eids + new)


def test_snapshot_store_roundtrip(tmp_path):
    """SnapshotStore serialization is lossless (entry ids, terms, members)."""
    store = SnapshotStore(str(tmp_path))
    cfg = RaftConfig(snapshot_threshold=5)
    c = Cluster(n=3, protocol="raft", seed=80, config=cfg, snapshot_store=store)
    lead = c.run_until_leader()
    c.run(500)
    lead = c.leader()
    eids = [c.submit(f"d{i}", via=lead) for i in range(12)]
    assert c.run_until_committed(eids, 60_000)
    snap = c.nodes[lead].snapshot
    assert snap is not None
    loaded = store.load(lead)
    assert loaded is not None
    assert loaded.last_index == snap.last_index
    assert loaded.last_term == snap.last_term
    assert tuple(loaded.members) == tuple(snap.members)
    assert [e.entry_id for e in loaded.entries] == [
        e.entry_id for e in snap.entries
    ]
    assert [e.command for e in loaded.entries] == [
        e.command for e in snap.entries
    ]
