"""In-graph consensus collectives need >1 device; run the checks in a
subprocess with a forced 8-device host platform so the main test process
keeps its single-device view (required by the smoke tests)."""
import os
import pathlib
import subprocess
import sys

import pytest


@pytest.mark.slow  # subprocess XLA compiles on a forced 8-device host platform
def test_collective_consensus_multidevice():
    child = pathlib.Path(__file__).parent / "collective_child.py"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(pathlib.Path(__file__).parents[1] / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, str(child)], capture_output=True, text=True, env=env,
        timeout=600,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    assert "COLLECTIVE-OK" in res.stdout
