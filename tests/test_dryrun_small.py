"""Dry-run machinery CI: exercises input_specs, lowering, compile, and the
collective parser on an 8-device host mesh in a SUBPROCESS (so the main
pytest process keeps one device)."""
import json
import os
import pathlib
import subprocess
import sys

import pytest

CHILD = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8"
                           " --xla_disable_hlo_passes=all-reduce-promotion")
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import compat
from repro.configs import registry
from repro.launch.dryrun import parse_collectives, _shaped
from repro.models import zoo
from repro.optim.adamw import AdamWConfig
from repro.runtime import sharding as shd, spmd

mesh = jax.make_mesh((4, 2), ("data", "model"))

for arch in ("qwen3-1.7b", "jamba-v0.1-52b", "xlstm-1.3b"):
    cfg = registry.get(arch, reduced=True)
    model = zoo.build(cfg, dtype=jnp.bfloat16)
    opt = AdamWConfig()
    step_fn, _, _ = spmd.build_train_step(model, opt, mesh)
    tpl = jax.eval_shape(lambda r: spmd.make_train_state(model, opt, r, False),
                         jax.random.PRNGKey(0))
    specs = spmd.state_specs(model, opt, mesh, False)
    structs = _shaped(tpl, mesh, specs)
    B, T = 8, 32
    batch = {
        k: jax.ShapeDtypeStruct((B, T), jnp.int32,
                                sharding=NamedSharding(mesh, P("data", None)))
        for k in ("tokens", "labels")
    }
    batch["loss_mask"] = jax.ShapeDtypeStruct(
        (B, T), jnp.float32, sharding=NamedSharding(mesh, P("data", None)))
    compiled = step_fn.lower(structs, batch).compile()
    cost = compat.cost_analysis(compiled)
    coll = parse_collectives(compiled.as_text(), 8)
    assert cost.get("flops", 0) > 0, (arch, cost)
    assert coll["total_bytes"] > 0, (arch, "no collectives found")
    assert coll["counts"].get("all-reduce", 0) > 0
    # FSDP leaves must reduce-scatter, not all-reduce.
    assert coll["counts"].get("reduce-scatter", 0) > 0, (arch, coll["counts"])
    print(f"{arch}: OK flops={cost['flops']:.3g} coll={coll['total_bytes']:.3g}")

    # Serve path: decode against a 2k cache.
    p_tpl = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = shd.tree_param_specs(p_tpl, mesh)
    p_structs = _shaped(p_tpl, mesh, p_specs)
    cache_tpl = jax.eval_shape(lambda: model.init_cache(8, 2048))
    c_specs = shd.tree_cache_specs(cache_tpl, mesh)
    c_structs = _shaped(cache_tpl, mesh, c_specs)
    dbatch = {"tokens": jax.ShapeDtypeStruct(
        (8, 1), jnp.int32, sharding=NamedSharding(mesh, P("data", None)))}
    dec = jax.jit(model.decode_step).lower(p_structs, c_structs, dbatch).compile()
    assert compat.cost_analysis(dec).get("flops", 0) > 0
    print(f"{arch}: decode OK")

print("DRYRUN-SMALL-OK")
"""


@pytest.mark.slow  # subprocess XLA compile of 3 archs (train + decode), minutes
def test_dryrun_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(pathlib.Path(__file__).parents[1] / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", CHILD], capture_output=True,
                         text=True, env=env, timeout=900)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr[-3000:]}"
    assert "DRYRUN-SMALL-OK" in res.stdout


def test_collective_parser_units():
    from repro.launch.dryrun import parse_collectives

    hlo = """
  %ag = bf16[16,512]{1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[1024]{0} all-reduce(%y), replica_groups=[16,16]<=[256], to_apply=%add
  %rs = bf16[4,128]{1,0} reduce-scatter(%z), replica_groups=[16,16]<=[256], dimensions={0}
  %cp = bf16[8,8]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
"""
    out = parse_collectives(hlo, 256)
    assert out["counts"] == {"all-gather": 1, "all-reduce": 1,
                             "reduce-scatter": 1, "collective-permute": 1}
    ag = 16 * 512 * 2 * 15 / 16
    ar = 2 * 1024 * 4 * 15 / 16
    rs = 4 * 128 * 2 * 15
    cp = 8 * 8 * 2
    assert abs(out["total_bytes"] - (ag + ar + rs + cp)) < 1e-6
