"""Protocol fuzzer: determinism, trace roundtrip, shrinking, oracles.

The fuzzer itself (src/repro/core/fuzzer.py) is infrastructure that mints
regression tests, so it gets the same correctness bar as the protocol: its
schedules must be reproducible bit-for-bit per seed, its trace files must
replay standalone, and its shrinker must preserve the failure it minimizes.
"""
import json

import pytest

from repro.core.fuzzer import (
    FuzzProfile,
    ProtocolFuzzer,
    load_trace,
    make_trace,
    replay,
    replay_trace_file,
    save_trace,
    shrink,
)
from repro.core.hierarchy import HierarchicalCluster
from repro.core.raft import RaftConfig
from repro.core.sim import Adversary, Cluster


# ------------------------------------------------------------ determinism


def test_same_seed_same_trace_and_verdict():
    t1, r1 = ProtocolFuzzer(6, steps=25).run()
    t2, r2 = ProtocolFuzzer(6, steps=25).run()
    assert t1 == t2
    assert r1.to_dict() == r2.to_dict()


def test_different_seeds_differ():
    t1 = ProtocolFuzzer(1, steps=25).generate()
    t2 = ProtocolFuzzer(2, steps=25).generate()
    assert t1["ops"] != t2["ops"]


def test_generation_is_execution_free():
    """Op generation draws from its own RNG with concrete node names — the
    trace must be fully resolved JSON (replayable with no cluster state)."""
    trace = ProtocolFuzzer(3, steps=30).generate()
    # JSON roundtrip is identity: nothing in the trace is a live object.
    assert json.loads(json.dumps(trace)) == trace
    for op in trace["ops"]:
        assert isinstance(op.get("op"), str)


# ------------------------------------------------------- seeds pass oracles


@pytest.mark.parametrize("seed", [1, 2, 3])
def test_fuzz_seed_passes(seed):
    trace, report = ProtocolFuzzer(seed, steps=20).run()
    assert report.ok, report.error


@pytest.mark.slow
@pytest.mark.parametrize("seed", list(range(4, 13)))
def test_fuzz_seed_passes_slow(seed):
    trace, report = ProtocolFuzzer(seed, steps=40).run()
    assert report.ok, report.error


# ------------------------------------------------------------ trace format


def test_trace_roundtrip(tmp_path):
    trace, report = ProtocolFuzzer(5, steps=12).run()
    path = str(tmp_path / "t.json")
    save_trace(trace, path)
    assert load_trace(path) == trace
    replayed = replay_trace_file(path)
    assert replayed.to_dict() == report.to_dict()


def test_trace_rejects_unknown_version(tmp_path):
    path = str(tmp_path / "bad.json")
    save_trace({"version": 99, "ops": []}, path)
    with pytest.raises(AssertionError):
        load_trace(path)


def test_replay_tolerates_invalid_ops():
    """Shrinking deletes ops arbitrarily; bookkeeping-impossible ops
    (unknown node, unknown kind) must be skipped, not crash the replay."""
    trace = make_trace(
        0,
        [
            {"op": "run", "ms": 2000.0},
            {"op": "crash", "node": "nope"},
            {"op": "restart", "node": "nope"},
            {"op": "partition", "groups": [["n0"], ["ghost"]]},
            {"op": "membership", "kind": "remove", "node": "ghost"},
            {"op": "frobnicate"},
            {"op": "run", "ms": 1000.0},
        ],
        expect={"require_leader": True},
    )
    report = replay(trace)
    assert report.ok, report.error


def test_expectations_enforced():
    trace = make_trace(
        0,
        [{"op": "run", "ms": 3000.0}],
        expect={"max_leader_elections": 0},
    )
    report = replay(trace)
    assert not report.ok
    assert "leaderships" in report.error


# -------------------------------------------------------------- shrinking


def test_shrink_preserves_failure_and_minimizes():
    ops = [{"op": "run", "ms": 500.0} for _ in range(8)]
    # The failure needs only the ops that elect a leader; expect forbids any
    # election, so a single run op should survive shrinking.
    trace = make_trace(0, ops, expect={"max_leader_elections": 0})
    assert not replay(trace).ok
    small, replays = shrink(trace)
    assert replays > 0
    assert not replay(small).ok, "shrunk trace must still fail"
    assert len(small["ops"]) < len(ops)
    assert len(small["ops"]) == 1


# ------------------------------------------------------ adversary plumbing


def test_adversary_deterministic_and_counts():
    adv1 = Adversary(seed=7, drop_p=0.5, dup_p=0.3)
    adv2 = Adversary(seed=7, drop_p=0.5, dup_p=0.3)
    from repro.core.metrics import Recorder
    from repro.core.types import AppendEntriesArgs

    r1, r2 = Recorder(), Recorder()
    msg = AppendEntriesArgs(term=1, src="n0")
    out1 = [len(adv1.apply(msg, r1)) for _ in range(200)]
    out2 = [len(adv2.apply(msg, r2)) for _ in range(200)]
    assert out1 == out2, "same adversary seed must give same fault schedule"
    assert r1.counters.get("adv_dropped", 0) > 0
    assert r1.counters.get("adv_duplicated", 0) > 0


def test_cluster_survives_dropping_duplicating_adversary():
    c = Cluster(n=5, protocol="fastraft", seed=77,
                config=RaftConfig(pre_vote=True, check_quorum=True))
    assert c.run_until_leader() is not None
    c.adversary = Adversary(seed=3, drop_p=0.2, dup_p=0.2,
                            until=c.sim.now + 4000.0)
    eids = c.submit_batch([f"w{i}" for i in range(10)])
    c.run(6000.0)  # adversary window expires mid-way
    assert c.run_until_committed(eids, 30_000.0)
    assert c.metrics.counters.get("adv_dropped", 0) > 0
    c.check_log_consistency()


def test_corruption_of_snapshot_chunks_detected_and_healed():
    """A bit-flipping adversary on chunked snapshot transfer: CRC catches
    every flip (treated as loss), retransmission heals, and the follower
    still restores a correct snapshot."""
    cfg = RaftConfig(snapshot_threshold=8, snapshot_chunk_bytes=64,
                     snapshot_chunk_window=2)
    c = Cluster(n=3, protocol="raft", seed=11, config=cfg)
    assert c.run_until_leader() is not None
    ids = sorted(c.nodes)
    straggler = [n for n in ids if n != c.leader()][0]
    c.crash(straggler)
    eids = c.submit_batch([f"cmd-{i:03d}" for i in range(30)], via=c.leader())
    assert c.run_until_committed(eids, 30_000.0)
    c.restart(straggler)
    c.adversary = Adversary(seed=5, corrupt_p=0.3, until=c.sim.now + 5000.0)
    c.run(25_000.0)
    assert c.metrics.counters.get("adv_corrupted", 0) > 0, (
        "adversary never hit a snapshot chunk"
    )
    assert c.metrics.counters.get("corrupt_chunks_dropped", 0) > 0, (
        "receiver never detected a corrupted chunk"
    )
    c.check_log_consistency()
    assert c.nodes[straggler].commit_index == c.nodes[c.leader()].commit_index


def test_per_pod_adversary_isolated():
    """A fault injector on one pod must not perturb the other pod or the
    global tier — and the hierarchy still commits globally through it."""
    h = HierarchicalCluster(n_pods=2, hosts_per_pod=3, seed=41)
    h.bootstrap()
    h.set_pod_adversary("pod0", Adversary(seed=9, drop_p=0.15, dup_p=0.1))
    h.run(3000)  # heartbeat traffic under fire
    eids = [h.propose_global(f"g{i}") for i in range(3)]
    assert h.run_until_globally_committed(eids, 60_000)
    h.check_consistency()
    assert h.pods["pod0"].metrics.counters.get("adv_dropped", 0) > 0
    assert h.pods["pod1"].metrics.counters.get("adv_dropped", 0) == 0
    assert h.global_metrics.counters.get("adv_dropped", 0) == 0


def test_global_adversary_smoke():
    h = HierarchicalCluster(n_pods=3, hosts_per_pod=3, seed=42)
    h.bootstrap()
    h.set_global_adversary(Adversary(seed=2, drop_p=0.2, dup_p=0.1))
    eids = [h.propose_global(f"g{i}") for i in range(3)]
    assert h.run_until_globally_committed(eids, 90_000)
    h.check_consistency()
    assert h.global_metrics.counters.get("adv_dropped", 0) > 0
