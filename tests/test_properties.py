"""Property-based safety tests: hypothesis drives random chaos schedules
(submissions from random nodes, crashes, restarts, partitions, lossy links)
and the Recorder enforces the two core safety invariants ONLINE:

  * Election Safety  — at most one leader per term,
  * State Machine Safety — no two nodes ever apply different entries at the
    same index.

plus end-of-run checks: committed-log prefix consistency and exactly-once
commitment per submitted command.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.sim import Cluster
from repro.core.types import fast_quorum, majority, recovery_threshold


# ---------------------------------------------------------------------------
# Quorum arithmetic properties (the algebra behind fast-track safety).
# ---------------------------------------------------------------------------


@given(st.integers(min_value=2, max_value=4096))
def test_fast_quorum_intersection_contains_majority(m):
    """Any two fast quorums overlap in >= majority-1 nodes, so a conflicting
    pair of fast commits is impossible."""
    assert 2 * fast_quorum(m) - m >= majority(m) - 1


@given(st.integers(min_value=3, max_value=4096))
def test_recovery_threshold_sound_and_unambiguous(m):
    fq, mj, t = fast_quorum(m), majority(m), recovery_threshold(m)
    # Sound: a fast-committed entry appears >= t times in any majority.
    assert fq + mj - m >= t >= 1
    # Unambiguous: two entries cannot both reach t within one majority.
    assert 2 * t > mj


@given(
    st.integers(min_value=3, max_value=512),
    st.integers(min_value=1, max_value=511),
)
def test_classic_and_fast_commit_mutually_exclusive(m, k):
    """A classic quorum for entry X and a fast quorum for entry Y at the same
    slot would need majority(m) + fast_quorum(m) <= m distinct nodes —
    impossible, since per-slot votes are first-come-first-served."""
    assert majority(m) + fast_quorum(m) > m


# ---------------------------------------------------------------------------
# Randomized schedule exploration.
# ---------------------------------------------------------------------------

ops_strategy = st.lists(
    st.one_of(
        st.tuples(st.just("submit"), st.integers(0, 4)),
        st.tuples(st.just("crash"), st.integers(0, 4)),
        st.tuples(st.just("restart"), st.integers(0, 4)),
        st.tuples(st.just("run"), st.integers(50, 800)),
        st.tuples(st.just("partition"), st.integers(1, 4)),
        st.tuples(st.just("heal"), st.integers(0, 0)),
    ),
    min_size=4,
    max_size=25,
)


def _run_chaos(protocol: str, n: int, seed: int, loss: float, ops) -> None:
    c = Cluster(n=n, protocol=protocol, seed=seed, loss=loss, jitter=2.0)
    c.run_until_leader(30_000)
    ids = list(c.nodes)
    submitted = []
    crashed = set()
    for op, arg in ops:
        if op == "submit":
            via = ids[arg % n]
            if c.nodes[via].alive:
                submitted.append(c.submit(f"cmd-{len(submitted)}", via=via))
        elif op == "crash":
            nid = ids[arg % n]
            # Keep a majority alive so liveness checks stay meaningful.
            if len(crashed) + 1 < n - n // 2 and c.nodes[nid].alive:
                c.crash(nid)
                crashed.add(nid)
        elif op == "restart":
            nid = ids[arg % n]
            if nid in crashed:
                c.restart(nid)
                crashed.discard(nid)
        elif op == "run":
            c.run(float(arg))
        elif op == "partition":
            k = max(1, arg % n)
            c.partition(ids[:k], ids[k:])
        elif op == "heal":
            c.heal()
    # Heal everything and let the cluster converge.
    c.heal()
    for nid in list(crashed):
        c.restart(nid)
    c.run(30_000)

    # SAFETY: prefix-consistent committed logs (online invariants already
    # checked every apply by the Recorder).
    c.check_log_consistency()
    # Exactly-once: no command appears twice in any committed log.
    for nid, node in c.nodes.items():
        log = node.committed_commands()
        assert len(log) == len(set(log)), f"{nid} double-committed: {log}"


@pytest.mark.slow  # randomized multi-minute chaos schedules
@settings(max_examples=25, deadline=None, suppress_health_check=list(HealthCheck))
@given(
    ops=ops_strategy,
    seed=st.integers(0, 2**16),
    n=st.sampled_from([3, 4, 5]),
    loss=st.sampled_from([0.0, 0.02, 0.10]),
)
def test_fastraft_chaos_safety(ops, seed, n, loss):
    _run_chaos("fastraft", n, seed, loss, ops)


@pytest.mark.slow  # randomized multi-minute chaos schedules
@settings(max_examples=15, deadline=None, suppress_health_check=list(HealthCheck))
@given(
    ops=ops_strategy,
    seed=st.integers(0, 2**16),
    n=st.sampled_from([3, 5]),
    loss=st.sampled_from([0.0, 0.05]),
)
def test_raft_chaos_safety(ops, seed, n, loss):
    _run_chaos("raft", n, seed, loss, ops)


@pytest.mark.slow  # randomized chaos schedules
@settings(max_examples=10, deadline=None, suppress_health_check=list(HealthCheck))
@given(
    seed=st.integers(0, 2**16),
    n=st.sampled_from([4, 5, 7]),
    burst=st.integers(2, 10),
)
def test_fastraft_concurrent_proposers_liveness(seed, n, burst):
    """All concurrent (conflicting) proposals eventually commit exactly once
    on a healthy network."""
    c = Cluster(n=n, protocol="fastraft", seed=seed)
    lead = c.run_until_leader(30_000)
    assert lead is not None
    c.run(500)
    others = [x for x in c.nodes if x != lead]
    eids = [c.submit(f"b{i}", via=others[i % len(others)]) for i in range(burst)]
    assert c.run_until_committed(eids, 120_000)
    c.run(2000)
    c.check_log_consistency()
    log = c.nodes[lead].committed_commands()
    for i in range(burst):
        assert log.count(f"b{i}") == 1
