"""Reusable commit-history checker for consensus clusters.

Used by the batched-replication and snapshot chaos tests (and available to
any future scenario test): one call validates the full committed history of
a :class:`repro.core.sim.Cluster` against the client-visible contract —

  * agreement      — committed entry sequences are prefix-compatible across
                     all nodes (snapshot-aware: compacted prefixes count);
  * no duplicates  — no command commits twice on any node (EntryId dedup
                     held through every retry / fallback / recovery path);
  * durability     — no acknowledged commit is lost: every entry the
                     Recorder observed as committed appears in the longest
                     committed history;
  * per-client FIFO — for origins the workload submitted sequentially
                     (await-between-submissions or single batched windows),
                     their commands commit in submission (seq) order.
"""
from __future__ import annotations

from typing import Iterable, Optional, Sequence

from repro.core.types import EntryId


def check_commit_history(
    cluster,
    acked: Sequence[EntryId] = (),
    fifo_origins: Iterable[str] = (),
) -> None:
    histories = {
        nid: node.committed_entries() for nid, node in cluster.nodes.items()
    }

    # Agreement: pairwise prefix compatibility by entry identity.
    items = list(histories.items())
    for i in range(len(items)):
        for j in range(i + 1, len(items)):
            (na, a), (nb, b) = items[i], items[j]
            k = min(len(a), len(b))
            ids_a = [e.entry_id for e in a[:k]]
            ids_b = [e.entry_id for e in b[:k]]
            assert ids_a == ids_b, (
                f"committed history divergence between {na} and {nb}:\n"
                f"  {ids_a}\n  {ids_b}"
            )

    # No duplicates on any node.
    for nid, entries in histories.items():
        ids = [e.entry_id for e in entries]
        assert len(ids) == len(set(ids)), f"{nid} double-committed: {ids}"

    longest = max(histories.values(), key=len, default=[])
    longest_ids = {e.entry_id for e in longest}

    # Durability: every acknowledged commit is present.
    for eid in acked:
        t = cluster.metrics.traces.get(eid)
        if t is not None and t.committed:
            assert eid in longest_ids, f"acknowledged commit lost: {eid}"

    # Per-client FIFO for sequential submitters.
    for origin in fifo_origins:
        seqs = [e.entry_id.seq for e in longest if e.entry_id.origin == origin]
        assert seqs == sorted(seqs), (
            f"per-client order violated for {origin}: {seqs}"
        )


def committed_acks(cluster, eids: Sequence[EntryId]) -> list:
    """The subset of ``eids`` the cluster acknowledged (committed per the
    Recorder) — i.e. the ones a client would consider durable."""
    return [
        e
        for e in eids
        if cluster.metrics.traces.get(e) is not None
        and cluster.metrics.traces[e].committed
    ]
