"""Reusable commit-history checker for consensus clusters.

Used by the batched-replication and snapshot chaos tests (and available to
any future scenario test): one call validates the full committed history of
a :class:`repro.core.sim.Cluster` against the client-visible contract —

  * agreement      — committed entry sequences are prefix-compatible across
                     all nodes (snapshot-aware: compacted prefixes count);
  * no duplicates  — no command commits twice on any node (EntryId dedup
                     held through every retry / fallback / recovery path);
  * durability     — no acknowledged commit is lost: every entry the
                     Recorder observed as committed appears in the longest
                     committed history;
  * per-client FIFO — for origins the workload submitted sequentially
                     (await-between-submissions or single batched windows),
                     their commands commit in submission (seq) order.
"""
from __future__ import annotations

from typing import Iterable, Sequence

from repro.core.types import EntryId


def check_commit_history(
    cluster,
    acked: Sequence[EntryId] = (),
    fifo_origins: Iterable[str] = (),
) -> None:
    histories = {
        nid: node.committed_entries() for nid, node in cluster.nodes.items()
    }

    # Agreement: same entry at the same ABSOLUTE index wherever two nodes
    # can both enumerate it (RaftNode.committed_by_index does the
    # alignment). Reduced-state machines (KV) cannot enumerate their
    # compacted prefix, so their history is a tail and indexes must be
    # aligned rather than compared positionally. (With the default
    # LogListMachine every history starts at index 1 and this degenerates
    # to the classic pairwise prefix check.)
    indexed = {
        nid: {x: e.entry_id for x, e in node.committed_by_index().items()}
        for nid, node in cluster.nodes.items()
    }
    items = list(indexed.items())
    for i in range(len(items)):
        for j in range(i + 1, len(items)):
            (na, a), (nb, b) = items[i], items[j]
            common = sorted(set(a) & set(b))
            ids_a = [a[x] for x in common]
            ids_b = [b[x] for x in common]
            assert ids_a == ids_b, (
                f"committed history divergence between {na} and {nb}:\n"
                f"  {ids_a}\n  {ids_b}"
            )

    # No duplicates on any node.
    for nid, entries in histories.items():
        ids = [e.entry_id for e in entries]
        assert len(ids) == len(set(ids)), f"{nid} double-committed: {ids}"

    longest = max(histories.values(), key=len, default=[])
    longest_ids = {e.entry_id for e in longest}

    # Durability: every acknowledged commit is present. Reduced-state
    # machines (KV) cannot enumerate compacted entries, so fall back to the
    # most-applied node's dedup oracle — exact across compaction. (For the
    # default LogListMachine the enumerated history already covers
    # everything, so this is a no-op.)
    most_applied = max(
        cluster.nodes.values(), key=lambda n: n.last_applied, default=None
    )
    for eid in acked:
        t = cluster.metrics.traces.get(eid)
        if t is not None and t.committed:
            assert eid in longest_ids or (
                most_applied is not None and most_applied.has_applied(eid)
            ), f"acknowledged commit lost: {eid}"

    # Per-client FIFO for sequential submitters.
    for origin in fifo_origins:
        seqs = [e.entry_id.seq for e in longest if e.entry_id.origin == origin]
        assert seqs == sorted(seqs), (
            f"per-client order violated for {origin}: {seqs}"
        )


def check_kv_consistency(cluster) -> None:
    """State-machine divergence checker for reduced-state (KV) clusters.

    History-based agreement cannot see past a compacted prefix when the
    machine does not retain entries, so this checks the machine states
    directly: any two nodes that applied the same number of entries must
    hold IDENTICAL machine state (same final KV map, versions included) —
    replicated state machines are deterministic, so equal applied prefixes
    imply equal states. Works for any StateMachine (snapshot() is the
    canonical state encoding)."""
    by_applied = {}
    for nid, node in cluster.nodes.items():
        by_applied.setdefault(node.last_applied, []).append(nid)
    for applied, nids in sorted(by_applied.items()):
        ref = cluster.nodes[nids[0]].state_machine.snapshot()
        for nid in nids[1:]:
            state = cluster.nodes[nid].state_machine.snapshot()
            assert state == ref, (
                f"state divergence at last_applied={applied} between "
                f"{nids[0]} and {nid}:\n  {ref}\n  {state}"
            )


def check_kv_converged(cluster) -> None:
    """Strict end-of-run form: every live node applied the same prefix and
    holds the same final KV map. Call after healing + settling."""
    applied = {nid: n.last_applied for nid, n in cluster.nodes.items() if n.alive}
    assert len(set(applied.values())) == 1, f"nodes not converged: {applied}"
    check_kv_consistency(cluster)


def committed_acks(cluster, eids: Sequence[EntryId]) -> list:
    """The subset of ``eids`` the cluster acknowledged (committed per the
    Recorder) — i.e. the ones a client would consider durable."""
    return [
        e
        for e in eids
        if cluster.metrics.traces.get(e) is not None
        and cluster.metrics.traces[e].committed
    ]
