"""Reusable commit-history checker for consensus clusters.

Used by the batched-replication and snapshot chaos tests (and available to
any future scenario test): one call validates the full committed history of
a :class:`repro.core.sim.Cluster` against the client-visible contract —

  * agreement      — committed entry sequences are prefix-compatible across
                     all nodes (snapshot-aware: compacted prefixes count);
  * no duplicates  — no command commits twice on any node (EntryId dedup
                     held through every retry / fallback / recovery path);
  * durability     — no acknowledged commit is lost: every entry the
                     Recorder observed as committed appears in the longest
                     committed history;
  * per-client FIFO — for origins the workload submitted sequentially
                     (await-between-submissions or single batched windows),
                     their commands commit in submission (seq) order.
"""
from __future__ import annotations

from typing import Iterable, Sequence

from repro.core.raft import is_config_command, parse_config_command
from repro.core.types import EntryId


def check_commit_history(
    cluster,
    acked: Sequence[EntryId] = (),
    fifo_origins: Iterable[str] = (),
) -> None:
    histories = {
        nid: node.committed_entries() for nid, node in cluster.nodes.items()
    }

    # Agreement: same entry at the same ABSOLUTE index wherever two nodes
    # can both enumerate it (RaftNode.committed_by_index does the
    # alignment). Reduced-state machines (KV) cannot enumerate their
    # compacted prefix, so their history is a tail and indexes must be
    # aligned rather than compared positionally. (With the default
    # LogListMachine every history starts at index 1 and this degenerates
    # to the classic pairwise prefix check.)
    indexed = {
        nid: {x: e.entry_id for x, e in node.committed_by_index().items()}
        for nid, node in cluster.nodes.items()
    }
    items = list(indexed.items())
    for i in range(len(items)):
        for j in range(i + 1, len(items)):
            (na, a), (nb, b) = items[i], items[j]
            common = sorted(set(a) & set(b))
            ids_a = [a[x] for x in common]
            ids_b = [b[x] for x in common]
            assert ids_a == ids_b, (
                f"committed history divergence between {na} and {nb}:\n"
                f"  {ids_a}\n  {ids_b}"
            )

    # No duplicates on any node.
    for nid, entries in histories.items():
        ids = [e.entry_id for e in entries]
        assert len(ids) == len(set(ids)), f"{nid} double-committed: {ids}"

    longest = max(histories.values(), key=len, default=[])
    longest_ids = {e.entry_id for e in longest}

    # Durability: every acknowledged commit is present. Reduced-state
    # machines (KV) cannot enumerate compacted entries, so fall back to the
    # most-applied node's dedup oracle — exact across compaction. (For the
    # default LogListMachine the enumerated history already covers
    # everything, so this is a no-op.)
    # Witnesses are excluded: their last_applied tracks commit progress
    # but their dedup filter and machine stay empty by design, so one
    # would answer has_applied() falsely negative.
    most_applied = max(
        (n for n in cluster.nodes.values() if not n.is_witness()),
        key=lambda n: n.last_applied,
        default=None,
    )
    for eid in acked:
        t = cluster.metrics.traces.get(eid)
        if t is not None and t.committed:
            assert eid in longest_ids or (
                most_applied is not None and most_applied.has_applied(eid)
            ), f"acknowledged commit lost: {eid}"

    # Per-client FIFO for sequential submitters.
    for origin in fifo_origins:
        seqs = [e.entry_id.seq for e in longest if e.entry_id.origin == origin]
        assert seqs == sorted(seqs), (
            f"per-client order violated for {origin}: {seqs}"
        )


def check_kv_consistency(cluster) -> None:
    """State-machine divergence checker for reduced-state (KV) clusters.

    History-based agreement cannot see past a compacted prefix when the
    machine does not retain entries, so this checks the machine states
    directly: any two nodes that applied the same number of entries must
    hold IDENTICAL machine state (same final KV map, versions included) —
    replicated state machines are deterministic, so equal applied prefixes
    imply equal states. Works for any StateMachine (snapshot() is the
    canonical state encoding)."""
    by_applied = {}
    for nid, node in cluster.nodes.items():
        if node.is_witness():
            continue  # no state machine: nothing to diverge
        by_applied.setdefault(node.last_applied, []).append(nid)
    for applied, nids in sorted(by_applied.items()):
        ref = cluster.nodes[nids[0]].state_machine.snapshot()
        for nid in nids[1:]:
            state = cluster.nodes[nid].state_machine.snapshot()
            assert state == ref, (
                f"state divergence at last_applied={applied} between "
                f"{nids[0]} and {nid}:\n  {ref}\n  {state}"
            )


def check_kv_converged(cluster) -> None:
    """Strict end-of-run form: every live node applied the same prefix and
    holds the same final KV map. Call after healing + settling."""
    applied = {
        nid: n.last_applied
        for nid, n in cluster.nodes.items()
        if n.alive and not n.is_witness()
    }
    assert len(set(applied.values())) == 1, f"nodes not converged: {applied}"
    check_kv_consistency(cluster)


def _replay_kv(value, parts):
    """Apply one KV write command (already split) to a single key's value,
    mirroring KVMachine semantics."""
    op = parts[0]
    if op == "SET" and len(parts) >= 3:
        return " ".join(parts[2:])
    if op == "DEL" and len(parts) == 2:
        return None
    if op == "CAS" and len(parts) >= 4:
        return " ".join(parts[3:]) if value == parts[2] else value
    return value


def check_read_oracle(cluster, writes) -> int:
    """Linearizability oracle for KV ``GET`` reads issued via
    :meth:`repro.core.sim.Cluster.read`.

    ``writes`` is an iterable of ``(EntryId, command)`` pairs — every KV
    write the workload submitted (SET/DEL/CAS). For each completed read the
    oracle checks, against the cluster's commit record (``metrics.traces``
    carries each write's commit index and first-commit time, which stays
    exact across compaction):

    * freshness — every write to the read's key that was ACKED (observably
      committed) strictly before the read's FRESHNESS FLOOR has
      ``committed_index <= served_index``. For linearizable reads (leader
      path, and replica reads with ``staleness_ms == 0``) the floor is the
      issue time: a linearizable read may never miss a write the client
      could already know about. For bounded-stale replica reads the floor
      is ``issued_at - staleness_ms`` — exactly the contract
      ``max_staleness_ms`` sells: writes acked inside the staleness window
      are allowed to be missing, anything older is not;
    * watermark safety — a replica-served read carries the certified
      watermark it served under (``wm_index``/``wm_time``); every write
      acked strictly before the watermark's certify time must sit at or
      below the watermark index, and the served prefix must cover the
      watermark. A leader that published a watermark above its
      commit coverage at certify time fails here;
    * validity — the returned value equals the replay of ALL committed
      writes to that key up to ``served_index`` in index order (a read must
      return some consistent prefix state, not a value from a parallel
      universe).

    Concurrent write/read pairs (identical timestamps) are exempt from the
    freshness check — either ordering is a valid linearization. Returns the
    number of reads checked (completed KV GETs), so callers can assert the
    oracle actually saw their workload.
    """
    committed = []
    for eid, cmd in writes:
        t = cluster.metrics.traces.get(eid)
        if t is not None and t.committed:
            parts = cmd.split(" ")
            if len(parts) >= 2 and parts[0] in ("SET", "DEL", "CAS"):
                committed.append(
                    (t.committed_index, t.first_commit_at, parts)
                )
    committed.sort(key=lambda x: x[0])
    n_checked = 0
    for rid, rec in cluster.reads.items():
        if not rec.get("ok"):
            continue
        q = rec.get("query")
        if not (isinstance(q, str) and q.startswith("GET ") and len(q.split()) == 2):
            continue
        key = q.split(" ")[1]
        served = rec["served_index"]
        issued = rec["issued_at"]
        # The freshness floor: linearizable reads must see everything acked
        # before issue; bounded-stale replica reads are allowed to miss
        # writes acked inside their staleness window, nothing older.
        floor = issued - float(rec.get("staleness_ms") or 0.0)
        assert served is not None, f"read {rid} completed without served_index"
        wm_time = rec.get("wm_time")
        if wm_time is not None:
            wm_index = rec.get("wm_index")
            assert wm_index is not None and served >= wm_index, (
                f"READ {rid} served index {served} below its own certified "
                f"watermark {wm_index}"
            )
            for idx, t_commit, parts in committed:
                # Watermark safety: the certified claim is "every write
                # committed anywhere strictly before wm_time has index <=
                # wm_index". A violation means the leader published a
                # watermark above its commit coverage at certify time.
                assert not (t_commit < wm_time and idx > wm_index), (
                    f"UNSAFE WATERMARK for read {rid}: ({wm_index}, "
                    f"t={wm_time}) certified, but write {' '.join(parts)} "
                    f"committed at index {idx}, t={t_commit}"
                )
        expected = None
        for idx, t_commit, parts in committed:
            if parts[1] != key:
                continue
            if idx <= served:
                expected = _replay_kv(expected, parts)
            else:
                # Not included in the served prefix: it must not have been
                # acked before the read's freshness floor.
                assert t_commit >= floor, (
                    f"STALE READ {rid}: '{q}' served at index {served} "
                    f"missed write {' '.join(parts)} (index {idx}) acked at "
                    f"t={t_commit} before the read's freshness floor "
                    f"t={floor} (issued {issued}, staleness bound "
                    f"{rec.get('staleness_ms', 0.0)})"
                )
        assert rec["value"] == expected, (
            f"READ VALUE MISMATCH {rid}: '{q}' at served_index {served} "
            f"returned {rec['value']!r}, replay says {expected!r}"
        )
        n_checked += 1
    return n_checked


def check_config_oracle(cluster) -> int:
    """Safety oracle for membership changes. Validates, over the committed
    history and the cluster's live state:

    * joint-consensus discipline — every committed change to the VOTER set
      goes through a joint config first: a committed simple config either
      repeats the previous voter set (learner-only change) or finalizes
      the immediately preceding joint config; a committed joint config's
      C_old equals the previous committed voter set, and no second joint
      config commits before the first finalizes;
    * at most one config change in flight — the current leader's log never
      holds more than one config entry above its commit index, and never a
      new change while its active config is still joint;
    * election safety across C_old/C_new — at most one leader was ever
      elected per term (the Recorder enforces this online and raises at
      violation time; re-checked here so a swallowed exception cannot hide
      it). Two concurrent leaders across the halves of a config change
      would need two leaders in one term or a quorum-less election, both
      of which this catches.

    Returns the number of committed config entries checked so callers can
    assert the oracle saw their churn. Works with any node whose machine
    enumerates history (the default LogListMachine does)."""
    best = max(
        cluster.nodes.values(), key=lambda n: len(n.committed_entries()), default=None
    )
    n_checked = 0
    if best is not None:
        configs = []
        for index, e in sorted(best.committed_by_index().items()):
            if is_config_command(e.command):
                configs.append((index, parse_config_command(e.command)))
        prev_voters = None  # unknown before the first committed config
        prev_joint = None
        for index, cfg in configs:
            n_checked += 1
            if cfg.joint:
                assert prev_joint is None, (
                    f"config at {index}: joint config committed while joint "
                    f"{prev_joint} had not finalized"
                )
                if prev_voters is not None:
                    assert set(cfg.old_voters) == prev_voters, (
                        f"config at {index}: C_old {cfg.old_voters} does not match "
                        f"previous committed voters {sorted(prev_voters)}"
                    )
                prev_joint = cfg
                prev_voters = set(cfg.old_voters)
            else:
                if prev_joint is not None:
                    assert set(cfg.voters) == set(prev_joint.voters), (
                        f"config at {index}: final voters {cfg.voters} do not "
                        f"finalize joint target {prev_joint.voters}"
                    )
                elif prev_voters is not None:
                    assert set(cfg.voters) == prev_voters, (
                        f"config at {index}: voter set changed "
                        f"{sorted(prev_voters)} -> {cfg.voters} without joint "
                        f"consensus"
                    )
                prev_joint = None
                prev_voters = set(cfg.voters)

    lead = cluster.leader()
    if lead is not None:
        node = cluster.nodes[lead]
        uncommitted = sum(
            1
            for s in node.log[max(0, node.commit_index - node.snapshot_last_index):]
            if is_config_command(s.entry.command)
        )
        assert uncommitted <= 1, (
            f"leader {lead} has {uncommitted} config entries in flight"
        )

    for term, leaders in cluster.metrics.leaders.items():
        assert len(leaders) <= 1, (
            f"two leaders elected in term {term}: {sorted(leaders)}"
        )
    return n_checked


def committed_acks(cluster, eids: Sequence[EntryId]) -> list:
    """The subset of ``eids`` the cluster acknowledged (committed per the
    Recorder) — i.e. the ones a client would consider durable."""
    return [
        e
        for e in eids
        if cluster.metrics.traces.get(e) is not None
        and cluster.metrics.traces[e].committed
    ]
