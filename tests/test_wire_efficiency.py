"""Bandwidth-frugal replication: wire_size accounting, delta snapshots,
ack piggybacking, and heartbeat suppression.

Covers the wire-efficiency layer end to end (DESIGN.md section 13): the
``wire_size`` model the size-aware links and the byte Recorder share, the
per-link/per-class byte accounting itself, delta InstallSnapshot streams
(negotiation, install, need_full fallback, LogList full-transfer fallback),
and the ``ack_piggyback`` knob (folded AppendEntries acks with pipeline
slot release, folded FastVotes, suppressed empty heartbeats). Knob-OFF
schedule preservation is proven separately by test_sim_equivalence.py.
"""
import pytest

from commit_history import check_commit_history, check_kv_converged

from repro.core.raft import RaftConfig
from repro.core.sim import Cluster, LinkModel, wire_size
from repro.core.statemachine import KVMachine
from repro.core.types import (
    AppendEntriesArgs,
    AppendEntriesReply,
    Entry,
    EntryId,
    FastPropose,
    FastVote,
    ForwardOperation,
    InstallSnapshotChunk,
    Message,
    ReadReply,
    Slot,
    SlotState,
    snapshot_to_bytes,
)

BASE = wire_size(Message(term=1))  # fixed framing cost every message pays


def _entry(cmd, seq=1, origin="cli"):
    return Entry(term=1, command=cmd, entry_id=EntryId(origin, seq))


def _slot(cmd, seq=1):
    return Slot(_entry(cmd, seq=seq), SlotState.CLASSIC)


# ----------------------------------------------------------- unit: wire_size


def test_wire_size_entry_bearing_messages_scale_with_payload():
    empty = AppendEntriesArgs(term=1, src="a", leader_id="a")
    assert wire_size(empty) == BASE  # heartbeat = pure framing
    one = AppendEntriesArgs(term=1, src="a", leader_id="a",
                            entries=(_slot("X" * 100),))
    two = AppendEntriesArgs(term=1, src="a", leader_id="a",
                            entries=(_slot("X" * 100), _slot("Y" * 50, seq=2)))
    assert wire_size(one) > BASE + 100
    # Adding an entry costs exactly that entry (framing is paid once).
    assert wire_size(two) - wire_size(one) == wire_size(
        AppendEntriesArgs(term=1, src="a", leader_id="a",
                          entries=(_slot("Y" * 50, seq=2),))
    ) - BASE
    # A batched ForwardOperation pays per command, one framing.
    fwd = ForwardOperation(term=1, src="b", command="C" * 30,
                           batch=(("D" * 30, EntryId("b", 2)),))
    solo = ForwardOperation(term=1, src="b", command="C" * 30)
    assert wire_size(fwd) - wire_size(solo) >= 30


def test_wire_size_chunk_pays_for_its_slice_only():
    chunk = InstallSnapshotChunk(term=1, src="a", leader_id="a",
                                 last_index=10, data=b"z" * 300)
    assert wire_size(chunk) == BASE + 300
    # A FastPropose window pays per entry.
    win = FastPropose(term=1, src="a",
                      window=(_entry("p" * 20), _entry("q" * 20, seq=2)))
    assert wire_size(win) > BASE + 40


def test_wire_size_fast_vote_folding_cheaper_than_messages():
    plain = FastVote(term=1, src="b", index=5)
    assert wire_size(plain) == BASE  # knob off: byte stream unchanged
    folded = FastVote(term=1, src="b", index=5,
                      multi_votes=tuple((5 + i, EntryId("c", i)) for i in range(1, 9)))
    # Folding 8 extra votes is charged, but far below 8 extra messages.
    assert BASE < wire_size(folded) < 9 * BASE


def test_wire_size_batched_read_reply_scales():
    solo = ReadReply(term=1, src="a", value="v" * 40)
    batched = ReadReply(term=1, src="a", value="v" * 40,
                        batch=tuple((EntryId("c", i), "w" * 40) for i in range(4)))
    assert wire_size(batched) - wire_size(solo) >= 4 * 40


def test_mtu_packetization_boundaries():
    link = LinkModel(loss=0.1, mtu_bytes=100.0)
    one = link.drop_probability(100)   # exactly one packet
    two = link.drop_probability(101)   # boundary: spills into a 2nd packet
    assert one == pytest.approx(0.1)
    assert two == pytest.approx(1.0 - 0.9 ** 2)
    assert link.drop_probability(1000) == pytest.approx(1.0 - 0.9 ** 10)
    # Bandwidth: serialization time is linear in wire_size.
    bw = LinkModel(bytes_per_ms=50.0)
    assert bw.serialization_cost(500) == pytest.approx(10.0)
    assert bw.serialization_cost(0) == pytest.approx(0.0)


# ----------------------------------------------- recorder byte accounting


def test_recorder_accounts_bytes_per_link_and_class():
    c = Cluster(n=3, protocol="raft", seed=5, loss=0.15, jitter=1.0,
                record_bytes=True)
    assert c.run_until_leader(30_000) is not None
    lead = c.leader()
    eids = c.submit_batch([f"op{i}" for i in range(10)], via=lead)
    assert c.run_until_committed(eids, 60_000)
    c.run(2000)
    rec = c.metrics
    sent, delivered = rec.total_bytes("sent"), rec.total_bytes("delivered")
    dropped = rec.total_bytes("dropped")
    assert sent > 0 and delivered > 0
    # Conservation: anything sent was delivered, dropped, or is still in
    # flight when the run stops (so >=, never <).
    assert sent >= delivered + dropped
    assert dropped > 0  # loss=0.15 must have eaten something
    by_class = rec.bytes_by_class("sent")
    assert "AppendEntriesArgs" in by_class and "AppendEntriesReply" in by_class
    # Per-link totals decompose the grand total.
    assert sum(rec.bytes_by_link("sent").values()) == sent
    bpc = rec.bytes_per_commit("sent")
    assert bpc is not None and bpc > 0


# ------------------------------------------------------------ ack piggyback


def test_ack_piggyback_folds_same_tick_acks_and_releases_slots():
    """A pipelined burst lands several AppendEntries on a follower in the
    same delivery tick; the follower must answer with ONE folded reply whose
    n_acks releases every pipeline slot — commits must not stall."""
    cfg = RaftConfig(ack_piggyback=True, max_inflight_batches=8,
                     max_batch_entries=1)
    c = Cluster(n=3, protocol="raft", seed=7, jitter=0.0, config=cfg)
    assert c.run_until_leader() is not None
    c.run(500)
    lead = c.leader()
    acked = []
    for burst in range(6):
        acked += [c.submit(f"b{burst}_{i}", via=lead) for i in range(8)]
        assert c.run_until_committed(acked[-8:], 60_000)
    assert c.metrics.counters.get("acks_folded", 0) > 0
    c.run(5000)
    check_commit_history(c, acked=acked, fifo_origins=[lead])


def test_ack_piggyback_suppresses_redundant_heartbeats():
    """Steady data traffic means every interval already carried a
    data-bearing round to each follower — the empty heartbeat that would
    follow it is pure overhead and must be suppressed (at most one per
    interval, so liveness and leases are untouched)."""
    cfg = RaftConfig(ack_piggyback=True)
    c = Cluster(n=3, protocol="raft", seed=19, jitter=0.0, config=cfg)
    assert c.run_until_leader() is not None
    c.run(500)
    lead = c.leader()
    acked = []
    for i in range(40):  # one write every ~30ms across many 50ms intervals
        acked.append(c.submit(f"w{i}", via=lead))
        c.run(30)
    assert c.run_until_committed(acked, 60_000)
    assert c.metrics.counters.get("heartbeats_suppressed", 0) > 0
    assert c.leader() == lead  # suppression never cost the leader its term
    c.run(5000)
    check_commit_history(c, acked=acked, fifo_origins=[lead])


def test_ack_piggyback_folds_fast_votes():
    """Several single-slot FastProposes arriving in one tick produce ONE
    FastVote carrying the extra votes in multi_votes; fast commits and the
    tentative-overlay invariants survive."""
    cfg = RaftConfig(ack_piggyback=True)
    c = Cluster(n=5, protocol="fastraft", seed=23, jitter=0.0, config=cfg)
    assert c.run_until_leader() is not None
    c.run(1000)
    lead = c.leader()
    # The fast track is proposer-driven: submit via a FOLLOWER so each op
    # broadcasts a single-slot FastPropose and every other acceptor answers
    # with a FastVote — six of them per burst, same delivery tick.
    proposer = [n for n in c.nodes if n != lead][0]
    acked = []
    for burst in range(5):
        acked += [c.submit(f"f{burst}_{i}", via=proposer) for i in range(6)]
        assert c.run_until_committed(acked[-6:], 60_000)
    assert c.metrics.counters.get("fast_votes_folded", 0) > 0
    c.run(5000)
    check_commit_history(c, acked=acked)


def test_ack_piggyback_schedule_with_knob_off_commits_identically():
    """Same scripted workload, knob on vs off: the committed sequence must
    be identical — piggybacking changes the wire, never the outcome."""

    def commits(cfg):
        c = Cluster(n=3, protocol="raft", seed=31, jitter=0.0, config=cfg)
        assert c.run_until_leader() is not None
        c.run(500)
        lead = c.leader()
        for phase in range(4):
            eids = c.submit_batch([f"p{phase}_{i}" for i in range(5)], via=lead)
            assert c.run_until_committed(eids, 60_000)
        c.run(3000)
        lead = c.leader()
        return [(e.entry_id, e.command) for e in c.nodes[lead].committed_entries()]

    off = commits(RaftConfig())
    on = commits(RaftConfig(ack_piggyback=True))
    assert off == on and len(off) >= 20


def test_ack_piggyback_reduces_total_bytes_under_pipelined_bursts():
    """The regime the knob targets: bursty pipelined traffic, where every
    burst lands several same-tick appends on each follower. Folding turns
    those N replies into one; same commits, fewer bytes."""

    def run(cfg):
        c = Cluster(n=3, protocol="raft", seed=41, jitter=0.0, config=cfg,
                    record_bytes=True)
        assert c.run_until_leader() is not None
        c.run(500)
        lead = c.leader()
        acked = []
        for burst in range(10):
            acked += [c.submit(f"b{burst}_{i}", via=lead) for i in range(8)]
            assert c.run_until_committed(acked[-8:], 60_000)
            c.run(40)
        c.run(2000)
        return len(acked), c.metrics.total_bytes("sent")

    n_off, bytes_off = run(RaftConfig(max_inflight_batches=8, max_batch_entries=1))
    n_on, bytes_on = run(RaftConfig(max_inflight_batches=8, max_batch_entries=1,
                                    ack_piggyback=True))
    assert n_off == n_on
    assert bytes_on < bytes_off, (bytes_on, bytes_off)


# ---------------------------------------------------------- delta snapshots


def _kv_cluster(seed, machine=True, chunk=200):
    cfg = RaftConfig(snapshot_chunk_bytes=chunk, delta_snapshots=True)
    factory = (lambda nid: KVMachine()) if machine else None
    return Cluster(n=3, protocol="raft", seed=seed, jitter=0.0, config=cfg,
                   state_machine_factory=factory)


def _lag_commit_compact(c, victim, lead, cmds):
    """Crash victim, commit cmds, compact the leader — the victim can now
    only recover via InstallSnapshot."""
    c.crash(victim)
    eids = [c.submit(cmd, via=lead) for cmd in cmds]
    assert c.run_until_committed(eids, 120_000)
    c.run(500)
    c.nodes[lead].compact()
    # Drain in-flight pre-compaction appends while the victim is still down:
    # an entry-bearing retransmission delivered right after restart would
    # catch it up via the log and the test would never exercise a snapshot.
    c.run(100)
    return eids


def test_delta_snapshot_negotiated_installed_and_smaller():
    c = _kv_cluster(seed=33)
    assert c.run_until_leader() is not None
    c.run(500)
    lead = c.leader()
    victim = [n for n in c.nodes if n != lead][0]
    # Round 1: the victim recovers via a FULL snapshot (it has no base yet).
    _lag_commit_compact(c, victim, lead,
                        [f"SET k{i % 12} {'x' * 60}{i}" for i in range(24)])
    base_index = c.nodes[lead].snapshot.last_index
    c.restart(victim)
    c.run(30_000)
    assert c.nodes[victim].snapshot_last_index == base_index
    assert c.metrics.counters.get("delta_snapshots_installed", 0) == 0
    # The victim's success replies advertised its new base to the leader.
    assert c.nodes[lead]._peer_snap_index.get(victim) == base_index
    # Round 2: only one hot key churns — the delta is tiny vs. the map.
    _lag_commit_compact(c, victim, lead,
                        [f"SET hot {'y' * 40}{i}" for i in range(20)])
    lead_node = c.nodes[lead]
    full_bytes = len(snapshot_to_bytes(lead_node.snapshot))
    data, neg_base = lead_node._snapshot_stream_for(victim)
    assert neg_base == base_index
    assert len(data) < full_bytes // 2, (len(data), full_bytes)
    c.restart(victim)
    c.run(30_000)
    assert c.metrics.counters.get("delta_snapshots_sent", 0) >= 1
    assert c.metrics.counters.get("delta_snapshots_installed", 0) >= 1
    assert c.metrics.counters.get("delta_snapshot_rejects", 0) == 0
    assert c.nodes[victim].snapshot.delta_base == base_index
    more = [c.submit("SET post done", via=c.leader())]
    assert c.run_until_committed(more, 60_000)
    c.run(10_000)
    check_kv_converged(c)
    assert c.nodes[c.leader()].state_machine.get("hot") is not None


def test_delta_snapshot_stale_base_falls_back_to_full():
    """The follower self-compacted past the base it last advertised: the
    delta stream must be rejected (need_full) and the leader must complete
    the transfer with the full stream — convergence, not a wedge."""
    c = _kv_cluster(seed=37)
    assert c.run_until_leader() is not None
    c.run(500)
    lead = c.leader()
    victim = [n for n in c.nodes if n != lead][0]
    _lag_commit_compact(c, victim, lead,
                        [f"SET k{i % 4} {'x' * 30}{i}" for i in range(16)])
    base_index = c.nodes[lead].snapshot.last_index
    c.restart(victim)
    c.run(30_000)
    assert c.nodes[lead]._peer_snap_index.get(victim) == base_index
    # A few more commits so the victim's own compaction lands ABOVE the
    # base the leader believes it holds.
    eids = [c.submit(f"SET extra{i} v", via=lead) for i in range(4)]
    assert c.run_until_committed(eids, 60_000)
    c.run(2000)
    c.crash(victim)
    c.nodes[victim].compact()  # local compaction invalidates the old base
    assert c.nodes[victim].snapshot_last_index > base_index
    eids = [c.submit(f"SET hot {'y' * 30}{i}", via=lead) for i in range(16)]
    assert c.run_until_committed(eids, 120_000)
    c.run(500)
    c.nodes[lead].compact()
    c.restart(victim)
    c.run(40_000)
    assert c.metrics.counters.get("delta_snapshot_rejects", 0) >= 1
    assert c.metrics.counters.get("delta_snapshot_fallbacks", 0) >= 1
    assert c.metrics.counters.get("snapshots_installed", 0) >= 1
    more = [c.submit("SET post done", via=c.leader())]
    assert c.run_until_committed(more, 60_000)
    c.run(10_000)
    check_kv_converged(c)


def test_delta_snapshots_loglist_machine_falls_back_to_full_transfer():
    """LogListMachine keeps snapshot_delta() = None: with the knob ON the
    leader must quietly stream full snapshots — no deltas, no rejects."""
    c = _kv_cluster(seed=43, machine=False)
    assert c.run_until_leader() is not None
    c.run(500)
    lead = c.leader()
    victim = [n for n in c.nodes if n != lead][0]
    acked = _lag_commit_compact(c, victim, lead,
                                [f"blob-{'x' * 30}-{i}" for i in range(12)])
    c.restart(victim)
    c.run(30_000)
    base_index = c.nodes[lead].snapshot.last_index
    assert c.nodes[lead]._peer_snap_index.get(victim) == base_index
    acked += _lag_commit_compact(c, victim, lead,
                                 [f"more-{'y' * 30}-{i}" for i in range(12)])
    c.restart(victim)
    c.run(30_000)
    assert c.metrics.counters.get("delta_snapshots_sent", 0) == 0
    assert c.metrics.counters.get("delta_snapshot_rejects", 0) == 0
    assert c.metrics.counters.get("snapshots_installed", 0) >= 2
    c.run(5000)
    check_commit_history(c, acked=acked, fifo_origins=[lead])
