"""Reliability-aware scale-out: failure profiles, weighted elections,
apply lag, link multipliers, and co-flaky-aware placement.

The contract under test throughout: every new knob defaults OFF and the
failure schedule is a pure function of (cluster seed, node id) — the SAME
crash/recover times replay no matter which protocol variant runs on top,
so A/B comparisons (weighted vs unweighted elections, witness vs full)
are schedule-for-schedule, never statistical.
"""

import pytest

from repro.core.hierarchy import (
    HierarchicalCluster,
    coflaky_risk,
    plan_coflaky_moves,
)
from repro.core.raft import RaftConfig
from repro.core.sim import Cluster, FailureProfile
from repro.core.statemachine import KVMachine

from commit_history import (
    check_commit_history,
    check_kv_consistency,
    committed_acks,
)


def kv_factory(nid):
    return KVMachine()


# ------------------------------------------------------- failure schedules


def _crashy(n, mtbf=3000.0):
    return {
        f"n{i}": FailureProfile(mtbf_ms=mtbf, mttr_ms=500.0, group=f"g{i % 2}")
        for i in range(n)
    }


def test_failure_schedule_is_deterministic_across_variants():
    """Same seed, same profiles, different protocol stack on top — the
    chaos (crash and recovery counts) must be identical, because the
    schedule draws from per-node RNG streams the protocol never touches."""
    counts = []
    for protocol, weighted in (("raft", False), ("fastraft", False),
                               ("fastraft", True)):
        cfg = RaftConfig(reliability_weighted_election=weighted)
        c = Cluster(n=5, protocol=protocol, seed=301, config=cfg)
        assert c.run_until_leader() is not None
        c.set_failure_profiles(_crashy(5))
        c.run(20_000)
        counts.append(
            (
                c.metrics.counters.get("fp_crashes", 0),
                c.metrics.counters.get("fp_recoveries", 0),
            )
        )
    assert counts[0] == counts[1] == counts[2]
    assert counts[0][0] > 0, "chaos never fired"


def test_neutral_profiles_preserve_schedule_exactly():
    """Profiles with no failures and x1.0 multipliers must be bit-identical
    to no profiles at all: same commits, same message counts, same sim."""

    def run(with_profiles):
        c = Cluster(n=3, protocol="fastraft", seed=302, loss=0.05, jitter=2.0)
        lead = c.run_until_leader()
        if with_profiles:
            c.set_failure_profiles(
                {f"n{i}": FailureProfile() for i in range(3)}
            )
        eids = [c.submit(f"x{i}", via=lead) for i in range(10)]
        c.run(5000)
        committed = [
            (e, c.metrics.traces[e].first_commit_at)
            for e in eids
            if c.metrics.traces[e].committed
        ]
        return committed, dict(c.metrics.counters), c.sim.now

    assert run(False) == run(True)


def test_clear_failure_profiles_stops_the_chaos():
    c = Cluster(n=3, protocol="raft", seed=303)
    assert c.run_until_leader() is not None
    c.set_failure_profiles(_crashy(3, mtbf=1500.0))
    c.run(10_000)
    assert c.metrics.counters.get("fp_crashes", 0) > 0
    c.clear_failure_profiles()
    for nid in list(c.nodes):
        if not c.nodes[nid].alive:
            c.nodes[nid].restart(c.sim.now)
    before = c.metrics.counters.get("fp_crashes", 0)
    c.run(15_000)
    assert c.metrics.counters.get("fp_crashes", 0) == before
    assert all(n.alive for n in c.nodes.values())


def test_commits_survive_crash_recover_chaos():
    c = Cluster(n=5, protocol="fastraft", seed=304,
                state_machine_factory=kv_factory)
    assert c.run_until_leader() is not None
    c.set_failure_profiles(_crashy(5, mtbf=4000.0))
    eids = []
    for i in range(40):
        alive = [n for n in sorted(c.nodes) if c.nodes[n].alive]
        if alive:
            eids.append(c.submit(f"SET c{i} {i}", via=alive[0]))
        c.run(100)
    c.clear_failure_profiles()
    c.heal()
    for nid in list(c.nodes):
        if not c.nodes[nid].alive:
            c.nodes[nid].restart(c.sim.now)
    assert c.run_until_leader(60_000) is not None
    c.run(5000)
    check_commit_history(c, acked=committed_acks(c, eids))
    check_kv_consistency(c)


def test_crash_group_fells_correlated_nodes():
    c = Cluster(n=5, protocol="raft", seed=305)
    assert c.run_until_leader() is not None
    c.set_failure_profiles(_crashy(5, mtbf=0.0))  # groups only, no renewal
    felled = c.crash_group("g0")  # n0, n2, n4
    assert felled == ["n0", "n2", "n4"]
    assert all(not c.nodes[n].alive for n in felled)
    # g1 = {n1, n3} is a minority: nothing can commit until recovery.
    c.run(5000)
    survivor = [n for n in sorted(c.nodes) if c.nodes[n].alive][0]
    eid = c.submit("stalled", via=survivor)
    assert not c.run_until_committed([eid], 5000)
    for nid in felled:
        c.nodes[nid].restart(c.sim.now)
    assert c.run_until_leader(60_000) is not None
    assert c.run_until_committed([eid], 30_000)


# ------------------------------------------------------------- apply lag


def test_apply_lag_defers_state_machine_not_commit():
    cfg = RaftConfig(apply_lag_ms=5000.0)
    c = Cluster(n=3, protocol="raft", seed=306, config=cfg,
                state_machine_factory=kv_factory)
    lead = c.run_until_leader()
    eids = [c.submit(f"SET a{i} {i}", via=lead) for i in range(3)]
    c.run(1500)  # plenty for the commit round, far less than the lag
    node = c.nodes[lead]
    assert node.commit_index >= 3  # consensus reached...
    assert node.last_applied == 0  # ...but the state machine lags behind
    c.run(6000)  # > apply_lag_ms: the deferred queue drains on ticks
    assert c.nodes[lead].last_applied >= 3
    assert c.run_until_committed(eids)
    check_kv_consistency(c)


def test_apply_lag_via_failure_profile_install():
    c = Cluster(n=3, protocol="raft", seed=307)
    lead = c.run_until_leader()
    c.set_failure_profiles({"n1": FailureProfile(apply_lag_ms=600.0)})
    assert c.nodes["n1"].config.apply_lag_ms == 600.0
    eids = [c.submit(f"y{i}", via=lead) for i in range(3)]
    assert c.run_until_committed(eids)
    c.run(2000)
    assert c.nodes["n1"].last_applied >= 3  # slow, but it gets there
    c.clear_failure_profiles()
    assert c.nodes["n1"].config.apply_lag_ms == 0.0


# ------------------------------------------------------- link multipliers


def test_asymmetric_latency_multiplier_slows_only_the_flaky_node():
    """A 20x inbound/outbound latency multiplier on one follower delays
    ITS replication but not the cluster's commits (quorum = the two fast
    members); the laggard's match index trails."""
    base = Cluster(n=3, protocol="raft", seed=308, base_latency=5.0)
    lead = base.run_until_leader()
    base.set_failure_profiles(
        {"n2" if lead != "n2" else "n1": FailureProfile(
            latency_mult=20.0, in_latency_mult=20.0)}
    )
    slow = "n2" if lead != "n2" else "n1"
    eids = [base.submit(f"z{i}", via=lead) for i in range(5)]
    assert base.run_until_committed(eids, 10_000)
    # Commit landed on the fast quorum while the slowed node still waits
    # for its 100ms-per-hop deliveries.
    assert base.nodes[slow].commit_index < base.nodes[lead].commit_index
    base.run(2000)
    assert base.nodes[slow].commit_index >= base.nodes[lead].commit_index - 1


def test_loss_multiplier_composes_with_link_loss():
    """loss_mult scales the link's own loss probability: a lossless link
    stays lossless (0 * k = 0), so neutral profiles cannot add drops."""
    c = Cluster(n=3, protocol="raft", seed=309, loss=0.0)
    lead = c.run_until_leader()
    c.set_failure_profiles(
        {n: FailureProfile(loss_mult=50.0, in_loss_mult=50.0) for n in c.nodes}
    )
    eids = [c.submit(f"l{i}", via=lead) for i in range(5)]
    assert c.run_until_committed(eids, 10_000)
    assert c.metrics.counters.get("dropped", 0) == 0


# ------------------------------------------------- weighted leader election


def test_weighted_election_prefers_reliable_nodes():
    """Aggregated over seeds, reliability-weighted elections produce no
    MORE leadership churn than unweighted under identical heterogeneous
    failure schedules (the flaky half crashes 8x more often)."""
    totals = {False: 0, True: 0}
    for weighted in (False, True):
        for seed in range(310, 330):
            cfg = RaftConfig(
                pre_vote=True, check_quorum=True,
                reliability_weighted_election=weighted,
            )
            c = Cluster(n=5, protocol="raft", seed=seed, config=cfg)
            assert c.run_until_leader() is not None
            profiles = {
                f"n{i}": FailureProfile(
                    mtbf_ms=1600.0 if i >= 2 else 12_800.0, mttr_ms=800.0
                )
                for i in range(5)
            }
            c.set_failure_profiles(profiles)
            c.run(25_000)
            totals[weighted] += c.metrics.counters.get("leader_elected", 0)
    assert totals[True] <= totals[False], totals
    assert totals[False] > 0


def test_weighted_election_off_is_bit_identical_to_baseline():
    """The knob defaults off and must not perturb schedules when off:
    the extra bias code only runs after the same rng.uniform draw."""

    def run(explicit_off):
        cfg = RaftConfig(reliability_weighted_election=False) if explicit_off \
            else RaftConfig()
        c = Cluster(n=3, protocol="fastraft", seed=315, loss=0.02, config=cfg)
        lead = c.run_until_leader()
        eids = [c.submit(f"w{i}", via=lead) for i in range(5)]
        c.run(4000)
        return dict(c.metrics.counters), c.sim.now, c.leader()

    assert run(False) == run(True)


# ---------------------------------------------- co-flaky-aware placement


def test_coflaky_risk_scores_concentration():
    placement = {"pod0": ["a", "b", "c"], "pod1": ["d", "e", "f"]}
    groups = {"a": "rack1", "b": "rack1", "c": "rack2", "d": "rack3"}
    risk = coflaky_risk(placement, groups)
    assert risk["pod0"] == pytest.approx(2 / 3)  # rack1 holds pod0's majority
    assert risk["pod1"] == pytest.approx(1 / 3)
    # Ungrouped hosts contribute no correlated risk.
    assert coflaky_risk({"p": ["x", "y"]}, {})["p"] == 0.0


def _apply_plan(placement, plan):
    place = {p: list(hs) for p, hs in placement.items()}
    for host, src, dst in plan:
        assert host in place[src], (host, src, place)
        place[src].remove(host)
        place[dst].append(host)
    return place


def _worst_group_majority(place, groups):
    worst = False
    for hosts in place.values():
        counts = {}
        for h in hosts:
            g = groups.get(h, "")
            if g:
                counts[g] = counts.get(g, 0) + 1
        if max(counts.values(), default=0) >= len(hosts) // 2 + 1:
            worst = True
    return worst


def test_plan_coflaky_moves_fully_decorrelates_when_feasible():
    """Three rack1 hosts over THREE pods: swaps can spread them one per
    pod, leaving no pod whose quorum dies with a single rack."""
    placement = {
        "pod0": ["a", "b", "c"],   # rack1 x3: one outage = quorum loss
        "pod1": ["d", "e", "f"],
        "pod2": ["g", "h", "i"],
    }
    groups = {"a": "rack1", "b": "rack1", "c": "rack1",
              "d": "rack2", "e": "rack3", "f": "rack4",
              "g": "rack5", "h": "rack6", "i": "rack7"}
    plan = plan_coflaky_moves(placement, groups)
    assert plan, "planner ignored a quorum-in-one-rack pod"
    assert len(plan) % 2 == 0, "swap-based plan must pair its moves"
    place = _apply_plan(placement, plan)
    # Swaps preserve pod sizes: nobody shrank below quorum-able size.
    assert all(len(hs) == 3 for hs in place.values())
    assert not _worst_group_majority(place, groups)
    assert max(coflaky_risk(place, groups).values()) < 1.0


def test_plan_coflaky_moves_best_effort_when_infeasible():
    """Three rack1 hosts over TWO 3-host pods: some pod must keep two of
    them, so the planner reduces the worst risk and stops — it must not
    thrash or empty a pod chasing the unreachable layout."""
    placement = {"pod0": ["a", "b", "c"], "pod1": ["d", "e", "f"]}
    groups = {"a": "rack1", "b": "rack1", "c": "rack1",
              "d": "rack2", "e": "rack3", "f": "rack4"}
    plan = plan_coflaky_moves(placement, groups)
    assert plan
    place = _apply_plan(placement, plan)
    assert all(len(hs) == 3 for hs in place.values())
    before = coflaky_risk(placement, groups)
    after = coflaky_risk(place, groups)
    assert max(after.values()) < max(before.values())


def test_plan_coflaky_moves_noop_when_spread():
    placement = {"pod0": ["a", "b", "c"]}
    groups = {"a": "r1", "b": "r2", "c": "r3"}
    assert plan_coflaky_moves(placement, groups) == []


def test_hierarchy_rebalance_coflaky_live():
    """End-to-end: install group-concentrated profiles, rebalance, and the
    executed pod swaps eliminate every quorum-in-one-group pod."""
    h = HierarchicalCluster(n_pods=3, hosts_per_pod=3, seed=316,
                            state_machine_factory=kv_factory)
    h.bootstrap()
    # pod0's three hosts all share one failure group; the rest are spread.
    p0 = h.pod_ids[0]
    profiles = {}
    for nid in h.placement()[p0]:
        profiles[nid] = FailureProfile(group="rackA")
    for pod in h.pod_ids[1:]:
        for i, nid in enumerate(h.placement()[pod]):
            profiles[nid] = FailureProfile(group=f"{pod}rack{i}")
    h.set_failure_profiles(profiles)
    before = coflaky_risk(h.placement(), h.failure_groups())
    assert before[p0] == 1.0
    moves = h.rebalance_coflaky()
    assert moves, "no rebalancing issued"
    assert h.run_until_moved(600_000), "pod moves did not complete"
    groups = h.failure_groups()
    place = h.placement()
    assert all(len(hs) == 3 for hs in place.values())  # swaps kept sizes
    assert not _worst_group_majority(place, groups)
    assert max(coflaky_risk(place, groups).values()) < 1.0
    # The reshuffled pods still elect and serve.
    for pod in h.pod_ids:
        assert (h.pods[pod].leader() is not None
                or h.pods[pod].run_until_leader(60_000))


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
