"""Election hardening: PreVote, CheckQuorum, and removed-node defense.

The three adversarial-availability mechanisms this suite pins down:

* **PreVote** — a timed-out node probes with a PROSPECTIVE term before
  burning a real one; a partitioned minority node therefore rejoins at the
  cluster's term and causes zero leader changes.
* **CheckQuorum** — a leader that cannot reach a commit quorum for an
  election timeout steps down instead of serving a stale view.
* **Removed-node defense** — vote and pre-vote requests from a candidate
  outside the cluster config are refused by any node with recent leader
  contact, and a REFUSED request never adopts the candidate's term, so a
  rejoining removed node cannot inflate cluster terms or depose a live
  leader (the pre-hardening election storm).
"""
import pytest

from repro.core.raft import RaftConfig, Role
from repro.core.sim import Cluster
from repro.core.types import PreVoteArgs, PreVoteReply


def _cfg(**kw) -> RaftConfig:
    return RaftConfig(**kw)


def _elections(c: Cluster) -> int:
    """Total leaderships ever elected (election-safety ledger)."""
    return sum(len(s) for s in c.metrics.leaders.values())


# ----------------------------------------------------------------- PreVote


def test_prevote_cluster_elects_and_commits():
    c = Cluster(n=5, protocol="fastraft", seed=101, config=_cfg(pre_vote=True))
    assert c.run_until_leader() is not None
    assert c.metrics.counters.get("prevote_rounds", 0) > 0
    eids = c.submit_batch([f"w{i}" for i in range(5)], via=c.leader())
    assert c.run_until_committed(eids)
    c.check_log_consistency()


def test_prevote_probe_burns_no_terms():
    """An isolated minority node probes for multiple election timeouts
    without ever incrementing its own term — the whole point of PreVote."""
    c = Cluster(n=5, protocol="raft", seed=102, config=_cfg(pre_vote=True))
    lead = c.run_until_leader()
    term_before = c.nodes[lead].term
    lone = [n for n in sorted(c.nodes) if n != lead][0]
    c.partition([lone], [n for n in c.nodes if n != lone])
    c.run(5000.0)  # ~16+ election timeouts alone
    assert c.nodes[lone].term == term_before, "probe burned a term"
    assert c.nodes[lone].role is not Role.LEADER
    assert c.nodes[lone].metrics.counters.get("prevote_rounds", 0) > 1
    assert c.nodes[lead].term == term_before


def test_prevote_grant_records_nothing():
    """A pre-vote grant is a statement about the PRESENT, not a promise:
    it must not persist voted_for, bump the term, or reset the election
    timer of the voter."""
    c = Cluster(n=3, protocol="raft", seed=103, config=_cfg(pre_vote=True))
    lead = c.run_until_leader()
    voter_id = [n for n in sorted(c.nodes) if n != lead][0]
    voter = c.nodes[voter_id]
    # Cut the voter off long enough to lose leader-contact recency, so the
    # probe is not refused as disruptive.
    c.partition([voter_id], [n for n in c.nodes if n != voter_id])
    c.run(1000.0)
    term, voted = voter.term, voter.voted_for
    probe = PreVoteArgs(
        term=term + 1,
        src="n9",
        candidate_id="n9",
        last_log_index=10**6,
        last_log_term=10**6,
    )
    # Out-of-config candidates are refused only under recent leader
    # contact, which the partition removed — so log up-to-dateness decides.
    out = voter.on_message(probe, c.sim.now)
    replies = [m for _, m in out if isinstance(m, PreVoteReply)]
    assert replies and replies[0].vote_granted
    assert replies[0].prospective_term == term + 1
    assert replies[0].term == term, "reply must carry the REAL term"
    assert voter.term == term, "pre-vote must not adopt the prospective term"
    assert voter.voted_for == voted, "pre-vote must not persist a vote"


def test_rejoining_follower_zero_disruption_with_prevote():
    """Partition a follower, let it time out for seconds, heal: with
    PreVote it rejoins at the cluster term and the leader never changes."""
    c = Cluster(n=5, protocol="fastraft", seed=104, config=_cfg(pre_vote=True))
    lead = c.run_until_leader()
    lone = [n for n in sorted(c.nodes) if n != lead][0]
    c.partition([lone], [n for n in c.nodes if n != lone])
    c.run(5000.0)
    before = _elections(c)
    c.heal()
    c.run(5000.0)
    assert _elections(c) == before, "rejoin caused a leader change"
    assert c.leader() == lead
    assert c.nodes[lone].term == c.nodes[lead].term
    c.check_log_consistency()


def test_rejoining_follower_disrupts_without_prevote():
    """Control for the test above: same schedule, PreVote off, no lease
    (vote stickiness off) — the classic disruption happens, proving the
    zero-disruption result is PreVote and not an accident of the seed."""
    c = Cluster(n=5, protocol="fastraft", seed=104, config=_cfg(pre_vote=False))
    lead = c.run_until_leader()
    lone = [n for n in sorted(c.nodes) if n != lead][0]
    c.partition([lone], [n for n in c.nodes if n != lone])
    c.run(5000.0)
    assert c.nodes[lone].term > c.nodes[lead].term, "term inflation expected"
    before = _elections(c)
    c.heal()
    c.run(5000.0)
    assert _elections(c) > before, (
        "without PreVote the inflated-term rejoin must force a re-election"
    )
    c.check_log_consistency()


# ------------------------------------------------------------- CheckQuorum


def test_checkquorum_leader_steps_down_within_one_timeout():
    cfg = _cfg(check_quorum=True)
    c = Cluster(n=5, protocol="raft", seed=105, config=cfg)
    lead = c.run_until_leader()
    c.partition([lead], [n for n in c.nodes if n != lead])
    cut_at = c.sim.now
    c.sim.run_until(
        cut_at + 10_000.0, stop=lambda: c.nodes[lead].role is not Role.LEADER
    )
    assert c.nodes[lead].role is not Role.LEADER, (
        "stranded leader never stepped down"
    )
    assert c.metrics.counters.get("checkquorum_stepdowns", 0) >= 1
    took = c.sim.now - cut_at
    # One election_timeout_max after losing the quorum, plus a heartbeat of
    # pre-partition contact slack and tick granularity.
    budget = cfg.election_timeout_max + cfg.heartbeat_interval + 2 * 10.0
    assert took <= budget, f"step-down took {took:.0f}ms (budget {budget:.0f})"


def test_checkquorum_off_stranded_leader_keeps_leading():
    """Control: without CheckQuorum a stranded leader happily stays leader
    in its bubble (the stale-view hazard the knob exists to close)."""
    c = Cluster(n=5, protocol="raft", seed=106, config=_cfg(check_quorum=False))
    lead = c.run_until_leader()
    c.partition([lead], [n for n in c.nodes if n != lead])
    c.run(3000.0)
    assert c.nodes[lead].role is Role.LEADER
    assert c.metrics.counters.get("checkquorum_stepdowns", 0) == 0


def test_checkquorum_majority_side_elects_and_old_leader_yields():
    c = Cluster(
        n=5, protocol="fastraft", seed=107,
        config=_cfg(check_quorum=True, pre_vote=True),
    )
    old = c.run_until_leader()
    rest = [n for n in c.nodes if n != old]
    c.partition([old], rest)
    c.run(5000.0)
    majority_leaders = {
        n for n in rest if c.nodes[n].role is Role.LEADER
    }
    assert majority_leaders, "majority side failed to elect"
    assert c.nodes[old].role is not Role.LEADER
    c.heal()
    c.run(5000.0)
    assert c.leader() is not None
    c.check_log_consistency()


def test_checkquorum_singleton_never_steps_down():
    """A single-voter cluster is always in contact with its own quorum."""
    c = Cluster(n=1, protocol="raft", seed=108, config=_cfg(check_quorum=True))
    assert c.run_until_leader() is not None
    c.run(5000.0)
    assert c.nodes[c.leader()].role is Role.LEADER
    assert c.metrics.counters.get("checkquorum_stepdowns", 0) == 0


# ----------------------------------------------- removed-node vote defense


def _removed_node_rejoin(pre_vote: bool, seed: int) -> Cluster:
    """Partition n-victim away BEFORE removing it, so it never learns the
    config that excludes it — the storm-prone rejoin scenario."""
    c = Cluster(
        n=5, protocol="fastraft", seed=seed, config=_cfg(pre_vote=pre_vote)
    )
    lead = c.run_until_leader()
    victim = [n for n in sorted(c.nodes) if n != lead][-1]
    c.partition([victim], [n for n in c.nodes if n != victim])
    c.run(1000.0)
    c.remove_node(victim)
    assert c.run_until_membership(60_000.0)
    c.run(2000.0)  # victim keeps timing out in its bubble
    return c


@pytest.mark.parametrize("pre_vote", [True, False])
def test_rejoining_removed_node_cannot_disrupt(pre_vote):
    """The tentpole regression: a removed node that still believes it is a
    voter rejoins and campaigns. Voters with recent leader contact refuse
    (vote AND pre-vote), and refusal never adopts the candidate's term —
    zero leader changes, bounded voter terms, regardless of PreVote."""
    c = _removed_node_rejoin(pre_vote, seed=109)
    lead = c.leader()
    assert lead is not None
    victim = [n for n in c.nodes if not c.nodes[n].alive or
              not c.nodes[lead].cluster_config.is_voter(n)]
    c.heal()
    before = _elections(c)
    lead_term = c.nodes[lead].term
    # Revive the removed node so it actually campaigns after the heal.
    for v in victim:
        if not c.nodes[v].alive:
            c.nodes[v].restart(c.sim.now)
    c.run(8000.0)
    assert _elections(c) == before, "removed node forced a re-election"
    assert c.leader() == lead
    voter_terms = {
        n: c.nodes[n].term
        for n in c.nodes
        if c.nodes[lead].cluster_config.is_voter(n)
    }
    assert all(t == lead_term for t in voter_terms.values()), (
        f"voter terms inflated: {voter_terms} (leader at {lead_term})"
    )
    c.check_log_consistency()


def test_removed_node_vote_request_not_adopted():
    """Refusing a disruptive RequestVote must not bump the voter's term
    (the pre-hardening gap: generic max-term adoption ran before the
    disruption check, so a refused vote still inflated terms cluster-wide)."""
    c = Cluster(n=3, protocol="raft", seed=110, config=_cfg())
    lead = c.run_until_leader()
    voter_id = [n for n in sorted(c.nodes) if n != lead][0]
    voter = c.nodes[voter_id]
    term = voter.term
    from repro.core.types import RequestVoteArgs

    out = voter.on_message(
        RequestVoteArgs(
            term=term + 50,
            src="gone",
            candidate_id="gone",  # not in the cluster config
            last_log_index=10**6,
            last_log_term=10**6,
        ),
        c.sim.now,
    )
    grants = [m for _, m in out if getattr(m, "vote_granted", False)]
    assert not grants, "out-of-config candidate must be refused"
    assert voter.term == term, "refused vote request still adopted the term"
