"""Schedule equivalence between the slotted and legacy engines.

The slotted engine (typed event records on the global heap, incremental
quorum/commit/durable-prefix trackers, idle-tick early-outs, watcher-based
stop conditions) must be a pure representation change: for any seed and
any fault schedule, both engines retire the SAME events at the SAME times
and every observable — commit histories, apply order, leader terms,
metrics counters, trace timestamps, final logs — is byte-identical.

Three layers of evidence:

* a deterministic chaos scenario (partitions, crashes, reads, batched
  submits, commit-awaits over already-committed sets — the stop-check
  overshoot corner) for both protocols, flat and hierarchical;
* a hypothesis sweep over random seeds and op schedules;
* the full ``tests/regressions/`` trace corpus replayed under the legacy
  engine (the slotted replay already runs in test_regressions.py).
"""
from __future__ import annotations

import glob
import os

import pytest

from repro.core.fuzzer import replay_trace_file
from repro.core.hierarchy import HierarchicalCluster
from repro.core.raft import RaftConfig
from repro.core.sim import Cluster
from repro.core.statemachine import KVMachine

TRACE_DIR = os.path.join(os.path.dirname(__file__), "regressions")
TRACES = sorted(glob.glob(os.path.join(TRACE_DIR, "*.json")))


def fingerprint(c: Cluster) -> dict:
    """Every engine-observable output of a run, in comparable form."""
    m = c.metrics
    return {
        "now": c.sim.now,
        "events": c.sim.events,
        "counters": dict(m.counters),
        "committed_at": {i: str(e) for i, e in m.committed_at.items()},
        "applied": {nid: list(seq) for nid, seq in m.applied.items()},
        "leaders": {t: sorted(s) for t, s in m.leaders.items()},
        "traces": sorted(
            (str(e), t.submitted_at, t.first_commit_at, t.fallbacks)
            for e, t in m.traces.items()
        ),
        "logs": {
            nid: [
                (str(s.entry.entry_id), s.entry.term, s.state.name)
                for s in node.log
            ]
            for nid, node in c.nodes.items()
        },
        "terms": {nid: node.term for nid, node in c.nodes.items()},
    }


def chaos_scenario(engine: str, protocol: str, seed: int) -> dict:
    c = Cluster(
        n=5, protocol=protocol, seed=seed, loss=0.05, jitter=1.0,
        config=RaftConfig(pre_vote=True, check_quorum=True,
                          lease_duration_ms=120.0, clock_skew_ms=20.0,
                          max_batch_entries=8),
        state_machine_factory=lambda nid: KVMachine(),
        clock_skew_ms=20.0, clock_drift=0.0001, engine=engine,
    )
    c.run_until_leader(30_000)
    nids = list(c.nodes)
    writes = []
    lead = c.leader() or nids[0]
    writes += c.submit_batch([f"a{i}=1" for i in range(6)], via=lead)
    c.run_until_committed(writes, 10_000)
    # Await an all-committed set: the scan engine still ran up to
    # check_every events here, and the watcher engine must too.
    c.run_until_committed(writes, 10_000)
    others = [x for x in nids if x != lead]
    c.partition([lead] + others[:2], others[2:])
    writes += c.submit_batch([f"b{i}=2" for i in range(6)], via=lead)
    c.run_until_committed(writes, 10_000)
    c.heal()
    c.run(500.0)
    c.crash(others[0])
    writes += [c.submit(f"c{i}=3", via=lead) for i in range(3)]
    c.run(800.0)
    c.restart(others[0])
    c.run_until_committed(writes, 20_000)
    rid = c.read("a0", via=c.leader() or lead)
    c.run_until_reads([rid], 10_000)
    c.run(2000.0)
    c.check_log_consistency()
    return fingerprint(c)


@pytest.mark.parametrize("protocol", ["raft", "fastraft"])
@pytest.mark.parametrize("seed", [3, 11])
def test_flat_chaos_equivalence(protocol, seed):
    assert chaos_scenario("slotted", protocol, seed) == chaos_scenario(
        "legacy", protocol, seed
    )


def hierarchy_scenario(engine: str, seed: int) -> dict:
    h = HierarchicalCluster(
        n_pods=3, hosts_per_pod=3, seed=seed,
        local_loss=0.02, global_loss=0.05, jitter=0.5, engine=engine,
    )
    h.bootstrap(30_000)
    eids = [h.propose_global(f"g{i}=1", via_pod="pod0") for i in range(4)]
    h.run_until_globally_committed(eids, 30_000)
    h.run_until_globally_committed(eids, 30_000)  # overshoot corner
    h.partition_pod("pod1")
    h.run(1000.0)
    h.heal_pod("pod1")
    eids += [h.propose_global(f"h{i}=2", via_pod="pod1") for i in range(3)]
    h.run_until_globally_committed(eids, 30_000)
    h.crash_pod_leader("pod2")
    h.run(2000.0)
    h.run_until_delivered(len(eids), 60_000)
    h.check_consistency()
    return {
        "now": h.sim.now,
        "events": h.sim.events,
        "counters": dict(h.global_metrics.counters),
        "traces": sorted(
            (str(e), t.submitted_at, t.first_commit_at)
            for e, t in h.global_metrics.traces.items()
        ),
        "delivered": {pod: list(h.delivered[pod]) for pod in h.pod_ids},
        "pod_now": {pod: h.pods[pod].metrics.counters.get("msgs_out", 0)
                    for pod in h.pod_ids},
    }


def test_hierarchy_equivalence():
    assert hierarchy_scenario("slotted", 5) == hierarchy_scenario("legacy", 5)


@pytest.mark.parametrize(
    "path", TRACES, ids=[os.path.splitext(os.path.basename(p))[0] for p in TRACES]
)
def test_regression_corpus_replays_under_legacy_engine(path):
    report = replay_trace_file(path, engine="legacy")
    assert report.ok, report.error


@pytest.mark.parametrize("seed", [1, 2, 5, 9, 17, 23])
def test_derived_schedules_equivalent(seed):
    """Seed-derived pseudo-random op schedules (no hypothesis needed):
    the same coverage shape as the randomized sweep below, guaranteed to
    run on minimal installs."""
    import random

    rng = random.Random(seed * 9176 + 13)
    kinds = ["submit", "submit", "run", "run", "crash", "restart",
             "partition", "heal"]
    ops = [(rng.choice(kinds), rng.randrange(1, 5)) for _ in range(8)]
    assert apply_ops("slotted", seed, "fastraft", ops) == apply_ops(
        "legacy", seed, "fastraft", ops
    )


# --------------------------------------------------------------------------
# Randomized sweep: hypothesis picks the seed and the op schedule; both
# engines must agree on every example. Guarded (not module-level
# importorskip) so the deterministic tests above always run.
# --------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False


def apply_ops(engine: str, seed: int, protocol: str, ops) -> dict:
    c = Cluster(
        n=5, protocol=protocol, seed=seed, loss=0.03, jitter=1.0,
        config=RaftConfig(pre_vote=True, check_quorum=True),
        engine=engine,
    )
    c.run_until_leader(30_000)
    nids = list(c.nodes)
    writes = []
    for kind, arg in ops:
        if kind == "submit":
            writes.append(c.submit(f"w{len(writes)}", via=nids[arg]))
            c.run_until_committed(writes, 5_000)
        elif kind == "crash":
            if c.nodes[nids[arg]].alive:
                c.crash(nids[arg])
        elif kind == "restart":
            if not c.nodes[nids[arg]].alive:
                c.restart(nids[arg])
        elif kind == "partition":
            side = [x for x in nids if x != nids[arg]]
            c.partition([nids[arg]], side)
        elif kind == "heal":
            c.heal()
        else:
            c.run(arg * 150.0)
    c.heal()
    c.run_until_committed(writes, 20_000)
    c.run(1000.0)
    return fingerprint(c)


if HAVE_HYPOTHESIS:
    op_strategy = st.lists(
        st.one_of(
            st.tuples(st.just("submit"), st.integers(0, 4)),
            st.tuples(st.just("crash"), st.integers(0, 4)),
            st.tuples(st.just("restart"), st.integers(0, 4)),
            st.tuples(st.just("partition"), st.integers(0, 4)),
            st.tuples(st.just("heal"), st.just(0)),
            st.tuples(st.just("run"), st.integers(1, 6)),
        ),
        min_size=3,
        max_size=10,
    )

    @settings(
        max_examples=12,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(
        seed=st.integers(0, 10_000),
        protocol=st.sampled_from(["raft", "fastraft"]),
        ops=op_strategy,
    )
    def test_random_schedules_equivalent(seed, protocol, ops):
        assert apply_ops("slotted", seed, protocol, ops) == apply_ops(
            "legacy", seed, protocol, ops
        )
else:  # keep the skip visible in reports instead of silently absent

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_random_schedules_equivalent():
        pass
