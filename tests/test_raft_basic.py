"""Classic Raft unit tests: mirrors the lab-3 style correctness checks the
paper used (election, replication, failover, persistence, membership)."""
import pytest

from repro.core.sim import Cluster
from repro.core.types import Role


def test_single_leader_elected():
    c = Cluster(n=3, protocol="raft", seed=11)
    lead = c.run_until_leader()
    assert lead is not None
    leaders = [n for n in c.nodes.values() if n.role is Role.LEADER]
    assert len(leaders) == 1


def test_commit_simple():
    c = Cluster(n=3, protocol="raft", seed=12)
    lead = c.run_until_leader()
    eids = [c.submit(f"cmd{i}", via=lead) for i in range(10)]
    assert c.run_until_committed(eids)
    c.run(1000)  # let heartbeats propagate commit
    for n in c.nodes.values():
        assert n.committed_commands() == [f"cmd{i}" for i in range(10)]
    c.check_log_consistency()


def test_commit_via_follower_forwarding():
    c = Cluster(n=3, protocol="raft", seed=13)
    lead = c.run_until_leader()
    follower = [n for n in c.nodes if n != lead][0]
    eid = c.submit("fwd-cmd", via=follower)
    assert c.run_until_committed([eid])
    assert c.metrics.traces[eid].mode == "classic"


def test_leader_failover():
    c = Cluster(n=5, protocol="raft", seed=14)
    lead = c.run_until_leader()
    e1 = c.submit("before-crash", via=lead)
    assert c.run_until_committed([e1])
    c.crash(lead)
    new_lead = None
    for _ in range(10):
        c.run(2000)
        new_lead = c.leader()
        if new_lead is not None and new_lead != lead:
            break
    assert new_lead is not None and new_lead != lead
    e2 = c.submit("after-crash", via=new_lead)
    assert c.run_until_committed([e2])
    c.check_log_consistency()
    # Committed entry survived the failover.
    assert "before-crash" in c.nodes[new_lead].committed_commands()


def test_restart_preserves_log():
    c = Cluster(n=3, protocol="raft", seed=15)
    lead = c.run_until_leader()
    eids = [c.submit(f"x{i}", via=lead) for i in range(5)]
    assert c.run_until_committed(eids)
    victim = [n for n in c.nodes if n != lead][0]
    pre_log = [s.entry.entry_id for s in c.nodes[victim].log]
    c.crash(victim)
    c.run(1000)
    c.restart(victim)
    c.run(3000)
    post_log = [s.entry.entry_id for s in c.nodes[victim].log]
    assert post_log[: len(pre_log)] == pre_log
    assert c.nodes[victim].commit_index >= 5
    c.check_log_consistency()


def test_minority_partition_cannot_commit():
    c = Cluster(n=5, protocol="raft", seed=16)
    lead = c.run_until_leader()
    minority = [lead] + [n for n in c.nodes if n != lead][:1]
    majority = [n for n in c.nodes if n not in minority]
    c.partition(minority, majority)
    eid = c.submit("stuck", via=lead)
    c.run(3000)
    t = c.metrics.traces.get(eid)
    assert t is None or not t.committed, "entry committed without a quorum"
    # Majority side elects a fresh leader and commits.
    new_lead = c.leader()
    assert new_lead in majority
    e2 = c.submit("moves-on", via=new_lead)
    assert c.run_until_committed([e2])
    c.heal()
    c.run(3000)
    c.check_log_consistency()


def test_membership_add_node():
    c = Cluster(n=3, protocol="raft", seed=17)
    lead = c.run_until_leader()
    eids = [c.submit(f"m{i}", via=lead) for i in range(3)]
    assert c.run_until_committed(eids)
    c.add_node("n3")
    c.run(5000)
    assert "n3" in c.nodes[lead].members
    assert c.nodes["n3"].commit_index >= 3, "new node not backfilled"
    c.check_log_consistency()


def test_lossy_network_still_commits():
    c = Cluster(n=3, protocol="raft", seed=18, loss=0.10, jitter=2.0)
    lead = c.run_until_leader(20_000)
    assert lead is not None
    eids = [c.submit(f"l{i}", via=lead) for i in range(5)]
    assert c.run_until_committed(eids, 30_000)
    c.check_log_consistency()
