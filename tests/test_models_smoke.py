"""Per-architecture smoke tests on REDUCED configs (assignment requirement):
instantiate, one forward + train-grad step on CPU, assert output shapes and
no NaNs; plus prefill/decode-parity for the serving path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import zoo

# Compiling forward+grad for every arch takes minutes of XLA time; the
# per-PR CI lane skips these and the full suite on main runs them.
pytestmark = pytest.mark.slow

ARCHS = registry.list_archs()


def _batch(cfg, B=2, T=16, seed=0):
    rng = np.random.RandomState(seed)
    batch = {}
    if cfg.frontend is not None:
        batch["embeddings"] = jnp.asarray(
            rng.randn(B, T, cfg.d_model), jnp.float32
        )
    else:
        batch["tokens"] = jnp.asarray(
            rng.randint(0, cfg.vocab_size, (B, T)), jnp.int32
        )
    batch["labels"] = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, T)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = registry.get(arch, reduced=True)
    model = zoo.build(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 16
    batch = _batch(cfg, B, T)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), "non-finite logits"
    for k, v in aux.items():
        assert bool(jnp.isfinite(v)), f"non-finite aux {k}"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grads_finite(arch):
    cfg = registry.get(arch, reduced=True)
    model = zoo.build(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg, 2, 16, seed=1)

    def loss_fn(p):
        total, metrics = model.loss(p, batch)
        return total, metrics

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert bool(jnp.isfinite(loss)), f"loss {loss}"
    # Loss near ln(vocab) at init (uniform predictions).
    assert float(metrics["ce"]) < np.log(cfg.vocab_size) + 2.0
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert bool(jnp.all(jnp.isfinite(g))), "non-finite grad"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the parallel forward logits —
    pins KV-cache indexing, positions, and recurrent state handoff."""
    cfg = registry.get(arch, reduced=True)
    model = zoo.build(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(2))
    B, T = 2, 8
    batch = _batch(cfg, B, T, seed=2)

    full_logits, _ = model.forward(params, batch)

    # Prefill on the first T//2, then decode the rest token by token.
    half = T // 2
    if cfg.frontend is not None:
        prompt = {"embeddings": batch["embeddings"][:, :half]}
        steps = [
            {"embeddings": batch["embeddings"][:, t : t + 1]} for t in range(half, T)
        ]
    else:
        prompt = {"tokens": batch["tokens"][:, :half]}
        steps = [{"tokens": batch["tokens"][:, t : t + 1]} for t in range(half, T)]

    logits, cache = model.prefill(params, prompt, max_len=T)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, half - 1]), rtol=2e-4, atol=2e-4
    )
    for i, step in enumerate(steps[:-1]):
        logits, cache = model.decode_step(params, cache, step)
        np.testing.assert_allclose(
            np.asarray(logits),
            np.asarray(full_logits[:, half + i]),
            rtol=2e-4,
            atol=2e-4,
        )


def test_param_counts_full_configs():
    """Analytic parameter counts for the FULL configs land in the advertised
    ballpark (order-of-magnitude pin against the model-card sizes)."""
    expect = {
        "llama4-scout-17b-a16e": (80e9, 120e9),   # total (16 experts)
        "granite-moe-1b-a400m": (0.7e9, 2.0e9),
        "qwen1.5-4b": (2.5e9, 5e9),
        "qwen3-1.7b": (1.2e9, 2.5e9),
        "phi3-medium-14b": (10e9, 18e9),
        "qwen3-4b": (3e9, 6e9),
        "musicgen-large": (2.0e9, 5e9),   # backbone only (no cross-attn/text enc)
        "internvl2-2b": (1.2e9, 3e9),
        "xlstm-1.3b": (0.8e9, 2.5e9),
        "jamba-v0.1-52b": (40e9, 65e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = registry.get(arch)
        n = cfg.param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]B"


def test_moe_active_params_smaller_than_total():
    for arch in ("llama4-scout-17b-a16e", "jamba-v0.1-52b", "granite-moe-1b-a400m"):
        cfg = registry.get(arch)
        assert cfg.active_param_count() < cfg.param_count()
    # llama4-scout: ~17B active.
    a = registry.get("llama4-scout-17b-a16e").active_param_count()
    assert 10e9 < a < 25e9, a
