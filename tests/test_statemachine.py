"""Pluggable state machines + chunked, resumable snapshot streaming.

Covers the refactor end to end: LogListMachine equivalence with the
pre-refactor (entry-carrying snapshot) semantics, KVMachine semantics and
reduced-state snapshots (O(live keys), not O(history)), the DedupTable
exactly-once filter, chunked InstallSnapshot under loss with offset-based
resume, and the two Cluster fixes that ride along (per-node replacement
seeds, joiner persistence wiring).
"""
import pytest

from commit_history import (
    check_commit_history,
    check_kv_consistency,
    check_kv_converged,
)

from repro.checkpoint.manager import SnapshotStore
from repro.core.raft import RaftConfig
from repro.core.sim import Cluster
from repro.core.statemachine import DedupTable, KVMachine, LogListMachine
from repro.core.types import Entry, EntryId, snapshot_to_bytes


def _entry(cmd, origin="cli", seq=1, term=1):
    return Entry(term=term, command=cmd, entry_id=EntryId(origin, seq))


# ------------------------------------------------------------- unit: machines


def test_kv_machine_semantics():
    m = KVMachine()
    assert m.apply(1, _entry("SET a hello")) == 1
    assert m.apply(2, _entry("SET a world", seq=2)) == 2  # version bumps
    assert m.get("a") == "world" and m.version("a") == 2
    assert m.apply(3, _entry("GET a", seq=3)) == "world"
    assert m.apply(4, _entry("CAS a world w2", seq=4)) is True
    assert m.apply(5, _entry("CAS a stale w3", seq=5)) is False
    assert m.get("a") == "w2" and m.version("a") == 3
    assert m.apply(6, _entry("SET b x y z", seq=6)) == 1
    assert m.get("b") == "x y z"  # values may contain spaces
    assert m.apply(7, _entry("DEL b", seq=7)) is True
    assert m.get("b") is None
    # Infrastructure commands are no-ops, not crashes.
    assert m.apply(8, _entry("__config__:n0,n1", seq=8)) is None
    assert m.apply(9, _entry("__global__:1:ckpt-0", seq=9)) is None
    assert m.apply(10, _entry(("not", "a", "string"), seq=10)) is None


def test_kv_machine_snapshot_roundtrip_and_size():
    m = KVMachine()
    for i in range(50):
        m.apply(i + 1, _entry(f"SET k{i % 4} value{i}", seq=i + 1))
    state = m.snapshot()
    assert set(state) == {"k0", "k1", "k2", "k3"}
    m2 = KVMachine()
    m2.restore(state)
    assert m2.snapshot() == state
    assert m2.size_bytes() == m.size_bytes()
    # Later writes must not mutate the already-taken snapshot.
    m.apply(51, _entry("SET k0 mutated", seq=51))
    assert state["k0"][0] != "mutated"
    m.restore(None)
    assert m.snapshot() == {} and m.size_bytes() == 0


def test_loglist_machine_retains_history():
    m = LogListMachine()
    for i in range(5):
        m.apply(i + 1, _entry(f"c{i}", seq=i + 1))
    ents = m.applied_entries()
    assert [e.command for e in ents] == [f"c{i}" for i in range(5)]
    m2 = LogListMachine()
    m2.restore(m.snapshot())
    assert [e.command for e in m2.applied_entries()] == [f"c{i}" for i in range(5)]
    assert [e.entry_id for e in m2.applied_entries()] == [
        e.entry_id for e in ents
    ]


def test_dedup_table_exact_with_out_of_order_applies():
    t = DedupTable()
    t.add(EntryId("a", 1))
    t.add(EntryId("a", 4))  # seqs 2,3 become holes
    assert t.contains(EntryId("a", 1)) and t.contains(EntryId("a", 4))
    assert not t.contains(EntryId("a", 2)) and not t.contains(EntryId("a", 3))
    assert not t.contains(EntryId("a", 5)) and not t.contains(EntryId("b", 1))
    t.add(EntryId("a", 3))  # hole fills later (out-of-order commit)
    assert t.contains(EntryId("a", 3)) and not t.contains(EntryId("a", 2))
    # Roundtrip through the snapshot wire format.
    t2 = DedupTable.from_state(t.state())
    for origin, seq, want in [("a", 1, True), ("a", 2, False), ("a", 3, True),
                              ("a", 4, True), ("a", 5, False), ("b", 1, False)]:
        assert t2.contains(EntryId(origin, seq)) is want
    assert t2.max_seq("a") == 4 and t2.max_seq("b") == 0


# ------------------------------------------------ equivalence with seed path


def _scripted_schedule(cfg, protocol="fastraft", seed=123):
    """Deterministic chaos workload (loss=0, jitter=0 => the sim RNG is
    never consumed, so runs are comparable across configs): awaited batches
    pin the commit order while a follower crashes, lags, and catches up —
    through log replay or InstallSnapshot depending on cfg."""
    c = Cluster(n=3, protocol=protocol, seed=seed, loss=0.0, jitter=0.0,
                config=cfg)
    assert c.run_until_leader() is not None
    c.run(500)
    lead = c.leader()
    victim = [n for n in c.nodes if n != lead][0]
    proposers = [n for n in c.nodes if n != victim]
    acked = []
    for phase in range(4):
        via = proposers[phase % len(proposers)]
        eids = c.submit_batch([f"p{phase}_{i}" for i in range(6)], via=via)
        assert c.run_until_committed(eids, 60_000)
        acked += eids
        if phase == 0:
            c.crash(victim)
        elif phase == 2:
            c.restart(victim)
    c.run(15_000)
    check_commit_history(c, acked=acked)
    lead = c.leader()
    return [(e.entry_id, e.command) for e in c.nodes[lead].committed_entries()]


def test_loglist_schedule_identical_to_seed_path():
    """The seed path is default config: no compaction, snapshots carry the
    whole history. Turning on compaction + chunked InstallSnapshot must not
    change the committed schedule by a single entry."""
    baseline = _scripted_schedule(RaftConfig())
    compacted = _scripted_schedule(
        RaftConfig(snapshot_threshold=4, snapshot_chunk_bytes=120)
    )
    assert baseline == compacted
    assert len(baseline) >= 24


def test_loglist_schedule_deterministic_across_runs():
    cfg = RaftConfig(snapshot_threshold=4)
    assert _scripted_schedule(cfg) == _scripted_schedule(cfg)


# --------------------------------------------------------------- KV clusters


def test_kv_cluster_compaction_and_store_replacement(tmp_path):
    """A KV cluster compacts to reduced state, persists it, and a full host
    replacement restores the KV map from the store — no entry replay."""
    store = SnapshotStore(str(tmp_path))
    cfg = RaftConfig(snapshot_threshold=6)
    c = Cluster(n=3, protocol="fastraft", seed=21, config=cfg,
                snapshot_store=store,
                state_machine_factory=lambda nid: KVMachine())
    assert c.run_until_leader() is not None
    c.run(500)
    lead = c.leader()
    ops = [f"SET k{i % 4} v{i}" for i in range(14)] + ["DEL k3", "CAS k0 v12 final"]
    acked = []
    for op in ops:
        eids = [c.submit(op, via=lead)]
        assert c.run_until_committed(eids, 60_000)
        acked += eids
    c.run(3000)
    victim = [n for n in c.nodes if n != c.leader()][0]
    assert store.latest_index(victim) >= 6, "KV snapshot never persisted"
    c.crash(victim)
    c.run(1000)
    c.restart_from_store(victim)
    node = c.nodes[victim]
    assert isinstance(node.state_machine, KVMachine)
    assert node.state_machine.get("k0") is not None  # state restored from disk
    more = [c.submit("SET post done", via=c.leader())]
    assert c.run_until_committed(more, 60_000)
    c.run(10_000)
    check_kv_converged(c)
    m = c.nodes[c.leader()].state_machine
    assert m.get("k0") == "final" and m.get("k3") is None
    assert m.get("post") == "done"


def test_kv_snapshot_is_o_live_keys_not_o_history():
    """Same workload, two machines: the KV snapshot stays flat as history
    grows while the LogList snapshot grows linearly."""

    def final_snapshot_bytes(factory):
        c = Cluster(n=3, protocol="raft", seed=17,
                    state_machine_factory=factory)
        assert c.run_until_leader() is not None
        c.run(500)
        lead = c.leader()
        for b in range(10):
            eids = c.submit_batch(
                [f"SET k{i % 5} value_{b}_{i}" for i in range(20)], via=lead
            )
            assert c.run_until_committed(eids, 60_000)
        c.run(2000)
        node = c.nodes[lead]
        node.compact()
        assert node.snapshot is not None and node.snapshot.last_index >= 200
        return node.snapshot.size_bytes()

    kv_bytes = final_snapshot_bytes(lambda nid: KVMachine())
    loglist_bytes = final_snapshot_bytes(None)
    # 200 updates over 5 live keys: the reduced snapshot should be over an
    # order of magnitude smaller than the history-carrying one.
    assert kv_bytes * 10 < loglist_bytes, (kv_bytes, loglist_bytes)


# ------------------------------------------------- chunked snapshot transfer


def test_chunked_catchup_under_loss_resumes_not_restarts():
    """Acceptance scenario: a follower partitioned past the snapshot horizon
    recovers via >= 3 chunks at loss=0.2; drops mid-transfer resume from the
    follower's cursor (retransmits), never restart the stream."""
    cfg = RaftConfig(snapshot_chunk_bytes=300)
    c = Cluster(n=3, protocol="raft", seed=9, loss=0.2, jitter=1.0, config=cfg)
    assert c.run_until_leader(30_000) is not None
    c.run(1000)
    lead = c.leader()
    victim = [n for n in c.nodes if n != lead][0]
    c.partition([victim], [n for n in c.nodes if n != victim])
    eids = [c.submit("payload-" + "q" * 40 + f"-{i}", via=lead) for i in range(20)]
    assert c.run_until_committed(eids, 120_000)
    c.nodes[lead].compact()
    snap = c.nodes[lead].snapshot
    assert snap is not None
    assert snap.last_index > c.nodes[victim].last_log_index()
    chunks_needed = -(-len(snapshot_to_bytes(snap)) // 300)
    assert chunks_needed >= 3
    c.heal()
    c.run(60_000)
    assert c.nodes[victim].commit_index >= 20
    sent = c.metrics.counters.get("snapshot_chunks_sent", 0)
    assert sent >= chunks_needed
    # Loss forced retransmissions, yet the transfer never started over.
    assert sent > chunks_needed
    assert c.metrics.counters.get("snapshot_transfer_restarts", 0) == 0
    assert c.metrics.counters.get("snapshots_installed", 0) >= 1
    check_commit_history(c, acked=eids, fifo_origins=[lead])


def test_chunked_transfer_survives_mid_transfer_blackout():
    """Deterministic resume check: blackhole the follower mid-transfer; the
    partial buffer must freeze (not reset) and the transfer must complete
    from the same offset after healing."""
    cfg = RaftConfig(snapshot_chunk_bytes=150)
    c = Cluster(n=3, protocol="raft", seed=11, config=cfg)
    assert c.run_until_leader() is not None
    c.run(500)
    lead = c.leader()
    victim = [n for n in c.nodes if n != lead][0]
    rest = [n for n in c.nodes if n != victim]
    # Crash (not partition) for the lag phase: a partitioned victim's term
    # would inflate past the leader's and force a re-election on heal.
    c.crash(victim)
    eids = [c.submit("blob-" + "x" * 50 + f"-{i}", via=lead) for i in range(30)]
    assert c.run_until_committed(eids, 120_000)
    c.nodes[lead].compact()
    c.restart(victim)
    # Step until the victim holds a partial (not complete) buffer. Steps
    # must exceed the 10ms tick interval: run_until only advances sim time
    # through events, so a sub-tick step can fail to reach the next event.
    node = c.nodes[victim]
    total = len(snapshot_to_bytes(c.nodes[lead].snapshot))
    for _ in range(1000):
        c.run(15)
        if node._incoming_snap is not None and 0 < len(node._incoming_snap["data"]) < total:
            break
    assert node._incoming_snap is not None, "transfer never started"
    partial = len(node._incoming_snap["data"])
    assert 0 < partial < total
    # Blackout shorter than the victim's election timeout: the transfer
    # stalls but nobody's term moves.
    c.partition([victim], rest)
    c.run(100)
    assert len(node._incoming_snap["data"]) == partial  # frozen, not reset
    c.heal()
    c.run(60_000)
    assert node.commit_index >= 30
    assert c.metrics.counters.get("snapshot_transfer_restarts", 0) == 0
    check_commit_history(c, acked=eids, fifo_origins=[lead])


# ------------------------------------------------------- cluster fix rides


def test_restart_from_store_derives_fresh_per_replacement_seeds(tmp_path):
    """Replacing the same host twice (or two hosts at once) must not replay
    one RNG stream: identical election timeouts can livelock elections."""
    store = SnapshotStore(str(tmp_path))
    cfg = RaftConfig(snapshot_threshold=4)
    c = Cluster(n=3, protocol="raft", seed=13, config=cfg, snapshot_store=store)
    assert c.run_until_leader() is not None
    c.run(500)
    lead = c.leader()
    eids = [c.submit(f"c{i}", via=lead) for i in range(10)]
    assert c.run_until_committed(eids, 60_000)
    c.run(2000)
    victim = [n for n in c.nodes if n != c.leader()][0]

    draws = []
    for _ in range(2):
        c.crash(victim)
        c.run(200)
        c.restart_from_store(victim)
        draws.append(c.nodes[victim].election_deadline - c.sim.now)
        c.run(1000)
    assert draws[0] != draws[1], "replacement RNG stream replayed"
    more = [c.submit(f"d{i}", via=c.leader()) for i in range(3)]
    assert c.run_until_committed(more, 60_000)
    check_commit_history(c, acked=eids + more)


def test_add_node_wires_persistence_sinks(tmp_path):
    """A joiner on a store-backed cluster must persist snapshots and hard
    state exactly like founding members."""
    store = SnapshotStore(str(tmp_path))
    cfg = RaftConfig(snapshot_threshold=4)
    c = Cluster(n=3, protocol="raft", seed=15, config=cfg, snapshot_store=store)
    assert c.run_until_leader() is not None
    c.run(500)
    c.add_node("n3")
    c.run(5000)
    eids = [c.submit(f"j{i}", via=c.leader()) for i in range(12)]
    assert c.run_until_committed(eids, 60_000)
    c.run(10_000)
    joiner = c.nodes["n3"]
    assert joiner.snapshot_sink is not None and joiner.hard_state_sink is not None
    assert store.latest_index("n3") >= 4, "joiner never persisted a snapshot"
    assert store.load_hard_state("n3") is not None
    check_commit_history(c, acked=eids)


# --------------------------------------------------- hypothesis chaos (slow)

try:  # the rest of this module must not skip when hypothesis is absent
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    chaos_ops = st.lists(
        st.one_of(
            st.tuples(st.just("submit"), st.integers(0, 5)),
            st.tuples(st.just("crash"), st.just(0)),
            st.tuples(st.just("restart"), st.just(0)),
            st.tuples(st.just("run"), st.integers(50, 600)),
        ),
        min_size=4,
        max_size=16,
    )


def _chaos_schedule(cfg, ops, seed, factory=None):
    """Awaited-submission chaos (loss=0, jitter=0): the victim follower
    crashes/restarts while non-victims submit; commit order is pinned by
    awaiting, so schedules are comparable across snapshot configs."""
    c = Cluster(n=3, protocol="fastraft", seed=seed, loss=0.0, jitter=0.0,
                config=cfg, state_machine_factory=factory)
    assert c.run_until_leader(30_000) is not None
    c.run(500)
    lead = c.leader()
    victim = [n for n in c.nodes if n != lead][0]
    proposers = [n for n in c.nodes if n != victim]
    down = False
    acked = []
    k = 0
    for op, arg in ops:
        if op == "submit":
            via = proposers[arg % len(proposers)]
            cmds = [f"SET key{(k + i) % 5} val{k + i}" for i in range(3)]
            eids = c.submit_batch(cmds, via=via)
            assert c.run_until_committed(eids, 60_000)
            acked += eids
            k += 3
        elif op == "crash" and not down:
            c.crash(victim)
            down = True
        elif op == "restart" and down:
            c.restart(victim)
            down = False
        elif op == "run":
            c.run(float(arg))
    if down:
        c.restart(victim)
    c.run(20_000)
    # Flush: committing one fresh entry forces the (possibly new) leader to
    # advance commit over prior-term entries — without a leader no-op,
    # entries acked under a crashed leader stay uncommitted on its
    # successor until the next command commits (standard Raft gap).
    eids = c.submit_batch(["SET flush 1"], via=c.leader() or proposers[0])
    assert c.run_until_committed(eids, 60_000)
    acked += eids
    c.run(10_000)
    check_commit_history(c, acked=acked)
    check_kv_consistency(c)
    lead = c.leader()
    return [(e.entry_id, e.command) for e in c.nodes[lead].committed_entries()]


if HAVE_HYPOTHESIS:

    @pytest.mark.slow  # randomized schedules
    @settings(
        max_examples=20, deadline=None, suppress_health_check=list(HealthCheck)
    )
    @given(ops=chaos_ops, seed=st.integers(0, 2**16))
    def test_chaos_loglist_equivalence_and_kv_divergence(ops, seed):
        """Hypothesis drives crash/restart chaos: (a) a LogListMachine
        cluster with compaction + chunked snapshots commits the IDENTICAL
        schedule as the seed path (default config), and (b) the same chaos
        on a KVMachine cluster leaves every node with the same KV map
        (divergence checker)."""
        baseline = _chaos_schedule(RaftConfig(), ops, seed)
        compacted = _chaos_schedule(
            RaftConfig(snapshot_threshold=4, snapshot_chunk_bytes=150), ops, seed
        )
        assert baseline == compacted
        _chaos_schedule(
            RaftConfig(snapshot_threshold=4, snapshot_chunk_bytes=150),
            ops,
            seed,
            factory=lambda nid: KVMachine(),
        )
