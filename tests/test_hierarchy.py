"""Hierarchical consensus: per-pod groups + global tier of pod leaders."""
import pytest

from repro.core.hierarchy import HierarchicalCluster


def test_bootstrap_two_pods():
    h = HierarchicalCluster(n_pods=2, hosts_per_pod=3, seed=31)
    h.bootstrap()
    assert h.global_leader() is not None
    for pod in h.pod_ids:
        assert h.pods[pod].leader() is not None


def test_global_commit_and_dissemination():
    h = HierarchicalCluster(n_pods=2, hosts_per_pod=3, seed=32)
    h.bootstrap()
    eids = [h.propose_global(f"ckpt-{i}") for i in range(4)]
    assert h.run_until_globally_committed(eids)
    assert h.run_until_delivered(4)
    h.check_consistency()
    # Every pod saw the same global sequence.
    assert h.delivered["pod0"] == h.delivered["pod1"]


def test_three_pod_tier_survives_one_pod_dark():
    h = HierarchicalCluster(n_pods=3, hosts_per_pod=3, seed=33)
    h.bootstrap()
    dark = [p for p in h.pod_ids if p != h.global_leader()][0]
    h.partition_pod(dark)
    e = h.propose_global("while-dark", via_pod=h.global_leader())
    assert h.run_until_globally_committed([e])
    h.heal_pod(dark)
    h.run(20_000)
    h.check_consistency()


def test_pod_leader_crash_global_member_migrates():
    """Pod-leader churn must be invisible to global membership: the member
    (pod identity) stays; only its physical host changes."""
    h = HierarchicalCluster(n_pods=2, hosts_per_pod=3, seed=34)
    h.bootstrap()
    e1 = h.propose_global("before")
    assert h.run_until_globally_committed([e1])
    victim_pod = h.pod_ids[0]
    h.crash_pod_leader(victim_pod)
    h.run(5000)  # local re-election
    assert h.pods[victim_pod].leader() is not None
    e2 = h.propose_global("after", via_pod=h.pod_ids[1])
    assert h.run_until_globally_committed([e2], 60_000)
    h.check_consistency()
    # Global membership never changed.
    for n in h.global_nodes.values():
        assert sorted(n.members) == sorted(h.pod_ids)


def test_global_tier_lossy_links():
    h = HierarchicalCluster(n_pods=3, hosts_per_pod=3, seed=35, global_loss=0.05)
    h.bootstrap()
    eids = [h.propose_global(f"g{i}") for i in range(5)]
    assert h.run_until_globally_committed(eids, 120_000)
    h.check_consistency()


def test_local_fast_global_hierarchy_latency_split():
    """Local commits ride cheap links; only global agreement pays the
    inter-pod latency — the core scaling argument of the hierarchy paper."""
    h = HierarchicalCluster(
        n_pods=2, hosts_per_pod=3, seed=36, local_latency=0.5, global_latency=10.0
    )
    h.bootstrap()
    h.run(2000)
    # Local commit inside a pod:
    pod = h.pods["pod0"]
    lead = pod.leader()
    e_local = pod.submit("local-op", via=lead)
    assert pod.run_until_committed([e_local])
    local_lat = pod.metrics.traces[e_local].latency
    # Global commit:
    e_glob = h.propose_global("global-op", via_pod=h.global_leader())
    assert h.run_until_globally_committed([e_glob])
    global_lat = h.global_metrics.traces[e_glob].latency
    assert local_lat < global_lat, (local_lat, global_lat)
    assert local_lat <= 2.0  # couple of 0.5ms hops
    assert global_lat >= 10.0  # at least one inter-pod round-trip leg
