"""Chaos integration tests (paper §3.1 style): targeted failure scenarios
beyond the randomized hypothesis schedules — asymmetric loss, flapping
partitions, cascaded leader kills, hierarchy under churn."""
import pytest

from repro.core.hierarchy import HierarchicalCluster
from repro.core.sim import Cluster


def test_asymmetric_lossy_links():
    """One node behind a terrible link (tc on a single pod, as the paper
    did): cluster keeps committing; the degraded node still converges."""
    c = Cluster(n=5, protocol="fastraft", seed=61)
    lead = c.run_until_leader()
    c.run(500)
    degraded = [n for n in c.nodes if n != c.leader()][0]
    for other in c.nodes:
        if other != degraded:
            c.set_link(degraded, other, loss=0.4, base_latency=20.0)
            c.set_link(other, degraded, loss=0.4, base_latency=20.0)
    eids = [c.submit(f"x{i}", via=c.leader()) for i in range(10)]
    assert c.run_until_committed(eids, 120_000)
    c.run(60_000)  # give the degraded node time to catch up
    c.check_log_consistency()
    assert c.nodes[degraded].commit_index >= 8  # mostly caught up


def test_flapping_partition():
    c = Cluster(n=5, protocol="fastraft", seed=62)
    c.run_until_leader()
    c.run(500)
    ids = list(c.nodes)
    submitted = []
    for round_ in range(4):
        k = 2 if round_ % 2 == 0 else 3
        c.partition(ids[:k], ids[k:])
        lead = None
        for _ in range(5):
            c.run(2000)
            lead = c.leader()
            if lead:
                break
        if lead:
            submitted.append(c.submit(f"flap{round_}", via=lead))
        c.heal()
        c.run(2000)
    c.run(30_000)
    c.check_log_consistency()
    # Everything submitted while a quorum-side leader existed must commit.
    for e in submitted:
        t = c.metrics.traces.get(e)
        assert t is not None and t.committed


def test_cascaded_leader_kills():
    """Kill every newly elected leader (up to the liveness limit)."""
    c = Cluster(n=5, protocol="fastraft", seed=63)
    killed = 0
    while killed < 2:
        lead = c.run_until_leader(60_000)
        assert lead is not None
        e = c.submit(f"k{killed}", via=lead)
        assert c.run_until_committed([e], 60_000)
        c.crash(lead)
        killed += 1
    lead = c.run_until_leader(60_000)
    assert lead is not None
    e = c.submit("survivor", via=lead)
    assert c.run_until_committed([e], 60_000)
    c.run(5000)
    c.check_log_consistency()
    log = c.nodes[lead].committed_commands()
    for i in range(2):
        assert f"k{i}" in log


def test_hierarchy_under_churn():
    h = HierarchicalCluster(n_pods=3, hosts_per_pod=3, seed=64,
                            local_loss=0.02, global_loss=0.02)
    h.bootstrap()
    eids = []
    for i in range(6):
        via = h.pod_ids[i % 3]
        if h.pods[via].leader() is not None:
            eids.append(h.propose_global(f"c{i}", via_pod=via))
        if i == 2:
            h.crash_pod_leader(h.pod_ids[1])
        h.run(3000)
    assert h.run_until_globally_committed(eids, 240_000)
    h.run(30_000)
    h.check_consistency()
