"""Multi-device in-graph consensus checks. Run in a SUBPROCESS by
test_collective.py with XLA_FLAGS=--xla_force_host_platform_device_count=8
(never set globally — see dryrun.py note in DESIGN.md)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.core.collective import (
    classic_track_commit,
    consensus_gradient_sync,
    fast_track_commit,
    masked_update,
    voted_psum,
)

assert len(jax.devices()) == 8, jax.devices()
mesh = jax.make_mesh((8,), ("data",))


def run_votes(fn, votes):
    f = shard_map(
        lambda v: fn(v[0], ("data",)),
        mesh=mesh,
        in_specs=P("data"),
        out_specs=(P(), P()),
        check_vma=False,
    )
    return jax.jit(f)(jnp.asarray(votes, jnp.float32))


# fast quorum for M=8 is ceil(24/4)=6
n_yes, committed = run_votes(fast_track_commit, [1, 1, 1, 1, 1, 1, 0, 0])
assert int(n_yes) == 6 and bool(committed), (n_yes, committed)
n_yes, committed = run_votes(fast_track_commit, [1, 1, 1, 1, 1, 0, 0, 0])
assert int(n_yes) == 5 and not bool(committed)

# classic track commits on simple majority (5 of 8)
n_yes, committed = run_votes(classic_track_commit, [1, 1, 1, 1, 1, 0, 0, 0])
assert int(n_yes) == 5 and bool(committed)
n_yes, committed = run_votes(classic_track_commit, [1, 1, 1, 1, 0, 0, 0, 0])
assert not bool(committed)

# voted_psum: sum + quorum in one fused round
def vp(x, v):
    tree, n_yes, committed = voted_psum({"g": x[0]}, v[0], ("data",))
    return tree["g"], n_yes, committed

f = shard_map(vp, mesh=mesh, in_specs=(P("data"), P("data")), out_specs=(P(), P(), P()), check_vma=False)
g, n_yes, committed = jax.jit(f)(
    jnp.arange(8, dtype=jnp.float32), jnp.ones(8, jnp.float32)
)
assert float(g) == 28.0 and int(n_yes) == 8 and bool(committed)

# HLO evidence for the piggyback claim: ONE all-reduce for grads+vote.
lowered = jax.jit(f).lower(
    jax.ShapeDtypeStruct((8,), jnp.float32), jax.ShapeDtypeStruct((8,), jnp.float32)
)
hlo = lowered.compile().as_text()
n_allreduce = hlo.count("all-reduce-start(") + hlo.count(" all-reduce(")
assert n_allreduce <= 1, f"expected fused single all-reduce, got {n_allreduce}"

# consensus_gradient_sync end-to-end: a poisoned replica is excluded.
def sync(g):
    grads = {"w": g}
    mean, n_yes, committed = consensus_gradient_sync(grads, ("data",), track="fast")
    return mean["w"], n_yes, committed

f2 = shard_map(sync, mesh=mesh, in_specs=P("data"), out_specs=(P(), P(), P()), check_vma=False)
g = jnp.ones((8, 4), jnp.float32)
g = g.at[3].set(jnp.nan)  # replica 3 diverged
mean, n_yes, committed = jax.jit(f2)(g)
assert int(n_yes) == 7 and bool(committed)
assert np.allclose(np.asarray(mean), 1.0), mean  # NaN replica excluded from mean

# masked_update rolls back on failed quorum
g = g.at[1:6].set(jnp.nan)  # 5 replicas diverged -> 3 yes votes < fq(8)=6
mean, n_yes, committed = jax.jit(f2)(g)
assert int(n_yes) == 3 and not bool(committed)
new = masked_update(committed, {"p": jnp.ones(3)}, {"p": jnp.zeros(3)})
assert np.allclose(np.asarray(new["p"]), 0.0)

print("COLLECTIVE-OK")
