"""Pallas kernel validation: interpret-mode execution against the pure-jnp
oracles in kernels/ref.py, swept over shapes, dtypes, GQA groups, and block
sizes (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _qkv(B, T, Hq, Hkv, D, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, T, Hq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize(
    "B,T,Hq,Hkv,D,dtype",
    [
        (1, 128, 2, 2, 64, jnp.float32),
        (2, 256, 4, 2, 64, jnp.float32),     # GQA group 2
        (1, 256, 4, 1, 128, jnp.float32),    # MQA
        (2, 128, 2, 2, 128, jnp.bfloat16),
        (1, 512, 8, 2, 64, jnp.bfloat16),
    ],
)
def test_flash_attention_forward(B, T, Hq, Hkv, D, dtype):
    q, k, v = _qkv(B, T, Hq, Hkv, D, dtype)
    out = ops.flash_attention(q, k, v, True)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype], rtol=1e-2,
    )


@pytest.mark.parametrize("blk", [64, 128])
def test_flash_attention_block_sizes(blk):
    q, k, v = _qkv(1, 256, 2, 2, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, True, blk, blk)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=1e-3)


def test_flash_attention_non_causal():
    q, k, v = _qkv(1, 128, 2, 2, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, False)
    want = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=1e-3)


@pytest.mark.parametrize(
    "B,T,Hq,Hkv,D",
    [
        (1, 128, 2, 2, 64),
        (2, 128, 4, 2, 64),   # GQA: dk/dv group-summed
    ],
)
def test_flash_attention_grads_match_ref(B, T, Hq, Hkv, D):
    q, k, v = _qkv(B, T, Hq, Hkv, D, jnp.float32, seed=3)

    def f_kernel(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.attention(q, k, v, causal=True).astype(jnp.float32) ** 2)

    g_kernel = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g_kernel, g_ref, "qkv"):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=5e-4, rtol=1e-2,
            err_msg=f"d{name} mismatch",
        )


@pytest.mark.parametrize(
    "B,S,Hq,Hkv,D,blk_s,dtype",
    [
        (2, 1024, 4, 4, 64, 256, jnp.float32),
        (2, 1024, 8, 2, 64, 512, jnp.float32),   # GQA
        (1, 2048, 4, 4, 128, 512, jnp.bfloat16),
    ],
)
def test_decode_attention(B, S, Hq, Hkv, D, blk_s, dtype):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32).astype(dtype)
    kv_len = jnp.asarray([S // 3, S][:B].copy() if B > 1 else [S // 2], jnp.int32)
    out = ops.decode_attention(q, k, v, kv_len, blk_s=blk_s)
    want = ref.decode_attention(q, k, v, kv_len)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype], rtol=1e-2,
    )


@pytest.mark.parametrize(
    "shape,dtype",
    [((4, 128, 256), jnp.float32), ((3, 100, 512), jnp.bfloat16), ((1000, 64), jnp.float32)],
)
def test_rmsnorm(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(9), shape, jnp.float32).astype(dtype)
    scale = jax.random.normal(jax.random.PRNGKey(10), (shape[-1],), jnp.float32)
    out = ops.rmsnorm(x, scale)
    want = ref.rmsnorm(x, scale)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype], rtol=1e-2,
    )


# ---------------------------------------------------------------------------
# Pure-jnp scan-flash (the dry-run / training tiled path) vs dense oracle.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "B,Tq,Tk,Hq,Hkv,D,offset",
    [
        (2, 2048, 2048, 4, 2, 32, None),         # training shape
        (2, 1, 2048, 4, 4, 32, (1000, 1500)),    # decode against cache
        (1, 1024, 2048, 4, 2, 32, (512,)),       # chunked prefill w/ offset
    ],
)
def test_chunked_attention_matches_sdpa(B, Tq, Tk, Hq, Hkv, D, offset):
    from repro.models import layers as L

    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (B, Tq, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Tk, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Tk, Hkv, D), jnp.float32)
    q_offset = None if offset is None else jnp.asarray(list(offset) * (B // len(offset)) or list(offset), jnp.int32)[:B]
    kv_len = None if offset is None else q_offset + Tq
    out = L.chunked_attention(q, k, v, causal=True, q_offset=q_offset,
                              kv_len=kv_len, blk_q=256, blk_k=512)
    want = L._sdpa(q, k, v, causal=True,
                   q_offset=q_offset if q_offset is not None else 0,
                   kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5, rtol=1e-2)


def test_chunked_attention_grads_match():
    from repro.models import layers as L

    ks = jax.random.split(jax.random.PRNGKey(12), 3)
    q = jax.random.normal(ks[0], (1, 1024, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 1024, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 1024, 2, 32), jnp.float32)

    f1 = lambda q, k, v: jnp.sum(L.chunked_attention(q, k, v, causal=True) ** 2)
    f2 = lambda q, k, v: jnp.sum(L._sdpa(q, k, v, causal=True) ** 2)
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4, rtol=1e-2)
