"""Membership-churn chaos tests: joint consensus, learners, rebalancing.

Every scenario runs the config-change oracle (`check_config_oracle`) on top
of the standard commit-history checks: at most one config change in flight,
voter-set changes only through joint consensus, election safety held across
C_old/C_new, and zero acked-commit loss through every reconfiguration.
"""

import pytest

from repro.core.hierarchy import HierarchicalCluster
from repro.core.raft import RaftConfig
from repro.core.sim import Cluster, MembershipError
from repro.core.types import Role

from commit_history import (
    check_commit_history,
    check_config_oracle,
    committed_acks,
)


def _drip(cluster, via, prefix, n, every=150.0):
    """Submit n commands one at a time with sim-time gaps — a continuous
    client load that keeps flowing THROUGH the reconfiguration."""
    eids = []
    for i in range(n):
        eids.append(cluster.submit(f"{prefix}{i}", via=via))
        cluster.run(every)
    return eids


# ---------------------------------------------------------------- learners


def test_learner_is_nonvoting_and_never_campaigns():
    c = Cluster(n=3, protocol="raft", seed=101)
    lead = c.run_until_leader()
    assert lead is not None
    c.add_learner("n3")
    assert c.run_until_membership()
    # The learner receives replication but counts toward no quorum: cut it
    # off entirely and the 3 voters keep committing at majority 2.
    c.partition(["n3"], [n for n in c.nodes if n != "n3"])
    eids = [c.submit(f"a{i}", via=c.leader()) for i in range(5)]
    assert c.run_until_committed(eids)
    # A partitioned VOTER would long since have started elections; the
    # learner must not (its term would have climbed).
    c.run(5000)
    assert c.nodes["n3"].role is Role.FOLLOWER
    assert c.nodes["n3"].term <= c.nodes[lead].term
    c.heal()
    c.run(3000)
    check_commit_history(c, acked=eids)
    check_config_oracle(c)


def test_learner_catches_up_via_pipelined_chunked_snapshot():
    cfg = RaftConfig(snapshot_threshold=8, snapshot_chunk_bytes=256, snapshot_chunk_window=4)
    c = Cluster(n=3, protocol="raft", seed=102, config=cfg)
    lead = c.run_until_leader()
    eids = [c.submit(f"w{i}", via=lead) for i in range(24)]
    assert c.run_until_committed(eids)
    c.run(2000)  # let compaction pass the joiner's horizon
    assert c.nodes[lead].snapshot is not None
    c.add_learner("n3")
    assert c.run_until_membership()
    c.run(5000)
    joiner = c.nodes["n3"]
    assert joiner.commit_index >= 24, "learner not backfilled"
    assert c.metrics.counters.get("snapshot_chunks_sent", 0) > 0, (
        "learner catch-up did not use the chunked snapshot path"
    )
    check_commit_history(c, acked=eids)
    check_config_oracle(c)


def test_promotion_goes_through_joint_consensus():
    c = Cluster(n=3, protocol="fastraft", seed=103)
    lead = c.run_until_leader()
    eids = [c.submit(f"p{i}", via=lead) for i in range(4)]
    assert c.run_until_committed(eids)
    c.add_node("n3")  # learner catch-up + promotion
    assert c.run_until_membership()
    lead = c.run_until_leader()
    cfg = c.nodes[lead].cluster_config
    assert "n3" in cfg.voters and not cfg.joint
    assert check_config_oracle(c) >= 3  # learner add, joint, final
    # The promoted voter now carries proposals.
    e = c.submit("from-new-voter", via="n3")
    assert c.run_until_committed([e], 30_000)
    check_commit_history(c, acked=eids + [e])


def test_at_most_one_config_change_in_flight():
    c = Cluster(n=3, protocol="raft", seed=104)
    lead = c.run_until_leader()
    node = c.nodes[lead]
    eid1, out = node.propose_config_change(
        voters=sorted(set(node.cluster_config.voters) | {"nX"}),
        now=c.sim.now,
    )
    assert eid1 is not None
    # Second change while the joint entry is uncommitted: refused.
    eid2, _ = node.propose_config_change(
        voters=sorted(set(node.cluster_config.voters) | {"nY"}),
        now=c.sim.now,
    )
    assert eid2 is None
    # Even after the joint half commits, the transition must finalize
    # before a NEW change is admitted (config_change_in_flight covers the
    # joint phase too).
    assert node.config_change_in_flight()


# ------------------------------------------------------- removals and swaps


def test_leader_removed_mid_joint_config():
    """Removing the leader itself: the leader drives its own removal
    through joint consensus, steps down only after C_new commits, and a
    new leader emerges among the survivors — under message loss."""
    c = Cluster(n=5, protocol="raft", seed=105, loss=0.05, jitter=2.0)
    lead = c.run_until_leader(30_000)
    assert lead is not None
    eids = [c.submit(f"r{i}", via=lead) for i in range(6)]
    assert c.run_until_committed(eids, 30_000)
    c.remove_node(lead, timeout=120_000.0)
    assert c.run_until_membership(180_000)
    new_lead = c.run_until_leader(60_000)
    assert new_lead is not None and new_lead != lead
    cfg = c.nodes[new_lead].cluster_config
    assert lead not in cfg.members and not cfg.joint
    more = [c.submit(f"s{i}", via=new_lead) for i in range(4)]
    assert c.run_until_committed(more, 60_000)
    check_commit_history(c, acked=committed_acks(c, eids + more))
    assert check_config_oracle(c) >= 2  # joint + final


def test_replace_leader_under_continuous_load():
    """Acceptance scenario: a 5-node cluster survives replace_node of the
    leader itself with zero acked-commit loss."""
    c = Cluster(n=5, protocol="fastraft", seed=106)
    lead = c.run_until_leader()
    other = [n for n in c.nodes if n != lead][0]
    acked = _drip(c, other, "pre", 5)
    c.replace_node(lead, "n9")
    # Load keeps flowing through a non-leader while the swap runs.
    acked += _drip(c, other, "mid", 20)
    assert c.run_until_membership(240_000)
    new_lead = c.run_until_leader(60_000)
    assert new_lead not in (None, lead)
    cfg = c.nodes[new_lead].cluster_config
    assert "n9" in cfg.voters and lead not in cfg.members
    acked += _drip(c, new_lead, "post", 5)
    c.run(5000)
    durable = committed_acks(c, acked)
    assert len(durable) >= 25, f"only {len(durable)} of {len(acked)} acked"
    check_commit_history(c, acked=durable)
    assert check_config_oracle(c) >= 3
    c.check_log_consistency()


def test_learner_promoted_during_partition():
    """The promotion joint config commits while the learner itself is
    partitioned away: majorities of C_old (3 voters) and C_new (4 voters)
    are both reachable without it, so the transition completes; the new
    voter catches up on heal."""
    c = Cluster(n=3, protocol="raft", seed=107)
    lead = c.run_until_leader()
    c.add_learner("n3")
    assert c.run_until_membership()
    c.run(2000)  # learner catches up fully
    c.partition(["n3"], [n for n in c.nodes if n != "n3"])
    c.promote("n3", timeout=120_000.0)
    assert c.run_until_membership(180_000)
    lead = c.run_until_leader()
    cfg = c.nodes[lead].cluster_config
    assert "n3" in cfg.voters and not cfg.joint
    # 4 voters, one dark: majority 3 still commits.
    eids = [c.submit(f"d{i}", via=lead) for i in range(4)]
    assert c.run_until_committed(eids, 30_000)
    c.heal()
    c.run(5000)
    assert c.nodes["n3"].commit_index >= c.nodes[lead].commit_index - 1
    check_commit_history(c, acked=eids)
    check_config_oracle(c)


def test_membership_op_fails_explicitly_without_quorum():
    c = Cluster(n=3, protocol="raft", seed=108)
    lead = c.run_until_leader()
    others = [n for n in c.nodes if n != lead]
    c.crash(others[0])
    c.crash(others[1])
    c.run(1000)
    c.remove_node(others[0], timeout=5_000.0)
    with pytest.raises(MembershipError):
        c.run_until_membership(30_000)


# ------------------------------------------------------ fast-track boundary


def test_fast_track_slots_straddle_config_boundary():
    """Fast-track windows proposed right around a promotion: slots land on
    both sides of the config entry, the joint phase requires ceil(3V/4) in
    BOTH voter sets, and every command still commits exactly once."""
    c = Cluster(n=4, protocol="fastraft", seed=109)
    lead = c.run_until_leader()
    prop = [n for n in c.nodes if n != lead][0]
    warm = [c.submit(f"warm{i}", via=prop) for i in range(4)]
    assert c.run_until_committed(warm)
    c.add_learner("n4")
    assert c.run_until_membership()
    c.run(1500)
    acked = list(warm)
    c.promote("n4", timeout=120_000.0)
    # Fast proposals race the joint/final config entries.
    for i in range(8):
        acked.append(c.submit(f"straddle{i}", via=prop))
        c.run(60)
    assert c.run_until_membership(120_000)
    assert c.run_until_committed(acked, 60_000)
    lead = c.run_until_leader()
    assert "n4" in c.nodes[lead].cluster_config.voters
    tail = [c.submit(f"after{i}", via=prop) for i in range(4)]
    assert c.run_until_committed(tail, 30_000)
    c.run(3000)
    check_commit_history(c, acked=acked + tail)
    assert check_config_oracle(c) >= 3
    c.check_log_consistency()


# ------------------------------------------------------------ hierarchy


def test_pod_rebalance_under_loss():
    """Live move of a host between pods under local message loss: both
    sides are pod-local joint-consensus changes, the mover catches up on
    the destination's state via snapshot, and neither pod loses an acked
    commit. The global tier never hears about host placement."""
    h = HierarchicalCluster(n_pods=2, hosts_per_pod=4, seed=110, local_loss=0.05)
    h.bootstrap()
    p0, p1 = h.pods["pod0"], h.pods["pod1"]
    acked0 = [p0.submit(f"a{i}", via=p0.run_until_leader()) for i in range(6)]
    acked1 = [p1.submit(f"b{i}", via=p1.run_until_leader()) for i in range(6)]
    assert p0.run_until_committed(acked0, 60_000)
    assert p1.run_until_committed(acked1, 60_000)
    global_members_before = sorted(h.global_nodes)
    h.move_node("pod0h3", "pod0", "pod1")
    assert h.run_until_moved(300_000)
    assert "pod0h3" not in p0.nodes and "pod0h3" in p1.nodes
    lead1 = p1.run_until_leader(60_000)
    assert "pod0h3" in p1.nodes[lead1].cluster_config.voters
    # The mover runs the DESTINATION pod's state (snapshot catch-up).
    h.run(5000)
    assert p1.nodes["pod0h3"].commit_index > 0
    more1 = [p1.submit(f"c{i}", via="pod0h3") for i in range(3)]
    assert p1.run_until_committed(more1, 60_000)
    check_commit_history(p0, acked=committed_acks(p0, acked0))
    check_commit_history(p1, acked=committed_acks(p1, acked1 + more1))
    check_config_oracle(p0)
    check_config_oracle(p1)
    # Pod rebalancing is invisible to the global tier.
    assert sorted(h.global_nodes) == global_members_before
    h.check_consistency()


def test_move_unaffected_by_unrelated_failed_op():
    """A stale failure record from an UNRELATED membership op must not
    poison a later pod move: moves judge failure on their own ops only."""
    h = HierarchicalCluster(n_pods=2, hosts_per_pod=4, seed=113)
    h.bootstrap()
    p1 = h.pods["pod1"]
    # Doomed op: promote a node that does not exist -> can never catch up.
    p1.promote("ghost", timeout=2_000.0)
    h.run(10_000)  # fails; record stays (nobody drains it)
    assert p1.membership_failures
    mv = h.move_node("pod0h3", "pod0", "pod1")
    assert h.run_until_moved(300_000)
    assert mv.done
    # The stale record is untouched: run_until_membership still surfaces it.
    assert p1.membership_failures
    with pytest.raises(MembershipError):
        p1.run_until_membership(1000)


def test_global_tier_catchup_uses_chunked_snapshots():
    """A pod dark through enough global commits that the global leader
    compacts past it must catch up via chunked InstallSnapshot over the
    slow links — and still deliver the full global sequence to its pod."""
    h = HierarchicalCluster(n_pods=3, hosts_per_pod=3, seed=111)
    h.bootstrap()
    dark = [p for p in h.pod_ids if p != h.global_leader()][0]
    h.partition_pod(dark)
    eids = [h.propose_global(f"g{i}", via_pod=h.global_leader()) for i in range(80)]
    assert h.run_until_globally_committed(eids, 180_000)
    glead = h.global_nodes[h.global_leader()]
    assert glead.snapshot is not None, "global tier never compacted"
    h.heal_pod(dark)
    h.run(90_000)
    assert h.global_metrics.counters.get("snapshot_chunks_sent", 0) > 0
    assert h.global_nodes[dark].commit_index >= 80
    # Snapshot-jumped history still down-propagates: full delivery.
    assert h.run_until_delivered(80, 120_000)
    h.check_consistency()


@pytest.mark.slow
def test_replace_pod_leader_with_concurrent_move():
    """Acceptance scenario: a 5-host pod survives replace_node of its own
    leader while a concurrent move_node rebalances a host INTO it from the
    other pod — zero acked-commit loss, both oracles green."""
    h = HierarchicalCluster(n_pods=2, hosts_per_pod=5, seed=112)
    h.bootstrap()
    p0, p1 = h.pods["pod0"], h.pods["pod1"]
    lead0 = p0.run_until_leader()
    other0 = [n for n in p0.nodes if n != lead0][0]
    acked0 = [p0.submit(f"pre{i}", via=other0) for i in range(4)]
    assert p0.run_until_committed(acked0, 60_000)
    # Concurrent: replace pod0's leader AND move a pod1 host into pod0.
    p0.replace_node(lead0, "pod0h9", timeout=240_000.0)
    h.move_node("pod1h4", "pod1", "pod0", timeout=300_000.0)
    for i in range(20):
        acked0.append(p0.submit(f"mid{i}", via=other0))
        h.run(200)
    assert p0.run_until_membership(300_000)
    assert h.run_until_moved(300_000)
    new_lead0 = p0.run_until_leader(60_000)
    assert new_lead0 not in (None, lead0)
    cfg = p0.nodes[new_lead0].cluster_config
    assert "pod0h9" in cfg.voters and "pod1h4" in cfg.voters
    assert lead0 not in cfg.members and not cfg.joint
    acked0.append(p0.submit("post", via=new_lead0))
    h.run(5000)
    durable = committed_acks(p0, acked0)
    assert len(durable) >= 20
    check_commit_history(p0, acked=durable)
    check_config_oracle(p0)
    check_config_oracle(p1)
    h.check_consistency()
