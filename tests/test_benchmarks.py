"""Validates the paper's experimental claims against our benchmarks:

1. Figure 1 trend: Fast Raft commits faster than Raft at low (<2%) packet
   loss — the regime the paper calls out as the real-world win — and the
   fast-track fallback fraction grows with loss (the mechanism behind the
   paper's >4% crossover).
2. Message rounds (original paper's core claim): non-leader proposals
   commit in 2 rounds on the fast track vs 3 on classic Raft; leader
   proposals are 2 rounds in both.
"""
import pytest

from benchmarks import latency_vs_loss, membership_churn, rounds_to_commit, throughput


def test_fig1_fastraft_wins_at_low_loss():
    """Loss-free: the fast track strictly wins (2 vs 3 hops). At 1% loss the
    paper's claim is a modest advantage that erodes toward the crossover —
    with a finite sample we assert fastraft stays within 10% of raft (it is
    usually below; a single unlucky fallback in a small sample can tip it)."""
    rows = {}
    for proto in ("raft", "fastraft"):
        for loss in (0.0, 0.01):
            cells = [latency_vs_loss.run_cell(proto, loss, seed=200 + s, n_ops=30)
                     for s in range(4)]
            rows[(proto, loss)] = sum(c["mean_latency"] for c in cells) / len(cells)
    assert rows[("fastraft", 0.0)] < rows[("raft", 0.0)]
    assert rows[("fastraft", 0.01)] < rows[("raft", 0.01)] * 1.10


def test_fig1_fallbacks_grow_with_loss():
    low = latency_vs_loss.run_cell("fastraft", 0.0, seed=300, n_ops=20)
    high = latency_vs_loss.run_cell("fastraft", 0.08, seed=300, n_ops=20)
    assert high["fallback_fraction"] >= low["fallback_fraction"]
    assert low["fallback_fraction"] == 0.0


def test_rounds_to_commit_exact():
    assert rounds_to_commit.measure("raft", via_leader=True) == pytest.approx(2.0)
    assert rounds_to_commit.measure("raft", via_leader=False) == pytest.approx(3.0)
    assert rounds_to_commit.measure("fastraft", via_leader=False) == pytest.approx(2.0)
    assert rounds_to_commit.measure("fastraft", via_leader=True) == pytest.approx(2.0)


def test_throughput_single_proposer_fast_share_high():
    """Largely non-conflicting proposals (the paper's fast-track regime)."""
    r = throughput.run("fastraft", burst=16, n_bursts=3, loss=0.0,
                       proposers="single")
    assert r["fast_share"] > 0.9
    r2 = throughput.run("raft", burst=16, n_bursts=3, loss=0.0,
                        proposers="single")
    assert r2["fast_share"] == 0.0
    assert r["mean_latency"] <= r2["mean_latency"]


def test_throughput_batching_at_least_2x():
    """Acceptance: with per-RPC serialization cost modeled, batched
    replication sustains >= 2x the unbatched ops/sec at loss=0."""
    s = throughput.batching_speedup("fastraft", burst=64)
    assert s["speedup"] >= 2.0, s


def test_rounds_per_op_amortized_by_batching():
    """A batch commits in the same number of serial rounds as one entry, so
    rounds per op divide by the batch size."""
    single = rounds_to_commit.measure("fastraft", via_leader=False, batch_size=1)
    batched = rounds_to_commit.measure("fastraft", via_leader=False, batch_size=8)
    assert batched == pytest.approx(single)  # same rounds per batch


def test_membership_churn_replace_leader_dip_bounded():
    """Acceptance: replacing the leader itself (learner join + joint swap
    + step-down + re-election) costs less than 2 election timeouts of
    availability at loss=0, with zero acked-commit loss (the scenario
    asserts the commit-history and config oracles internally)."""
    r = membership_churn.run_scenario("replace_leader", loss=0.0,
                                      steady_ops=6, churn_ops=15)
    assert r["gap_timeouts"] < 2.0, r
    assert r["config_entries"] >= 3  # learner add, joint, final


def test_membership_churn_hardened_dip_no_worse():
    """PreVote + CheckQuorum must not slow leader replacement: the hardened
    dip clears the same 2-timeout bar, within one probe round (~half a
    timeout) of the unhardened baseline."""
    base = membership_churn.run_scenario("replace_leader", loss=0.0,
                                         steady_ops=6, churn_ops=15)
    hard = membership_churn.run_scenario("replace_leader", loss=0.0,
                                         steady_ops=6, churn_ops=15,
                                         hardened=True)
    assert hard["gap_timeouts"] < 2.0, hard
    assert hard["gap_timeouts"] <= base["gap_timeouts"] + 0.5, (base, hard)


def test_throughput_conflict_regime_falls_back_but_commits():
    """Simultaneous proposals from every non-leader deliberately collide on
    slots — the paper's conflict case: the fast track degrades to classic,
    but every op still commits exactly once."""
    r = throughput.run("fastraft", burst=16, n_bursts=3, loss=0.0,
                       proposers="all")
    assert r["committed"] == 48
    assert r["fast_share"] < 0.9  # collisions force fallbacks
    single = throughput.run("fastraft", burst=16, n_bursts=3, loss=0.0,
                            proposers="single")
    assert single["mean_latency"] <= r["mean_latency"]
