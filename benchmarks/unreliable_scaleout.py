"""Cost-per-nine: scale-out on flaky nodes, witness vs full-replica.

The reliability-aware scale-out story (DESIGN.md §12) says you can buy
quorum resilience without paying for full replicas: a *witness* votes and
acks rounds but stores no log payload and runs no state machine, so an
odd-sized cluster costs the storage/apply of only its full members. This
benchmark puts a price on that claim under a FIXED per-node failure rate
(every node crash/recovers on an exponential renewal schedule) while a
continuous client load runs:

- ``committed_ops_per_sec`` — commit throughput under chaos.
- ``acked_lost`` — acked commits that vanished after the dust settles
  (the durability floor; must be 0 for every arm — that is what "equal
  durability" means here, enforced by ``check_commit_history``).
- ``full_replicas`` — the cost axis: state-machine-bearing members.
- ``elections`` — leadership churn paid during the run.

Arms per cluster size N: ``full`` (N full voters) and ``witness`` (N
voters of which W are witnesses, so N - W full replicas). Both arms see
the IDENTICAL failure schedule (per-node RNG streams keyed by seed and
node id, independent of protocol behaviour), so the comparison is
schedule-for-schedule, not statistical.

A second experiment holds the cluster fixed and toggles
``RaftConfig.reliability_weighted_election`` under a heterogeneous
profile (half the nodes flaky, half stable): weighted elections bias
timeouts toward recently-up, regularly-contacted nodes, which should
shed leadership churn with no safety cost.

Asserted in ``main`` (and therefore in the CI smoke lane):

- the witness arm matches or beats full-replica committed ops/sec at
  every N (within a 10% tolerance band), with zero acked commits lost in
  BOTH arms;
- weighted elections produce no more leadership churn than unweighted
  under the same failure schedule, and commit at least as much.

``--check`` runs exactly the smoke grid and exits non-zero on any
assertion failure (CI gate). ``--json PATH`` writes the rows as a
``BENCH_*.json`` artifact.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.raft import RaftConfig
from repro.core.sim import Cluster, FailureProfile

from tests.commit_history import check_commit_history, committed_acks

INTERVAL = 50.0  # sim-ms between client submissions (continuous load)
MTTR_MS = 800.0  # repair time for every flaky node


def _alive_full(c: Cluster) -> Optional[str]:
    """A live, payload-bearing submission point (witnesses forward fine,
    but real clients talk to full members)."""
    for nid in sorted(c.nodes):
        n = c.nodes[nid]
        if n.alive and not n.cluster_config.is_witness(nid):
            return nid
    return None


def run_cell(
    n: int,
    witnesses: int,
    seed: int,
    ops: int,
    mtbf_ms: float,
    weighted: bool = False,
    heterogeneous: bool = False,
    protocol: str = "fastraft",
) -> Dict[str, float]:
    """One (cluster size, arm) cell: bootstrap, install the failure
    schedule, drive load, heal, and audit durability."""
    cfg = RaftConfig(
        heartbeat_interval=50.0,
        pre_vote=True,
        check_quorum=True,
        reliability_weighted_election=weighted,
    )
    wit_ids = [f"n{i}" for i in range(n - witnesses, n)] if witnesses else []
    c = Cluster(
        n=n, protocol=protocol, seed=seed, jitter=2.0, config=cfg,
        witnesses=wit_ids,
    )
    assert c.run_until_leader(60_000) is not None

    # The failure schedule is a pure function of (seed, node id): both
    # arms and both election variants replay the same crash/recover times.
    profiles = {}
    for i in range(n):
        # Heterogeneous mode: the "stable" half still fails, just 8x more
        # rarely — leadership keeps being contested, which is exactly the
        # regime where reliability-weighted elections should matter.
        m = mtbf_ms * 8 if (heterogeneous and i < n // 2) else mtbf_ms
        profiles[f"n{i}"] = FailureProfile(
            mtbf_ms=m, mttr_ms=MTTR_MS, group=f"g{i % 2}"
        )
    c.set_failure_profiles(profiles)

    eids: List = []
    t0 = c.sim.now
    for i in range(ops):
        via = _alive_full(c)
        if via is not None:
            eids.append(c.submit(f"op{i}", via=via))
        c.run(INTERVAL)
    t1 = c.sim.now

    # Stop the chaos, heal, and give the cluster time to converge before
    # auditing: durability claims are about what survives, not mid-storm.
    c.clear_failure_profiles()  # also cancels in-flight recover events
    c.heal()
    for nid in list(c.nodes):
        if not c.nodes[nid].alive:
            c.nodes[nid].restart(c.sim.now)
    assert c.run_until_leader(120_000) is not None
    c.run(5_000)

    durable = committed_acks(c, eids)
    check_commit_history(c, acked=durable)  # raises if an acked commit vanished
    committed = sum(
        1
        for e in eids
        if (t := c.metrics.traces.get(e)) is not None and t.committed
    )
    load_s = max((t1 - t0) / 1000.0, 1e-9)
    return {
        "n": float(n),
        "witnesses": float(witnesses),
        "full_replicas": float(n - witnesses),
        "committed": float(committed),
        "committed_ops_per_sec": committed / load_s,
        "acked": float(len(durable)),
        "acked_lost": 0.0,  # check_commit_history would have raised
        "elections": float(c.metrics.counters.get("leader_elected", 0)),
        "crashes": float(c.metrics.counters.get("fp_crashes", 0)),
    }


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--smoke", action="store_true",
        help="quick CI mode: small grid, fewer ops",
    )
    ap.add_argument(
        "--check", action="store_true",
        help="CI gate: run the smoke grid and fail on any regression "
        "(witness arm slower than full, acked loss, weighted churn worse)",
    )
    ap.add_argument(
        "--json", metavar="PATH",
        help="write result rows as JSON (CI artifact)",
    )
    ap.add_argument(
        "--protocol", default="fastraft", choices=("raft", "fastraft"),
    )
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument(
        "--mtbf", type=float, default=4000.0, metavar="MS",
        help="per-node mean time between failures (fixed failure rate)",
    )
    args = ap.parse_args(argv)
    quick = args.smoke or args.check
    sizes = (3, 5) if quick else (3, 5, 7, 9)
    ops = 150 if quick else 400

    rows: List[Dict] = []
    print("experiment,n,witnesses,full_replicas,ops_per_sec,acked,elections,crashes")

    # -- Experiment 1: witness vs full-replica scale-out ------------------
    for n in sizes:
        wit = 1 if n == 3 else 2
        for witnesses in (0, wit):
            r = run_cell(
                n, witnesses, seed=args.seed, ops=ops, mtbf_ms=args.mtbf,
                protocol=args.protocol,
            )
            r["experiment"] = "scaleout"
            r["protocol"] = args.protocol
            rows.append(r)
            print(
                f"scaleout,{n},{witnesses},{n - witnesses},"
                f"{r['committed_ops_per_sec']:.2f},{r['acked']:.0f},"
                f"{r['elections']:.0f},{r['crashes']:.0f}"
            )

    # -- Experiment 2: weighted vs unweighted elections -------------------
    # Leadership churn is a counting statistic with real per-seed variance;
    # aggregate over a handful of seeds (each seed pair replays the SAME
    # failure schedule for both variants, so the comparison stays paired).
    churn_n = 5
    churn_seeds = range(args.seed + 1, args.seed + 1 + (5 if quick else 10))
    for weighted in (False, True):
        agg = {"elections": 0.0, "committed": 0.0, "crashes": 0.0, "acked": 0.0}
        for s in churn_seeds:
            cell = run_cell(
                churn_n, 0, seed=s, ops=ops * 3, mtbf_ms=args.mtbf * 0.4,
                weighted=weighted, heterogeneous=True, protocol=args.protocol,
            )
            for k in agg:
                agg[k] += cell[k]
        r = {
            **agg,
            "n": float(churn_n),
            "witnesses": 0.0,
            "full_replicas": float(churn_n),
            "committed_ops_per_sec": cell["committed_ops_per_sec"],
            "seeds": float(len(list(churn_seeds))),
            "experiment": "weighted" if weighted else "unweighted",
            "protocol": args.protocol,
        }
        rows.append(r)
        print(
            f"{r['experiment']},{churn_n},0,{churn_n},"
            f"{r['committed_ops_per_sec']:.2f},{r['acked']:.0f},"
            f"{r['elections']:.0f},{r['crashes']:.0f}"
        )

    # -- Gates (run under --smoke and --check: the CI lanes) --------------
    by_n: Dict[int, Dict[str, Dict]] = {}
    for r in rows:
        if r["experiment"] == "scaleout":
            arm = "witness" if r["witnesses"] else "full"
            by_n.setdefault(int(r["n"]), {})[arm] = r
    for n, arms in sorted(by_n.items()):
        full, wit = arms["full"], arms["witness"]
        ratio = wit["committed_ops_per_sec"] / max(full["committed_ops_per_sec"], 1e-9)
        print(
            f"n={n}: witness/full throughput ratio {ratio:.2f} "
            f"({wit['full_replicas']:.0f} vs {full['full_replicas']:.0f} full replicas)"
        )
        # Equal durability is enforced inside run_cell (zero acked loss in
        # both arms); at that durability the cheaper cluster must keep up.
        assert ratio >= 0.9, (
            f"witness arm lost throughput at n={n}: ratio {ratio:.2f}"
        )
    unw = next(r for r in rows if r["experiment"] == "unweighted")
    wgt = next(r for r in rows if r["experiment"] == "weighted")
    print(
        f"elections: unweighted {unw['elections']:.0f} vs "
        f"weighted {wgt['elections']:.0f} (same failure schedule)"
    )
    assert wgt["elections"] <= unw["elections"], (
        f"weighted elections churned MORE: {wgt['elections']:.0f} vs "
        f"{unw['elections']:.0f}"
    )
    assert wgt["committed"] >= unw["committed"] * 0.9, (
        f"weighted elections cost throughput: {wgt['committed']:.0f} vs "
        f"{unw['committed']:.0f} committed"
    )

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


if __name__ == "__main__":
    main()
