"""Snapshot catch-up time vs packet loss: monolithic vs chunked transfer.

The scenario is the paper's recovery path (and BlackWater Raft's headline
cost on unreliable nodes): a follower lost its disk while the leader
compacted past it, so catch-up must ship the snapshot. The network model is
size-aware in both dimensions that matter:

- ``mtu_bytes`` makes loss per-packet: a message of S bytes survives with
  probability (1-loss)^ceil(S/mtu). A monolithic InstallSnapshot carrying a
  multi-KB snapshot virtually never survives a lossy link in one piece; an
  MTU-sized chunk usually does.
- ``bytes_per_ms`` charges transmission time, so every monolithic retry
  pays the full snapshot serialization again while a chunk retry pays one
  chunk.

Chunked transfer additionally RESUMES from the follower's offset after a
drop (retransmit on heartbeat) instead of restarting, so its catch-up time
degrades linearly-ish with loss while the monolithic curve blows up. And a
PIPELINED window (``snapshot_chunk_window`` > 1 chunks in flight) amortizes
the per-chunk RTT that a serial stream pays even on a loss-free link — the
regime where serial chunking was visibly slower than its own bandwidth.
Headline checks (``main``): chunked <= monolithic catch-up time at every
loss >= 0.1, and pipelined < serial chunked at loss=0.

Also reported: KV vs LogList snapshot size for the same history — the
reduced-state snapshot is O(live keys), which is what makes streaming it
cheap in the first place.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

from repro.core.raft import RaftConfig
from repro.core.sim import Cluster
from repro.core.statemachine import KVMachine

MTU = 1400.0          # bytes per simulated packet
BYTES_PER_MS = 1500.0  # link bandwidth (~12 Mbit/s, keeps numbers readable)
CHUNK_BYTES = 1200     # just under the MTU: one packet per chunk
CHUNK_WINDOW = 8       # pipelined mode: chunks in flight per follower
N_CMDS = 120
PAYLOAD = 300          # per-command payload bytes => ~40 KB snapshot
MAX_CATCH_UP_MS = 300_000.0  # cap: "effectively never" for monolithic


def catch_up(chunk_bytes: int, loss: float, seed: int = 5,
             n_cmds: int = N_CMDS, payload: int = PAYLOAD,
             chunk_window: int = 1) -> Dict[str, float]:
    """Crash a follower, commit + compact past it on the survivors, restart
    it, and measure sim-time until it has the full committed prefix."""
    # Small AppendEntries batches: with per-packet loss a 64-entry batch is
    # ~16 packets and essentially never survives loss >= 0.2, which would
    # starve the commit phase before the measurement even starts.
    cfg = RaftConfig(snapshot_chunk_bytes=chunk_bytes, max_batch_entries=8,
                     snapshot_chunk_window=chunk_window)
    c = Cluster(n=3, protocol="raft", seed=seed, loss=loss, base_latency=5.0,
                jitter=1.0, bytes_per_ms=BYTES_PER_MS, mtu_bytes=MTU,
                config=cfg)
    assert c.run_until_leader(60_000) is not None
    c.run(1000)
    lead = c.leader()
    victim = [n for n in c.nodes if n != lead][0]
    # Partition AND crash the victim: the partition blocks traffic at the
    # source (otherwise the leader's optimistic pipeline queues hundreds of
    # ms of stale AppendEntries on the busy link, which would "deliver"
    # after restart and catch the victim up without any snapshot); the
    # crash freezes its election timers so its term cannot inflate.
    c.partition([victim], [n for n in c.nodes if n != victim])
    c.crash(victim)
    eids = [c.submit("v" * payload + f"-{i}", via=lead) for i in range(n_cmds)]
    assert c.run_until_committed(eids, 600_000)

    # Let every survivor APPLY the full prefix before compacting, else a
    # lagging survivor compacts at its own (lower) horizon and a later
    # election through it hands the victim a cheap snapshot+replay path.
    def settled() -> bool:
        return all(
            (not n.alive) or n.last_applied >= n_cmds for n in c.nodes.values()
        )

    c.sim.run_until(c.sim.now + 120_000, stop=settled)
    assert settled()
    # Compact EVERY survivor: leadership may churn under loss, and whoever
    # leads must be past the replay horizon so catch-up must ship the
    # snapshot. (Survivors applied the same prefix, so their snapshots are
    # byte-identical and a chunked transfer even survives a leader change.)
    for node in c.nodes.values():
        if node.alive:
            node.compact()
    lead = c.leader() or lead
    snap_bytes = c.nodes[lead].snapshot.size_bytes()
    t0 = c.sim.now
    c.heal()
    c.restart(victim)

    def caught_up() -> bool:
        return c.nodes[victim].commit_index >= n_cmds

    c.sim.run_until(c.sim.now + MAX_CATCH_UP_MS, stop=caught_up)
    # A transfer that never completes within the cap reports the cap — at
    # high loss a monolithic InstallSnapshot effectively never survives.
    elapsed = (c.sim.now - t0) if caught_up() else MAX_CATCH_UP_MS
    return {
        "catch_up_ms": elapsed,
        "caught_up": float(caught_up()),
        "snapshot_bytes": float(snap_bytes),
        "chunks_sent": float(c.metrics.counters.get("snapshot_chunks_sent", 0)),
        "snapshots_sent": float(c.metrics.counters.get("snapshots_sent", 0)),
        "transfer_restarts": float(
            c.metrics.counters.get("snapshot_transfer_restarts", 0)
        ),
    }


def kv_vs_loglist_snapshot_bytes(n_updates: int = 240, n_keys: int = 6,
                                 seed: int = 7) -> Dict[str, float]:
    """Same history through both machines; compare snapshot wire size."""

    def run(factory) -> float:
        c = Cluster(n=3, protocol="raft", seed=seed,
                    state_machine_factory=factory)
        assert c.run_until_leader(60_000) is not None
        c.run(1000)
        lead = c.leader()
        for b in range(n_updates // 20):
            eids = c.submit_batch(
                [f"SET key{i % n_keys} value_{b}_{i}" for i in range(20)],
                via=lead,
            )
            assert c.run_until_committed(eids, 120_000)
        node = c.nodes[lead]
        node.compact()
        return float(node.snapshot.size_bytes())

    kv = run(lambda nid: KVMachine())
    loglist = run(None)
    return {
        "kv_snapshot_bytes": kv,
        "loglist_snapshot_bytes": loglist,
        "reduction": loglist / max(kv, 1.0),
    }


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI mode: fewer loss points, smaller history")
    ap.add_argument("--json", metavar="PATH",
                    help="write result rows as JSON (CI artifact)")
    args = ap.parse_args(argv)
    losses = (0.0, 0.2) if args.smoke else (0.0, 0.05, 0.1, 0.2, 0.3)
    n_cmds = 60 if args.smoke else N_CMDS

    rows = []
    print("mode,loss,catch_up_ms,snapshot_bytes,chunks_sent,transfer_restarts")
    for loss in losses:
        mono = catch_up(chunk_bytes=0, loss=loss, n_cmds=n_cmds)
        chunk = catch_up(chunk_bytes=CHUNK_BYTES, loss=loss, n_cmds=n_cmds)
        piped = catch_up(chunk_bytes=CHUNK_BYTES, loss=loss, n_cmds=n_cmds,
                         chunk_window=CHUNK_WINDOW)
        for mode, r in (("monolithic", mono), ("chunked", chunk),
                        ("pipelined", piped)):
            r.update(mode=mode, loss=loss)
            rows.append(r)
            print(f"{mode},{loss},{r['catch_up_ms']:.0f},"
                  f"{r['snapshot_bytes']:.0f},{r['chunks_sent']:.0f},"
                  f"{r['transfer_restarts']:.0f}")
        if loss == 0.0:
            # The serial stream pays one RTT per chunk even with zero loss;
            # the pipelined window amortizes it.
            assert piped["catch_up_ms"] < chunk["catch_up_ms"], (
                f"pipelined not faster than serial chunked at loss=0: "
                f"{piped['catch_up_ms']:.0f} vs {chunk['catch_up_ms']:.0f} ms"
            )
        if loss >= 0.1:
            assert chunk["catch_up_ms"] <= mono["catch_up_ms"], (
                f"chunked slower than monolithic at loss={loss}: "
                f"{chunk['catch_up_ms']:.0f} vs {mono['catch_up_ms']:.0f} ms"
            )
    sizes = kv_vs_loglist_snapshot_bytes()
    print(f"kv snapshot {sizes['kv_snapshot_bytes']:.0f} B vs loglist "
          f"{sizes['loglist_snapshot_bytes']:.0f} B "
          f"({sizes['reduction']:.1f}x smaller)")
    rows.append({"mode": "kv_vs_loglist", "loss": 0.0, **sizes})
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


if __name__ == "__main__":
    main()
