"""Render collected ``BENCH_*.json`` artifacts as a markdown perf report.

Reads the artifact directory produced by ``benchmarks/run.py`` (default
``bench-out/``) and prints one headline-metric table, optionally with a
baseline column for before/after comparison::

  PYTHONPATH=src python benchmarks/perf_report.py                       # bench-out/
  PYTHONPATH=src python benchmarks/perf_report.py --dir new --baseline old

Each headline is extracted from the benchmark's own row schema (see
docs/benchmarks.md); artifacts that are missing are skipped, so the
report works on partial runs (e.g. a single ``run.py --only`` entry).
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional, Tuple

Headline = Tuple[str, float, str]  # (label, value, unit)


def _load(dirname: str, name: str) -> Optional[List[Dict]]:
    path = os.path.join(dirname, f"BENCH_{name}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        rows = json.load(f)
    return rows or None


def _throughput(rows: List[Dict]) -> List[Headline]:
    # Rows are heterogeneous (plain load, batching-comparison rows with a
    # `speedup` key, adaptive-batching score rows); headline each kind.
    best: Dict[str, float] = {}
    for r in rows:
        p, v = r["protocol"], r.get("ops_per_sec")
        if p.startswith("window-"):  # coalesce-window score grid, not load
            continue
        if v is not None and v > best.get(p, 0.0):
            best[p] = v
    out = [(f"throughput/{p}_peak", v, "ops/s") for p, v in sorted(best.items())]
    if best.get("raft") and best.get("fastraft"):
        out.append(
            ("throughput/fastraft_vs_raft", best["fastraft"] / best["raft"], "x")
        )
    batched = [r["speedup"] for r in rows if "speedup" in r]
    if batched:
        out.append(("throughput/batching_speedup", max(batched), "x"))
    return out


def _read_latency(rows: List[Dict]) -> List[Headline]:
    out = []
    for kind in ("lease_reads", "readindex_reads"):
        served = [r for r in rows if r.get(kind, 0) > 0 and r.get("loss") == 0.0]
        if served:
            v = min(r["mean_read_latency_ms"] for r in served)
            out.append((f"read_latency/{kind.replace('_reads', '')}", v, "ms"))
    return out


def _read_scaleout(rows: List[Dict]) -> List[Headline]:
    return [
        (
            "read_scaleout/agg_reads_peak",
            max(r["agg_reads_per_sec"] for r in rows),
            "reads/s",
        )
    ]


def _membership_churn(rows: List[Dict]) -> List[Headline]:
    at0 = [r for r in rows if r.get("loss") == 0.0]
    return [
        (
            "membership_churn/worst_gap",
            max(r["gap_timeouts"] for r in at0),
            "election timeouts",
        )
    ]


def _snapshot(rows: List[Dict]) -> List[Headline]:
    done = [r for r in rows if r.get("caught_up")]
    if not done:
        return []
    return [
        ("snapshot/fastest_catch_up", min(r["catch_up_ms"] for r in done), "sim-ms")
    ]


def _sim_speed(rows: List[Dict]) -> List[Headline]:
    by_engine: Dict[str, float] = {}
    for r in rows:
        if "events_per_sec" not in r:  # engine-comparison rows carry `speedup`
            continue
        e = r.get("engine", "?")
        by_engine[e] = max(by_engine.get(e, 0.0), r["events_per_sec"])
    out = [
        (f"sim_speed/{e}_peak", v, "events/s") for e, v in sorted(by_engine.items())
    ]
    speedups = [r["speedup"] for r in rows if "speedup" in r]
    if speedups:
        out.append(("sim_speed/slotted_vs_legacy", max(speedups), "x"))
    return out


def _unreliable(rows: List[Dict]) -> List[Headline]:
    out = []
    scale = [r for r in rows if r.get("experiment") == "scaleout"]
    if scale:
        n = max(int(r["n"]) for r in scale)
        arms = {bool(r["witnesses"]): r for r in scale if int(r["n"]) == n}
        if True in arms and False in arms:
            full = arms[False]["committed_ops_per_sec"]
            out.append(
                (
                    f"unreliable/witness_vs_full_n{n}",
                    arms[True]["committed_ops_per_sec"] / max(full, 1e-9),
                    "x",
                )
            )
    by_exp = {r["experiment"]: r for r in rows}
    if "weighted" in by_exp and "unweighted" in by_exp:
        for k in ("unweighted", "weighted"):
            out.append(
                (f"unreliable/elections_{k}", by_exp[k]["elections"], "elections")
            )
    return out


def _bytes_on_wire(rows: List[Dict]) -> List[Headline]:
    out: List[Headline] = []
    bws = sorted({r["bytes_per_ms"] for r in rows})
    for bw in bws:
        arms = {r["arm"]: r for r in rows if r["bytes_per_ms"] == bw}
        if "baseline" not in arms or "frugal" not in arms:
            continue
        red = 1.0 - (
            arms["frugal"]["bytes_per_commit"]
            / arms["baseline"]["bytes_per_commit"]
        )
        out.append((f"bytes_on_wire/reduction_bw{bw:.0f}", 100.0 * red, "%"))
    if bws:
        lo = min(bws)
        for r in rows:
            if r["arm"] == "frugal" and r["bytes_per_ms"] == lo:
                out.append(
                    (
                        f"bytes_on_wire/frugal_bytes_per_commit_bw{lo:.0f}",
                        r["bytes_per_commit"],
                        "B/commit",
                    )
                )
                out.append(
                    (
                        f"bytes_on_wire/frugal_ops_bw{lo:.0f}",
                        r["ops_per_sec"],
                        "ops/s",
                    )
                )
                break
    return out


EXTRACTORS = [
    ("throughput", _throughput),
    ("read_latency", _read_latency),
    ("read_latency_scaleout", _read_scaleout),
    ("membership_churn", _membership_churn),
    ("snapshot_transfer", _snapshot),
    ("sim_speed", _sim_speed),
    ("unreliable_scaleout", _unreliable),
    ("bytes_on_wire", _bytes_on_wire),
]


def collect(dirname: str) -> List[Headline]:
    out: List[Headline] = []
    for name, fn in EXTRACTORS:
        rows = _load(dirname, name)
        if rows is None:
            continue
        try:
            out.extend(fn(rows))
        except (KeyError, ValueError) as e:  # schema drift: flag, don't die
            out.append((f"{name}/UNREADABLE_{type(e).__name__}", float("nan"), ""))
    return out


def _fmt(v: float) -> str:
    if v != v:
        return "--"
    if abs(v) >= 1000:
        return f"{v:,.0f}"
    return f"{v:.2f}"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dir", default="bench-out", help="artifact directory")
    ap.add_argument(
        "--baseline", metavar="DIR",
        help="second artifact directory for a before/after delta column",
    )
    args = ap.parse_args(argv)

    current = collect(args.dir)
    if not current:
        print(f"no BENCH_*.json artifacts in {args.dir}/ — run benchmarks/run.py first")
        return 1
    base = dict()
    if args.baseline:
        base = {label: v for label, v, _ in collect(args.baseline)}

    print(f"## Benchmark report ({args.dir})\n")
    if base:
        print("| metric | value | unit | baseline | delta |")
        print("|---|---:|---|---:|---:|")
    else:
        print("| metric | value | unit |")
        print("|---|---:|---|")
    for label, v, unit in current:
        if base:
            b = base.get(label, float("nan"))
            delta = f"{100 * (v - b) / b:+.0f}%" if b == b and b else "--"
            print(f"| {label} | {_fmt(v)} | {unit} | {_fmt(b)} | {delta} |")
        else:
            print(f"| {label} | {_fmt(v)} | {unit} |")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
