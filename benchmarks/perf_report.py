"""Generate the EXPERIMENTS.md §Roofline table and §Perf before/after
comparison from artifacts (dryrun_baseline = iteration-0/1 state, dryrun =
final state, perf = per-variant knob runs).

  PYTHONPATH=src python -m benchmarks.perf_report
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, analyze_cell,
                                 build_table, calibrate, model_flops)

PERF_DIR = os.path.join("artifacts", "perf")


def fmt_s(x):
    if x != x:
        return "--"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def roofline_markdown(mesh="single", artifact_root="artifacts/dryrun"):
    calib = calibrate()
    import benchmarks.roofline as R

    old = R.ARTIFACT_DIR
    R.ARTIFACT_DIR = artifact_root
    try:
        rows = build_table(mesh, calib)
    finally:
        R.ARTIFACT_DIR = old
    lines = [
        "| arch | shape | compute | memory | collective | dominant | useful | roofline |",
        "|---|---|---:|---:|---:|---|---:|---:|",
    ]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | skip | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} |"
        )
    return "\n".join(lines), rows


def variant_row(arch, shape, variant, calib):
    path = os.path.join(PERF_DIR, f"{arch}__{shape}__{variant}.json")
    if not os.path.exists(path):
        return None
    d = json.load(open(path))
    deep = d.get("hlo_analysis")
    if deep:
        flops, b, coll = deep["flops"], deep["bytes_accessed"], deep["collective_bytes"]
        counts = {k: int(v) for k, v in deep["collective_counts"].items()}
    else:
        cost = d["cost_analysis"]
        flops = cost.get("flops", float("nan")) * calib
        b = cost.get("bytes accessed", float("nan"))
        coll = d["collectives"]["total_bytes"]
        counts = d["collectives"]["counts"]
    return {
        "variant": variant,
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": b / HBM_BW,
        "collective_s": coll / ICI_BW,
        "counts": counts,
    }


def perf_markdown(cells):
    calib = calibrate()
    out = []
    for arch, shape, variants in cells:
        out.append(f"\n**{arch} × {shape}**\n")
        out.append("| variant | compute | memory | collective | collective ops |")
        out.append("|---|---:|---:|---:|---|")
        for v in variants:
            r = variant_row(arch, shape, v, calib)
            if r is None:
                continue
            cnt = ",".join(f"{k}:{n}" for k, n in sorted(r["counts"].items()))
            out.append(
                f"| {v} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
                f"{fmt_s(r['collective_s'])} | {cnt} |"
            )
    return "\n".join(out)


def main():
    md, rows = roofline_markdown("single", "artifacts/dryrun")
    print("## Final roofline (single pod, per device)\n")
    print(md)
    if os.path.isdir("artifacts/dryrun_baseline"):
        md_b, rows_b = roofline_markdown("single", "artifacts/dryrun_baseline")
        by_key = {(r.get("arch"), r.get("shape")): r for r in rows_b}
        print("\n## Baseline -> final dominant-term movement\n")
        print("| arch | shape | dominant | baseline | final | delta |")
        print("|---|---|---|---:|---:|---:|")
        for r in rows:
            if "skipped" in r:
                continue
            b = by_key.get((r["arch"], r["shape"]))
            if not b or "skipped" in b:
                continue
            k = r["dominant"] + "_s"
            bk = b.get(k, float("nan"))
            fk = r.get(k, float("nan"))
            if bk == bk and fk == fk and bk > 0:
                print(f"| {r['arch']} | {r['shape']} | {r['dominant']} | "
                      f"{fmt_s(bk)} | {fmt_s(fk)} | {100*(fk-bk)/bk:+.0f}% |")
    cells = [
        ("llama4-scout-17b-a16e", "train_4k", ["classic", "fast", "stream"]),
        ("qwen3-1.7b", "train_4k", ["classic", "fast", "stream"]),
        ("qwen3-4b", "decode_32k", ["fsdpserve", "tponly"]),
    ]
    print("\n## Hillclimb variants\n")
    print(perf_markdown(cells))


if __name__ == "__main__":
    main()
