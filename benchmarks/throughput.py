"""Throughput under bursty load (the paper's load-tester scenario): N ops
submitted in bursts through all non-leader nodes; measure committed ops/sec
of simulated time and the fast-track share."""
from __future__ import annotations

from typing import Dict, List

from repro.core.sim import Cluster


def run(protocol: str, burst: int, n_bursts: int = 5, seed: int = 3,
        loss: float = 0.01, proposers: str = "single") -> Dict[str, float]:
    """proposers="single": one non-leader client (largely non-conflicting —
    the regime where the paper's fast track wins). "all": every non-leader
    proposes at the same instant — deliberate slot collisions, measuring the
    paper's conflict/fallback behavior."""
    c = Cluster(n=5, protocol=protocol, seed=seed, loss=loss,
                base_latency=5.0, jitter=1.0)
    c.run_until_leader(60_000)
    c.run(1000)
    lead = c.leader()
    others = [x for x in c.nodes if x != lead]
    t_start = c.sim.now
    eids = []
    for b in range(n_bursts):
        for i in range(burst):
            via = others[0] if proposers == "single" else others[i % len(others)]
            eids.append(c.submit(f"b{b}i{i}", via=via))
        c.run(200.0)
    c.run_until_committed(eids, 600_000)
    c.check_log_consistency()
    elapsed = c.sim.now - t_start
    n_committed = len(c.metrics.latencies())
    fast_commits = c.metrics.counters.get("fast_commits", 0)
    return {
        "ops_per_sec": n_committed / (elapsed / 1000.0),
        "committed": n_committed,
        "fast_share": fast_commits / max(n_committed, 1),
        "mean_latency": c.metrics.mean_latency() or float("nan"),
    }


def main() -> List[Dict]:
    rows = []
    for protocol in ("raft", "fastraft"):
        for burst in (4, 16, 64):
            r = run(protocol, burst)
            r.update(protocol=protocol, burst=burst, proposers="single")
            rows.append(r)
    # The conflict regime (paper: "as long as proposals remain largely
    # non-conflicting" — here they are NOT, deliberately).
    r = run("fastraft", 16, proposers="all")
    r.update(protocol="fastraft", burst=16, proposers="all")
    rows.append(r)
    print("protocol,proposers,burst,ops_per_sec,fast_share,mean_latency_ms")
    for r in rows:
        print(f"{r['protocol']},{r['proposers']},{r['burst']},{r['ops_per_sec']:.1f},"
              f"{r['fast_share']:.2f},{r['mean_latency']:.2f}")
    return rows


if __name__ == "__main__":
    main()
