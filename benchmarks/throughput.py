"""Throughput under bursty load (the paper's load-tester scenario): N ops
submitted in bursts through all non-leader nodes; measure committed ops/sec
of simulated time and the fast-track share.

Batched vs. unbatched: with ``batch=True`` each burst is submitted through
:meth:`Cluster.submit_batch` — one multi-slot FastPropose window / one
multi-entry AppendEntries instead of one RPC per command. The network model
charges a per-message serialization cost (``msg_overhead``) so the benchmark
measures what batching actually amortizes: per-RPC overhead. The headline
comparison (``main()``) shows batched replication sustaining >= 2x the
unbatched ops/sec at loss=0.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

from repro.core.raft import RaftConfig
from repro.core.sim import Cluster
from repro.core.statemachine import KVMachine

MSG_OVERHEAD = 0.4  # ms per RPC: fixed marshalling/syscall/NIC cost
KV_KEYS = 32        # live keyspace for workload="kv"


def _command(workload: str, b: int, i: int) -> str:
    if workload == "kv":
        return f"SET key{(b * 131 + i) % KV_KEYS} val_{b}_{i}"
    return f"b{b}i{i}"


def run(protocol: str, burst: int, n_bursts: int = 5, seed: int = 3,
        loss: float = 0.01, proposers: str = "single", batch: bool = False,
        msg_overhead: float = MSG_OVERHEAD,
        workload: str = "append", read_ratio: float = 0.0,
        lease: bool = False, batch_window=0.0) -> Dict[str, float]:
    """proposers="single": one non-leader client (largely non-conflicting —
    the regime where the paper's fast track wins). "all": every non-leader
    proposes at the same instant — deliberate slot collisions, measuring the
    paper's conflict/fallback behavior.

    workload="append" replicates opaque strings (the seed behavior);
    "kv" drives SET commands over a bounded keyspace through KVMachine
    state machines with compaction on — the real key-value regime where
    snapshots stay O(live keys) while throughput numbers stay comparable.

    read_ratio > 0 (kv workload only) turns that fraction of each burst
    into linearizable GETs on the read path (``Cluster.read``: ReadIndex,
    or zero-round leases with ``lease=True``) — reads stop consuming log
    slots and replication bandwidth, which is exactly what the read
    subsystem buys over GET-as-log-entry.

    batch_window: leader-side coalescing delay in sim-ms, or the string
    "adaptive" to enable RaftConfig.adaptive_batch_window (the leader
    derives the window from the observed submit arrival rate)."""
    factory: Optional[object] = None
    snapshot_threshold = 0
    if workload == "kv":
        factory = lambda nid: KVMachine()  # noqa: E731
        snapshot_threshold = 64
    assert read_ratio == 0.0 or workload == "kv", "read_ratio needs --workload kv"
    config = RaftConfig(max_batch_entries=max(burst, 1), max_inflight_batches=4,
                        snapshot_threshold=snapshot_threshold,
                        lease_duration_ms=10_000.0 if lease else 0.0,
                        batch_window=(0.0 if batch_window == "adaptive"
                                      else float(batch_window)),
                        adaptive_batch_window=batch_window == "adaptive")
    c = Cluster(n=5, protocol=protocol, seed=seed, loss=loss,
                base_latency=5.0, jitter=1.0, msg_overhead=msg_overhead,
                config=config, state_machine_factory=factory)
    c.run_until_leader(60_000)
    c.run(1000)
    lead = c.leader()
    others = [x for x in c.nodes if x != lead]
    t_start = c.sim.now
    eids = []
    rids = []
    n_burst_reads = int(burst * read_ratio)
    # Closed-loop load: each burst is submitted the moment the previous one
    # fully commits, so elapsed time measures sustained replication rate.
    for b in range(n_bursts):
        burst_eids = []
        burst_rids = []
        n_writes = burst - n_burst_reads
        if batch:
            if proposers == "single":
                burst_eids += c.submit_batch(
                    [_command(workload, b, i) for i in range(n_writes)],
                    via=others[0])
            else:
                for k, via in enumerate(others):
                    cmds = [_command(workload, b, i) for i in range(n_writes)
                            if i % len(others) == k]
                    if cmds:
                        burst_eids += c.submit_batch(cmds, via=via)
        else:
            for i in range(n_writes):
                via = others[0] if proposers == "single" else others[i % len(others)]
                burst_eids.append(c.submit(_command(workload, b, i), via=via))
        for i in range(n_burst_reads):
            via = others[0] if proposers == "single" else others[i % len(others)]
            burst_rids.append(
                c.read(f"GET key{(b * 131 + i) % KV_KEYS}", via=via)
            )
        c.run_until_committed(burst_eids, 120_000)
        if burst_rids:
            c.run_until_reads(burst_rids, 120_000)
        eids += burst_eids
        rids += burst_rids
    c.check_log_consistency()
    # Elapsed from commit/serve timestamps, not sim.now: run_until_committed
    # only polls its stop condition every few events, and that overshoot
    # would swamp the fast (event-sparse) configurations.
    commit_times = [
        c.metrics.traces[e].first_commit_at for e in eids
        if c.metrics.traces.get(e) is not None and c.metrics.traces[e].committed
    ]
    commit_times += [
        c.reads[r]["completed_at"] for r in rids
        if c.reads[r]["completed_at"] is not None
    ]
    elapsed = (max(commit_times) - t_start) if commit_times else (c.sim.now - t_start)
    n_reads_done = sum(1 for r in rids if c.reads[r]["completed_at"] is not None)
    n_committed = len(c.metrics.latencies())
    fast_commits = c.metrics.counters.get("fast_commits", 0)
    return {
        "ops_per_sec": (n_committed + n_reads_done) / (elapsed / 1000.0),
        "committed": n_committed,
        "reads_done": n_reads_done,
        "fast_share": fast_commits / max(n_committed, 1),
        "mean_latency": c.metrics.mean_latency() or float("nan"),
        "lease_reads": c.metrics.counters.get("lease_reads", 0),
    }


def paced_run(batch_window, gap_ms: float, n_ops: int = 300, seed: int = 3,
              protocol: str = "raft") -> Dict[str, float]:
    """Open-loop paced load straight at the leader: one command every
    ``gap_ms`` of simulated time. This is the regime where the leader-side
    batch window is the ONLY coalescing in play (no client-side
    submit_batch, no fast-track bypass), so it isolates what the window
    buys: fewer RPCs (each charged ``msg_overhead``) against the latency
    cost of holding commands back. Returns messages-per-commit and mean
    commit latency; ``cost`` is their product — the network-cost x latency
    frontier a window tuner is trying to minimize."""
    config = RaftConfig(max_batch_entries=64, max_inflight_batches=4,
                        batch_window=(0.0 if batch_window == "adaptive"
                                      else float(batch_window)),
                        adaptive_batch_window=batch_window == "adaptive")
    c = Cluster(n=5, protocol=protocol, seed=seed, loss=0.0,
                base_latency=5.0, jitter=1.0, msg_overhead=MSG_OVERHEAD,
                config=config)
    c.run_until_leader(60_000)
    c.run(1000)
    lead = c.leader()
    msgs_before = c.metrics.counters.get("msgs_out", 0)
    eids = []
    # Pace against an absolute clock: Simulation.now only advances when an
    # event fires, so run(gap_ms) from an unchanged `now` would re-request
    # the same window forever and collapse the pacing into one instant.
    t_next = c.sim.now
    for i in range(n_ops):
        eids.append(c.submit(f"p{i}", via=lead))
        t_next += gap_ms
        c.sim.run_until(t_next)
    c.run_until_committed(eids, 120_000)
    c.check_log_consistency()
    n_committed = sum(
        1 for e in eids
        if c.metrics.traces.get(e) is not None and c.metrics.traces[e].committed
    )
    msgs = (c.metrics.counters.get("msgs_out", 0) - msgs_before) / max(n_committed, 1)
    lat = c.metrics.mean_latency() or float("nan")
    return {
        "msgs_per_commit": msgs,
        "mean_latency": lat,
        "cost": msgs * lat,
        "committed": float(n_committed),
    }


def batching_speedup(protocol: str = "fastraft", burst: int = 64,
                     seed: int = 3, n_bursts: int = 5) -> Dict[str, float]:
    """Headline number: batched vs unbatched ops/sec at loss=0 on the same
    deterministic schedule."""
    unbatched = run(protocol, burst, n_bursts=n_bursts, loss=0.0, seed=seed,
                    batch=False)
    batched = run(protocol, burst, n_bursts=n_bursts, loss=0.0, seed=seed,
                  batch=True)
    return {
        "unbatched_ops_per_sec": unbatched["ops_per_sec"],
        "batched_ops_per_sec": batched["ops_per_sec"],
        "speedup": batched["ops_per_sec"] / max(unbatched["ops_per_sec"], 1e-9),
    }


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI mode: smaller matrix, fewer bursts")
    ap.add_argument("--json", metavar="PATH",
                    help="write result rows as JSON (CI artifact)")
    ap.add_argument("--workload", choices=("append", "kv"), default="append")
    ap.add_argument("--read-ratio", type=float, default=0.0,
                    help="fraction of each burst issued as linearizable GETs"
                         " on the read path (kv workload)")
    args = ap.parse_args(argv)
    smoke = args.smoke
    n_bursts = 2 if smoke else 5
    bursts = (16,) if smoke else (4, 16, 64)

    rows = []
    for protocol in ("raft", "fastraft"):
        for burst in bursts:
            for batch in ((True,) if smoke else (False, True)):
                r = run(protocol, burst, n_bursts=n_bursts, batch=batch)
                r.update(protocol=protocol, burst=burst, proposers="single",
                         batch=batch)
                rows.append(r)
    # The conflict regime (paper: "as long as proposals remain largely
    # non-conflicting" — here they are NOT, deliberately).
    r = run("fastraft", 16, n_bursts=n_bursts, proposers="all")
    r.update(protocol="fastraft", burst=16, proposers="all", batch=False)
    rows.append(r)
    # The key-value regime: KVMachine + compaction, snapshots O(live keys).
    kv_ratio = args.read_ratio if args.workload == "kv" else 0.0
    for batch in (False, True):
        r = run("fastraft", 16, n_bursts=n_bursts, batch=batch, workload="kv",
                read_ratio=kv_ratio)
        r.update(protocol="fastraft-kv", burst=16, proposers="single",
                 batch=batch)
        rows.append(r)
    # The read-heavy KV regime: 75% of each burst takes the linearizable
    # read path instead of the log (ReadIndex, then zero-round leases).
    for lease in (False, True):
        r = run("fastraft", 16, n_bursts=n_bursts, workload="kv",
                read_ratio=0.75, lease=lease)
        r.update(protocol="fastraft-kv-read" + ("-lease" if lease else ""),
                 burst=16, proposers="single", batch=False)
        rows.append(r)
    # Leader-side coalescing: static batch_window sweep vs adaptive
    # auto-tuning (RaftConfig.adaptive_batch_window) across arrival-rate
    # regimes. No single static window is right for every rate — a dense
    # stream wants a wide window (message economy), a sparse one wants none
    # (pure latency) — so each config is scored by the geometric mean over
    # regimes of msgs_per_commit * mean_latency. The adaptive row must
    # match or beat the best static on that score without anyone picking a
    # window by hand.
    import math
    n_paced = 60 if smoke else 300
    rates = ((0.5, "dense"), (30.0, "sparse")) if smoke else (
        (0.5, "dense"), (2.0, "medium"), (30.0, "sparse"))
    windows = (0.0, 5.0, "adaptive") if smoke else (0.0, 2.0, 5.0, 20.0, "adaptive")
    scores: Dict = {}
    for w in windows:
        label = "adaptive" if w == "adaptive" else f"{w:g}ms"
        costs = []
        for gap, regime in rates:
            r = paced_run(w, gap, n_ops=n_paced)
            costs.append(r["cost"])
            r.update(protocol=f"window-{label}-{regime}", burst=0,
                     proposers="single", batch=False, gap_ms=gap,
                     ops_per_sec=1000.0 / gap, fast_share=0.0)
            rows.append(r)
        scores[label] = math.prod(costs) ** (1.0 / len(costs))
    best_static = min(v for k, v in scores.items() if k != "adaptive")
    print("window tuning (geomean msgs_per_commit x latency; lower is better):")
    for label, v in scores.items():
        print(f"  {label}: {v:.1f}")
    print(f"adaptive batch_window: {scores['adaptive']:.1f} vs best static "
          f"{best_static:.1f} ({best_static / max(scores['adaptive'], 1e-9):.2f}x headroom)")
    rows.append({"protocol": "window_tuning", "proposers": "single", "burst": 0,
                 "batch": True, "ops_per_sec": 0.0, "fast_share": 0.0,
                 "mean_latency": 0.0, "adaptive_score": scores["adaptive"],
                 "best_static_score": best_static,
                 **{f"score_{k}": v for k, v in scores.items()}})
    print("protocol,proposers,burst,batch,ops_per_sec,fast_share,mean_latency_ms")
    for r in rows:
        print(f"{r['protocol']},{r['proposers']},{r['burst']},{int(r['batch'])},"
              f"{r['ops_per_sec']:.1f},{r['fast_share']:.2f},{r['mean_latency']:.2f}")
    s = batching_speedup(n_bursts=n_bursts)
    print(f"batching speedup at loss=0: {s['speedup']:.2f}x "
          f"({s['unbatched_ops_per_sec']:.0f} -> {s['batched_ops_per_sec']:.0f} ops/s)")
    rows.append({"protocol": "batching_speedup", "proposers": "single",
                 "burst": 64, "batch": True, **s})
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


if __name__ == "__main__":
    main()
