"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

Terms per (arch, shape, mesh) cell — all per-device quantities, since the
post-SPMD module is per-device:

  compute_s    = HLO_FLOPs_dev / 197e12
  memory_s     = HLO_bytes_dev / 819e9
  collective_s = collective_bytes_dev / 50e9

cost_analysis FLOP-counting semantics are pinned EMPIRICALLY by
``calibrate()``: a known matmul is compiled the same way and the reported
flops compared against 2*M*N*K/n_dev; the resulting factor scales every
cell (recorded in the table).

MODEL_FLOPS (useful work): train 6*N_active*D_tokens; prefill 2*N_active*D;
decode 2*N_active*B. The ratio MODEL_FLOPS / HLO_FLOPS catches
remat/dispatch/recompute waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

ARTIFACT_DIR = os.path.join("artifacts", "dryrun")


def calibrate() -> float:
    """Returns factor F such that true_flops_per_dev = reported * F.

    Runs in a SUBPROCESS (needs the 512-device platform without polluting
    this process). Cached in artifacts/dryrun/calibration.json.
    """
    cache = os.path.join(ARTIFACT_DIR, "calibration.json")
    if os.path.exists(cache):
        with open(cache) as f:
            return json.load(f)["factor"]
    import subprocess, sys
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 --xla_disable_hlo_passes=all-reduce-promotion"
import jax, jax.numpy as jnp, json
from jax.sharding import NamedSharding, PartitionSpec as P
mesh = jax.make_mesh((16, 16), ("data", "model"))
M = N = K = 4096
a = jax.ShapeDtypeStruct((M, K), jnp.bfloat16, sharding=NamedSharding(mesh, P("data", None)))
b = jax.ShapeDtypeStruct((K, N), jnp.bfloat16, sharding=NamedSharding(mesh, P(None, "model")))
c = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
flops = c.cost_analysis()["flops"]
true_per_dev = 2 * M * N * K / 256
print(json.dumps({"factor": true_per_dev / flops, "reported": flops}))
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600,
                         env={**os.environ, "PYTHONPATH": "src"})
    data = json.loads(out.stdout.strip().splitlines()[-1])
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    with open(cache, "w") as f:
        json.dump({"factor": data["factor"]}, f)
    return data["factor"]


def model_flops(cell: Dict[str, Any]) -> float:
    n = cell["active_param_count"]
    if cell["kind"] == "train":
        return 6.0 * n * cell["seq_len"] * cell["global_batch"]
    if cell["kind"] == "prefill":
        return 2.0 * n * cell["seq_len"] * cell["global_batch"]
    return 2.0 * n * cell["global_batch"]  # decode: one token per sequence


def analytic_memory_bytes(cell: Dict[str, Any]) -> float:
    """Transparent napkin HBM-traffic model per device per step.

    The HLO-walk byte count (artifacts' hlo_analysis.bytes_accessed) counts
    every instruction's operands+result at fusion granularity, which badly
    over-counts on the CPU backend (scan-internal converts/copies that a TPU
    fuses away) — so the roofline memory term uses this explicit model
    instead; the HLO number is kept in artifacts as an upper-bound
    diagnostic. Terms:

      weights: fwd + bwd reads of the TP shard (+1 regather write, train)
      optimizer (train): f32 m/v/master read+write + f32 grad write, on the
                TP x FSDP shard
      activations: ~8 boundary tensors per layer, write+read, x1.5 remat
                recompute, feature dims TP-sharded
      kv/state (decode): full cache read per emitted token
    """
    from repro.configs import registry

    cfg = registry.get(cell["arch"])
    TP, DP = 16, 16
    n_active = cell["active_param_count"]
    n_total = cell["param_count"]
    B, S = cell["global_batch"], cell["seq_len"]
    L = cfg.n_layers
    d = cfg.d_model
    tokens_dev = (B / DP) * (S if cell["kind"] != "decode" else 1)

    w_read = 2.0 * n_active / TP  # bf16 shard
    if cell["kind"] == "train":
        weights = 3.0 * w_read              # fwd + bwd + regather traffic
        optimizer = 30.0 * n_total / (TP * DP)
        acts = 24.0 * tokens_dev * (d / TP) * L * 2.0
        return weights + optimizer + acts
    if cell["kind"] == "prefill":
        return 2.0 * w_read + 12.0 * tokens_dev * (d / TP) * L * 2.0
    # decode: weights once + the cache/state sweep.
    n_attn = sum(1 for k in cfg.block_types() if k == "attn")
    cache = (B / DP) * S * n_attn * 2 * cfg.kv_dim * 2.0 / max(TP // 2, 1)
    state = 0.0
    if cfg.family in ("ssm", "hybrid"):
        state = (B / DP) * n_total / max(L, 1) * 0.1  # recurrent state sweep
    return 2.0 * w_read + cache + state


def analyze_cell(cell: Dict[str, Any], calib: float) -> Optional[Dict[str, Any]]:
    if "skipped" in cell or "error" in cell:
        return None
    deep = cell.get("hlo_analysis")
    if deep:  # trip-count-aware HLO walk (launch/hlo_analysis.py) — preferred
        flops_dev = deep["flops"]
        coll_dev = deep["collective_bytes"]
    else:  # legacy: XLA cost_analysis (counts scan bodies once) + calibration
        cost = cell.get("cost_analysis", {})
        flops_dev = cost.get("flops", float("nan")) * calib
        coll_dev = cell["collectives"]["total_bytes"]
    bytes_dev = analytic_memory_bytes(cell)  # see docstring: HLO-walk bytes
    # over-count on the CPU backend; kept in artifacts as a diagnostic.
    n_dev = cell["n_devices"]

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    finite = {k: v for k, v in terms.items() if v == v}
    dominant = max(finite, key=finite.get) if finite else "?"
    bound_s = max(finite.values()) if finite else float("nan")
    mf = model_flops(cell)
    useful_ratio = mf / (flops_dev * n_dev) if flops_dev == flops_dev else float("nan")
    # Roofline fraction: useful model FLOPs per second achievable at the
    # bottleneck, vs peak compute.
    roofline_frac = (mf / n_dev / PEAK_FLOPS) / bound_s if bound_s and bound_s == bound_s else float("nan")
    return {
        "arch": cell["arch"],
        "shape": cell["shape"],
        "mesh": cell["mesh"],
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "step_s_bound": bound_s,
        "model_flops": mf,
        "hlo_flops_global": flops_dev * n_dev,
        "useful_ratio": useful_ratio,
        "roofline_frac": roofline_frac,
        "collective_counts": (deep or {}).get(
            "collective_counts", cell["collectives"]["counts"]),
    }


ADVICE = {
    ("compute",): "reduce recompute (remat policy) / drop dispatch overhead so "
                  "HLO flops approach 6ND",
    ("memory",): "raise arithmetic intensity: larger per-device batch, fuse "
                 "elementwise chains, keep weights resident (bigger TP block)",
    ("collective",): "reshard to cut gathered bytes: reduce-scatter instead of "
                     "all-gather, overlap FSDP gathers with compute, shrink "
                     "vocab-parallel logits traffic",
}


def build_table(mesh: str = "single", calib: Optional[float] = None) -> List[Dict]:
    calib = calib if calib is not None else calibrate()
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, mesh, "*.json"))):
        with open(path) as f:
            cell = json.load(f)
        r = analyze_cell(cell, calib)
        if r is None:
            rows.append({"arch": cell["arch"], "shape": cell["shape"],
                         "mesh": cell.get("mesh", mesh),
                         "skipped": cell.get("skipped", cell.get("error"))})
        else:
            r["advice"] = ADVICE[(r["dominant"],)]
            rows.append(r)
    return rows


def main() -> List[Dict]:
    calib = calibrate()
    print(f"# calibration factor (true/reported flops): {calib:.3f}")
    rows = build_table("single", calib)
    print("arch,shape,compute_s,memory_s,collective_s,dominant,useful_ratio,roofline_frac")
    for r in rows:
        if "skipped" in r:
            print(f"{r['arch']},{r['shape']},SKIP({r['skipped'][:40]})")
            continue
        print(f"{r['arch']},{r['shape']},{r['compute_s']:.4f},{r['memory_s']:.4f},"
              f"{r['collective_s']:.4f},{r['dominant']},{r['useful_ratio']:.3f},"
              f"{r['roofline_frac']:.3f}")
    return rows


if __name__ == "__main__":
    main()
