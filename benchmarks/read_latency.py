"""Linearizable read cost: log-riding GETs vs ReadIndex vs leader leases.

The paper's KV evaluation (and CD-Raft's cross-domain argument) is
read-dominated, yet a GET that rides the replicated log pays the same
commit machinery as a write. This benchmark drives a closed-loop 90:10
read:write KV workload through three read paths on the same cluster
geometry:

- ``log``       — every GET is submitted as a log entry (the pre-read-path
                  behavior). The client sees its value once the node it
                  submitted through APPLIES the entry: replication round +
                  commit-dissemination round = ~2 quorum rounds per read.
- ``readindex`` — GETs take ``Cluster.read``: the leader confirms
                  leadership with ONE ReadIndexProbe quorum round and
                  answers from applied state: ~1 round per read.
- ``lease``     — ``RaftConfig.lease_duration_ms`` > 0: a leader holding a
                  fresh heartbeat-quorum lease answers instantly: ~0
                  rounds per read.

Two measurements, asserted in ``main`` at loss=0:

- throughput (reads submitted at the leader, the read-optimized client
  placement): the lease path sustains >= 2x the ops/sec of the log path
  on the 90:10 mix;
- service rounds (reads submitted through a follower, client-transport
  hops subtracted): ~2 -> ~1 -> ~0 across the three modes.

A loss sweep shows the read path degrading gracefully: reads retry
idempotently and never occupy log slots that must then be repaired.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

from repro.core.raft import RaftConfig
from repro.core.sim import Cluster
from repro.core.statemachine import KVMachine

ONE_WAY = 5.0   # link one-way latency (ms); one quorum round = 2 * ONE_WAY
KV_KEYS = 16


def _await(c: Cluster, done, max_time: float = 120_000.0) -> None:
    """Run the sim until ``done()`` with per-event polling. The default
    coarse stop-polling of run_until_committed overshoots by tens of sim-ms
    per await, which would drown the 0-round lease reads entirely."""
    if not done():
        c.sim.run_until(c.sim.now + max_time, stop=done, check_every=1)
    assert done()


def _mk_cluster(mode: str, protocol: str, loss: float, seed: int) -> Cluster:
    cfg = RaftConfig(
        heartbeat_interval=20.0,  # commit-dissemination cadence (both paths)
        lease_duration_ms=10_000.0 if mode == "lease" else 0.0,
        clock_skew_ms=5.0 if mode == "lease" else 0.0,
    )
    c = Cluster(n=5, protocol=protocol, seed=seed, loss=loss,
                base_latency=ONE_WAY, jitter=0.0, config=cfg,
                state_machine_factory=lambda nid: KVMachine())
    assert c.run_until_leader(60_000) is not None
    c.run(1000)
    return c


def run(mode: str, via: str = "leader", protocol: str = "fastraft",
        loss: float = 0.0, seed: int = 11, n_rounds: int = 10,
        reads_per_round: int = 9, writes_per_round: int = 1) -> Dict[str, float]:
    """Closed-loop rounds: each round commits its writes, then issues its
    reads one at a time, each awaited to CLIENT-VISIBLE completion — for
    the log path that is the submitting node applying the GET entry, for
    the read path it is the ReadReply arriving back at the origin."""
    assert mode in ("log", "readindex", "lease"), mode
    assert via in ("leader", "follower"), via
    c = _mk_cluster(mode, protocol, loss, seed)
    lead = c.leader()
    via_node = lead if via == "leader" else [n for n in c.nodes if n != lead][0]
    t_start = c.sim.now
    n_reads = n_writes = 0
    read_latencies: List[float] = []
    last_done = t_start
    for b in range(n_rounds):
        weids = [
            c.submit(f"SET key{(b * 7 + i) % KV_KEYS} v_{b}_{i}", via=lead)
            for i in range(writes_per_round)
        ]
        _await(c, lambda: all(
            c.metrics.traces.get(e) is not None and c.metrics.traces[e].committed
            for e in weids
        ))
        n_writes += len(weids)
        last_done = max(
            last_done, *[c.metrics.traces[e].first_commit_at for e in weids]
        )
        for i in range(reads_per_round):
            key = f"key{(b * 7 + i) % KV_KEYS}"
            t0 = c.sim.now
            if mode == "log":
                eid = c.submit(f"GET {key}", via=via_node)

                def done(e=eid):
                    t = c.metrics.traces.get(e)
                    return (
                        t is not None
                        and t.committed
                        and c.nodes[via_node].last_applied >= t.committed_index
                    )

                _await(c, done)
                t1 = c.sim.now
            else:
                rid = c.read(f"GET {key}", via=via_node)
                _await(c, lambda r=rid: c.reads[r]["completed_at"] is not None)
                t1 = c.reads[rid]["completed_at"]
            read_latencies.append(t1 - t0)
            last_done = max(last_done, t1)
            n_reads += 1
    c.check_log_consistency()
    elapsed = max(last_done - t_start, 1e-9)
    mean_read = sum(read_latencies) / len(read_latencies)
    # Client-transport hops that are not read service: the forward to the
    # leader (and, on the read path, the explicit reply hop; the log path's
    # "reply" is commit dissemination, which IS the service being measured).
    overhead = ONE_WAY * (0.0 if via == "leader" else (1.0 if mode == "log" else 2.0))
    ctr = c.metrics.counters
    return {
        "ops_per_sec": (n_reads + n_writes) / (elapsed / 1000.0),
        "mean_read_latency_ms": mean_read,
        "service_rounds_per_read": max(0.0, mean_read - overhead) / (2.0 * ONE_WAY),
        "reads": float(n_reads),
        "writes": float(n_writes),
        "read_probes": float(ctr.get("read_probes", 0)),
        "lease_reads": float(ctr.get("lease_reads", 0)),
        "readindex_reads": float(ctr.get("readindex_reads", 0)),
    }


def burst_run(coalesce: bool, protocol: str = "raft", loss: float = 0.0,
              seed: int = 13, n_rounds: int = 5, burst: int = 10) -> Dict[str, float]:
    """Open-loop read bursts from several followers at once: measures how
    many ReadIndexProbe quorum rounds it takes to serve a burst. With
    ``read_coalesce_window`` > 0 the leader batches every read arriving
    within the window behind ONE probe and groups the replies per origin
    (etcd-style read coalescing) — probes/read collapses from ~1 toward
    1/burst; without it each arrival fires its own probe."""
    cfg = RaftConfig(
        heartbeat_interval=20.0,
        read_coalesce_window=(2 * ONE_WAY) if coalesce else 0.0,
    )
    c = Cluster(n=5, protocol=protocol, seed=seed, loss=loss,
                base_latency=ONE_WAY, jitter=0.0, config=cfg,
                state_machine_factory=lambda nid: KVMachine())
    assert c.run_until_leader(60_000) is not None
    c.run(1000)
    lead = c.leader()
    followers = [n for n in c.nodes if n != lead]
    weid = c.submit("SET key0 v0", via=lead)
    _await(c, lambda: (
        c.metrics.traces.get(weid) is not None and c.metrics.traces[weid].committed
    ))
    p0 = c.metrics.counters.get("read_probes", 0)
    latencies: List[float] = []
    total = 0
    for _ in range(n_rounds):
        t_issue = c.sim.now
        rids = [
            c.read("GET key0", via=followers[i % len(followers)])
            for i in range(burst)
        ]
        assert c.run_until_reads(rids)
        latencies += [c.reads[r]["completed_at"] - t_issue for r in rids]
        total += len(rids)
        c.run(50.0)  # separate the bursts
    c.check_log_consistency()
    probes = c.metrics.counters.get("read_probes", 0) - p0
    return {
        "probes_per_read": probes / total,
        "mean_read_latency_ms": sum(latencies) / len(latencies),
        "reads": float(total),
        "read_probes": float(probes),
        "reply_batches": float(c.metrics.counters.get("read_reply_batches", 0)),
    }


def lease_speedup(protocol: str = "fastraft", seed: int = 11,
                  n_rounds: int = 10) -> Dict[str, float]:
    """Headline number: 90:10 read:write ops/sec at the leader, lease vs
    log path, loss=0."""
    log = run("log", via="leader", protocol=protocol, loss=0.0, seed=seed,
              n_rounds=n_rounds)
    lease = run("lease", via="leader", protocol=protocol, loss=0.0, seed=seed,
                n_rounds=n_rounds)
    return {
        "log_ops_per_sec": log["ops_per_sec"],
        "lease_ops_per_sec": lease["ops_per_sec"],
        "speedup": lease["ops_per_sec"] / max(log["ops_per_sec"], 1e-9),
    }


def scale_out_run(n_hosts: int, mode: str = "replica", seed: int = 17,
                  duration_ms: float = 4000.0, clients_per_host: int = 4,
                  write_interval_ms: float = 500.0) -> Dict[str, float]:
    """Read scale-out: 3 voters + (n_hosts - 3) learners, closed-loop read
    clients pinned to EVERY host. ``mode="replica"`` serves each read at
    its host from applied state once ``last_applied`` passes the leader's
    heartbeat-published watermark — zero leader round-trips — so aggregate
    read throughput grows with hosts while the leader sees only its
    replication traffic. ``mode="leader"`` is the scale-UP baseline: every
    read funnels through the leader's ReadIndex path.

    A trickle writer (one SET per ``write_interval_ms`` at the leader)
    keeps the watermark advancing over live commits, read:write ~99:1.
    """
    cfg = RaftConfig(
        heartbeat_interval=20.0,
        # A fresh leader on an idle cluster has no current-term commit and
        # cannot certify a watermark; the election-time noop closes that
        # startup window (DESIGN.md §10).
        election_noop=True,
    )
    c = Cluster(n=3, protocol="fastraft", seed=seed, base_latency=ONE_WAY,
                jitter=0.0, config=cfg,
                state_machine_factory=lambda nid: KVMachine())
    assert c.run_until_leader(60_000) is not None
    c.run(500)
    lead = c.leader()
    for i in range(n_hosts - 3):
        c.add_learner(f"r{i}")
    c.run(3000)  # learner catch-up + config commit
    weids = [c.submit(f"SET key{k} v0", via=lead) for k in range(KV_KEYS)]
    _await(c, lambda: all(
        c.metrics.traces.get(e) is not None and c.metrics.traces[e].committed
        for e in weids
    ))
    c.run(500)  # applies disseminate to every replica
    assert c.leader() == lead
    serving = sorted(c.nodes) if mode == "replica" else [lead]

    lead_node = c.nodes[lead]
    inbound = {"n": 0}
    orig_on_message = lead_node.on_message

    def counting_on_message(msg, now):
        inbound["n"] += 1
        return orig_on_message(msg, now)

    lead_node.on_message = counting_on_message
    try:
        t0 = c.sim.now
        t_end = t0 + duration_ms
        clients = [
            {"host": h, "rid": None, "k": (j * 5 + hi * 3) % KV_KEYS}
            for hi, h in enumerate(serving) for j in range(clients_per_host)
        ]
        n_reads = n_writes = 0
        latencies: List[float] = []
        next_write = t0
        while c.sim.now < t_end:
            if c.sim.now >= next_write:
                c.submit(f"SET key{n_writes % KV_KEYS} w{n_writes}", via=lead)
                n_writes += 1
                next_write += write_interval_ms
            for cl in clients:
                rid = cl["rid"]
                if rid is not None:
                    done_at = c.reads[rid]["completed_at"]
                    if done_at is None:
                        continue
                    n_reads += 1
                    latencies.append(done_at - c.reads[rid]["issued_at"])
                cl["k"] = (cl["k"] + 1) % KV_KEYS
                cl["rid"] = c.read(
                    f"GET key{cl['k']}", via=cl["host"],
                    mode=("replica" if mode == "replica" else "leader"),
                )
            # Poll at the node-tick cadence; sim time only advances when an
            # event pops, so a sub-tick step could spin without progressing.
            before = c.sim.now
            c.run(10.0)
            if c.sim.now <= before:
                c.run(25.0)  # jump past the next heartbeat
                assert c.sim.now > before, "simulation stalled"
    finally:
        lead_node.on_message = orig_on_message
    assert c.leader() == lead, "leadership churned mid-measurement"
    c.check_log_consistency()
    elapsed_s = (c.sim.now - t0) / 1000.0
    ctr = c.metrics.counters
    return {
        "hosts": float(n_hosts),
        "clients": float(len(clients)),
        "agg_reads_per_sec": n_reads / elapsed_s,
        "mean_read_latency_ms": (
            sum(latencies) / len(latencies) if latencies else float("inf")
        ),
        "reads": float(n_reads),
        "writes": float(n_writes),
        "leader_inbound_msgs": float(inbound["n"]),
        "leader_msgs_per_read": inbound["n"] / max(n_reads, 1),
        "replica_reads_served": float(ctr.get("replica_reads_served", 0)),
        "read_probes": float(ctr.get("read_probes", 0)),
    }


def scale_out(smoke: bool = False) -> List[Dict]:
    """The --scale-out sweep: replica-read throughput across 3/5/7/9 hosts
    plus the 3-node leader-served baseline, with two assertions:

    - aggregate read throughput grows near-linearly in hosts (9-host
      replica mode >= 2x the 3-host replica mode on the ~99:1 mix);
    - scaling out does not concentrate load: the leader's inbound messages
      PER READ SERVED at 9 hosts stay within 1.2x of the 3-node
      leader-served baseline (in practice far below it — replica reads
      never touch the leader, so its traffic is replication only).
    """
    duration = 2000.0 if smoke else 4000.0
    rows = []
    base = scale_out_run(3, mode="leader", duration_ms=duration)
    base.update(mode="leader_baseline")
    rows.append(base)
    sizes = (3, 9) if smoke else (3, 5, 7, 9)
    by_size = {}
    for n_hosts in sizes:
        r = scale_out_run(n_hosts, mode="replica", duration_ms=duration)
        r.update(mode="replica")
        by_size[n_hosts] = r
        rows.append(r)
    print("mode,hosts,agg_reads_per_sec,mean_read_latency_ms,"
          "leader_msgs_per_read,replica_reads_served")
    for r in rows:
        print(f"{r['mode']},{r['hosts']:.0f},{r['agg_reads_per_sec']:.0f},"
              f"{r['mean_read_latency_ms']:.2f},"
              f"{r['leader_msgs_per_read']:.2f},"
              f"{r['replica_reads_served']:.0f}")
    growth = (by_size[max(sizes)]["agg_reads_per_sec"]
              / max(by_size[3]["agg_reads_per_sec"], 1e-9))
    print(f"read throughput growth 3->{max(sizes)} hosts: {growth:.2f}x; "
          f"leader msgs/read {by_size[max(sizes)]['leader_msgs_per_read']:.2f} "
          f"(baseline {base['leader_msgs_per_read']:.2f})")
    assert growth >= 2.0, (growth, by_size)
    assert (by_size[max(sizes)]["leader_msgs_per_read"]
            <= 1.2 * base["leader_msgs_per_read"]), (by_size, base)
    # Replica mode must actually exercise the replica path.
    assert by_size[max(sizes)]["replica_reads_served"] > 0, by_size
    return rows


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI mode: fewer rounds, loss=0 only")
    ap.add_argument("--json", metavar="PATH",
                    help="write result rows as JSON (CI artifact)")
    ap.add_argument("--scale-out", action="store_true",
                    help="replica-read scale-out sweep (3/5/7/9 hosts) "
                         "instead of the read-path ladder")
    args = ap.parse_args(argv)
    if args.scale_out:
        rows = scale_out(smoke=args.smoke)
        if args.json:
            os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
            with open(args.json, "w") as f:
                json.dump(rows, f, indent=2)
        return rows
    n_rounds = 4 if args.smoke else 10
    losses = (0.0,) if args.smoke else (0.0, 0.05, 0.1)

    rows = []
    print("protocol,mode,via,loss,ops_per_sec,mean_read_latency_ms,"
          "service_rounds_per_read,read_probes")
    # Throughput sweep: read-optimized clients at the (fastraft) leader.
    # Rounds ladder: classic-raft follower clients — the regime the log
    # path pays full price in (the fast track already commits follower
    # GETs in 3 one-way hops, which is exactly why the paper cares; the
    # lease still beats both with zero rounds).
    cells = [("fastraft", m, "leader", loss)
             for m in ("log", "readindex", "lease") for loss in losses]
    cells += [("raft", m, "follower", 0.0)
              for m in ("log", "readindex", "lease")]
    for protocol, mode, via, loss in cells:
        r = run(mode, via=via, protocol=protocol, loss=loss, n_rounds=n_rounds)
        r.update(protocol=protocol, mode=mode, via=via, loss=loss)
        rows.append(r)
        print(f"{protocol},{mode},{via},{loss},{r['ops_per_sec']:.1f},"
              f"{r['mean_read_latency_ms']:.2f},"
              f"{r['service_rounds_per_read']:.2f},"
              f"{r['read_probes']:.0f}")
    ladder = {
        r["mode"]: r["service_rounds_per_read"]
        for r in rows
        if r["protocol"] == "raft" and r["via"] == "follower"
    }
    # The ladder the read path exists for: ~2 -> ~1 -> ~0 rounds per read.
    assert ladder["log"] >= 1.5, ladder
    assert 0.5 <= ladder["readindex"] < ladder["log"], ladder
    assert ladder["lease"] < 0.3, ladder
    s = lease_speedup(n_rounds=n_rounds)
    print(f"lease speedup over log path at loss=0 (90:10 mix): "
          f"{s['speedup']:.2f}x ({s['log_ops_per_sec']:.0f} -> "
          f"{s['lease_ops_per_sec']:.0f} ops/s)")
    assert s["speedup"] >= 2.0, s
    rows.append({"mode": "lease_speedup", "via": "leader", "loss": 0.0, **s})
    # Read coalescing: burst workload, probes per read with and without the
    # coalescing window (ROADMAP "read batching" item).
    burst_rounds = 3 if args.smoke else 6
    plain = burst_run(False, n_rounds=burst_rounds)
    coal = burst_run(True, n_rounds=burst_rounds)
    print("mode,probes_per_read,mean_read_latency_ms,reply_batches")
    for mode, r in (("readindex_burst", plain), ("coalesced", coal)):
        r.update(protocol="raft", mode=mode, via="follower", loss=0.0)
        rows.append(r)
        print(f"{mode},{r['probes_per_read']:.2f},"
              f"{r['mean_read_latency_ms']:.2f},{r['reply_batches']:.0f}")
    # One probe round must serve (most of) a coalesced burst.
    assert coal["probes_per_read"] <= 0.5 * max(plain["probes_per_read"], 1e-9), (
        plain["probes_per_read"], coal["probes_per_read"],
    )
    assert coal["reply_batches"] > 0, coal
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


if __name__ == "__main__":
    main()
