"""Simulator throughput: simulated-events/sec and wall-clock for the
canonical workload matrix, so sim speed is a tracked perf number alongside
the protocol benchmarks.

Workloads (``--smoke`` runs scaled-down versions of each):

* ``steady-N`` (N in 3/9/33): open-loop paced commit traffic plus reads —
  the flat-cluster shape every protocol benchmark uses.
* ``loss-9-P``: the steady workload across a packet-loss sweep (retransmit
  and election pressure as loss climbs).
* ``fuzz-33``: a 33-node fuzz-profile chaos workload — partition a minority,
  commit through the retained quorum, heal, and let the laggards catch up,
  with idle stretches between cycles (the shape of a real fuzz trace). Runs
  under BOTH engines and reports the slotted-over-legacy speedup: legacy
  re-scans the durable prefix per commit advance and re-sorts quorum state
  per reply, which is quadratic in trace length, so this is where the
  engine rewrite pays.
* ``chaos-100``: a 100-node, million-event chaos trace (partitions, a
  crashed host per cycle, catch-up, idle) — the CI-scale target: it must
  finish in well under a minute for 100x-bigger experiments to be routine.

``--json PATH`` writes the row list for the perf-trajectory artifact;
``--check`` enforces floors (events/sec, chaos-100 wall, fuzz-33 speedup)
and exits non-zero on regression.

Schedules are engine-independent (see tests/test_sim_equivalence.py), so
the two engines of ``fuzz-33`` retire identical event streams — the wall
ratio is pure engine cost, not workload drift.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

from repro.core.raft import RaftConfig
from repro.core.sim import Cluster
from repro.core.statemachine import KVMachine


def _fuzz_profile_config(max_batch_entries: int = 16,
                         snapshot_threshold: int = 0) -> RaftConfig:
    """The stock fuzz-profile knobs (fuzzer.FuzzProfile) minus snapshotting:
    long uncompacted logs are the regime the BlackWater-scale directions
    need, and exactly where per-advance full-log scans blow up."""
    return RaftConfig(
        pre_vote=True, check_quorum=True,
        lease_duration_ms=120.0, clock_skew_ms=20.0,
        max_batch_entries=max_batch_entries,
        snapshot_threshold=snapshot_threshold,
    )


def _row(name: str, c: Cluster, wall: float, engine: str,
         n_ops: int, **extra) -> Dict[str, float]:
    ev = c.sim.events
    r = {
        "name": name, "n": len(c.nodes), "engine": engine,
        "events": ev, "wall_s": round(wall, 4),
        "events_per_sec": round(ev / wall) if wall > 0 else 0,
        "sim_ms": round(c.sim.now, 1), "n_ops": n_ops,
    }
    r.update(extra)
    return r


def steady(n: int, steps: int, engine: str = "slotted", seed: int = 3,
           loss: float = 0.01, link_rng: str = "shared") -> Dict[str, float]:
    """Open-loop paced load: one command per 100 sim-ms through rotating
    nodes, a read every fifth step — the standard benchmark shape."""
    c = Cluster(n=n, protocol="fastraft", seed=seed, loss=loss, jitter=1.0,
                config=_fuzz_profile_config(snapshot_threshold=12),
                state_machine_factory=lambda nid: KVMachine(),
                clock_drift=0.0001, engine=engine, link_rng=link_rng)
    c.run_until_leader(60_000)
    nids = list(c.nodes)
    t0 = time.perf_counter()
    t_target = c.sim.now
    for i in range(steps):
        c.submit_batch([f"k{i}=v{i}"], via=nids[i % n])
        if i % 5 == 0:
            lead = c.leader()
            if lead:
                c.read("k0", via=lead)
        t_target += 100.0
        c.sim.run_until(t_target)
    wall = time.perf_counter() - t0
    name = f"steady-{n}" if loss == 0.01 else f"loss-{n}-{loss:g}"
    if link_rng != "shared":
        name += f"-{link_rng}"
    return _row(name, c, wall, engine, steps, loss=loss)


def fuzz_33(engine: str, cycles: int, waves: int = 20,
            seed: int = 11) -> Dict[str, float]:
    """Fuzz-profile chaos: partition 11 of 33 followers away, keep
    committing through the 22-node quorum, heal, catch the laggards up,
    idle, repeat. Long logs + deep catch-up debt is the quadratic regime
    for the legacy engine."""
    c = Cluster(n=33, protocol="raft", seed=seed, loss=0.01, jitter=1.0,
                config=_fuzz_profile_config(),
                state_machine_factory=lambda nid: KVMachine(),
                clock_skew_ms=20.0, clock_drift=0.0001, engine=engine)
    c.run_until_leader(60_000)
    nids = list(c.nodes)
    writes: List = []
    t0 = time.perf_counter()
    for cyc in range(cycles):
        lead = c.leader() or c.run_until_leader(60_000)
        minority = [x for x in nids[cyc % 3 :: 3] if x != lead][:11]
        c.partition([x for x in nids if x not in minority], minority)
        for w in range(waves):
            writes.extend(c.submit_batch(
                [f"c{cyc}w{w}k{j}=v" for j in range(25)], via=lead))
            c.run_until_committed(writes, 30_000)
        c.heal()
        c.run(1500.0)
        ok = c.run_until_committed(writes, 60_000)
        assert ok, f"fuzz-33 cycle {cyc} failed to converge"
        c.run(3000.0)
    wall = time.perf_counter() - t0
    c.check_log_consistency()
    return _row("fuzz-33", c, wall, engine, len(writes))


def chaos_100(cycles: int, seed: int = 42) -> Dict[str, float]:
    """The CI-scale target: 100 nodes, ~58k events per chaos cycle
    (partition + crash + commit waves + heal + catch-up + idle)."""
    c = Cluster(n=100, protocol="raft", seed=seed, loss=0.02, jitter=2.0,
                config=_fuzz_profile_config(max_batch_entries=32,
                                            snapshot_threshold=500),
                state_machine_factory=lambda nid: KVMachine(),
                clock_skew_ms=20.0, clock_drift=0.0001, engine="slotted")
    c.run_until_leader(60_000)
    nids = list(c.nodes)
    writes: List = []
    t0 = time.perf_counter()
    for cyc in range(cycles):
        lead = c.leader() or c.run_until_leader(60_000)
        minority = [x for x in nids[cyc % 5 :: 5] if x != lead][:33]
        c.partition([x for x in nids if x not in minority], minority)
        crashed = next(x for x in nids if x != lead and x not in minority)
        c.crash(crashed)
        for w in range(15):
            writes.extend(c.submit_batch(
                [f"c{cyc}w{w}k{j}=v" for j in range(20)], via=lead))
            c.run_until_committed(writes, 30_000)
        c.restart(crashed)
        c.heal()
        c.run(1500.0)
        ok = c.run_until_committed(writes, 60_000)
        assert ok, f"chaos-100 cycle {cyc} failed to converge"
        c.run(2000.0)
    wall = time.perf_counter() - t0
    c.check_log_consistency()
    return _row("chaos-100", c, wall, "slotted", len(writes))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down workloads for the CI lane")
    ap.add_argument("--json", metavar="PATH",
                    help="write benchmark rows as JSON")
    ap.add_argument("--check", action="store_true",
                    help="enforce perf floors; non-zero exit on regression")
    args = ap.parse_args()
    smoke = args.smoke

    rows: List[Dict[str, float]] = []
    failures: List[str] = []

    # Conservative floors: shared CI runners are several times slower than
    # a quiet dev box (local slotted rates: ~60-120k events/sec).
    floor_events_per_sec = 10_000
    floor_speedup = 2.0 if smoke else 10.0

    steps = 30 if smoke else 80
    print("== steady state ==")
    for n in ((3, 9) if smoke else (3, 9, 33)):
        r = steady(n, steps)
        rows.append(r)
        print(f"  {r['name']:>16}: {r['events_per_sec']:>8,} ev/s "
              f"({r['events']:,} events in {r['wall_s']:.2f}s)")

    print("== loss sweep (n=9) ==")
    for loss in ((0.1,) if smoke else (0.0, 0.05, 0.2)):
        r = steady(9, steps, loss=loss)
        rows.append(r)
        print(f"  {r['name']:>16}: {r['events_per_sec']:>8,} ev/s "
              f"({r['events']:,} events in {r['wall_s']:.2f}s)")

    if not smoke:
        # Vectorized per-(src,dst) link RNG (numpy batched draws): a
        # different-but-deterministic schedule, so a perf row only.
        r = steady(33, steps, link_rng="vectorized")
        rows.append(r)
        print(f"  {r['name']:>16}: {r['events_per_sec']:>8,} ev/s")

    print("== fuzz-33 (engine comparison, identical schedules) ==")
    cycles, waves = (1, 8) if smoke else (10, 20)
    slotted = fuzz_33("slotted", cycles, waves)
    legacy = fuzz_33("legacy", cycles, waves)
    if slotted["events"] != legacy["events"] or slotted["sim_ms"] != legacy["sim_ms"]:
        failures.append(
            f"fuzz-33 schedules diverged: slotted {slotted['events']} events"
            f"/{slotted['sim_ms']}ms vs legacy {legacy['events']}"
            f"/{legacy['sim_ms']}ms")
    speedup = legacy["wall_s"] / slotted["wall_s"] if slotted["wall_s"] else 0.0
    rows += [slotted, legacy,
             {"name": "fuzz-33-speedup", "speedup": round(speedup, 2),
              "slotted_wall_s": slotted["wall_s"],
              "legacy_wall_s": legacy["wall_s"],
              "events": slotted["events"]}]
    for r in (slotted, legacy):
        print(f"  {r['engine']:>8}: {r['wall_s']:6.2f}s "
              f"({r['events']:,} events, {r['events_per_sec']:,} ev/s)")
    print(f"  speedup: {speedup:.1f}x")

    print("== chaos-100 ==")
    r = chaos_100(2 if smoke else 18)
    rows.append(r)
    print(f"  {r['events']:,} events, {r['n_ops']:,} client ops in "
          f"{r['wall_s']:.2f}s ({r['events_per_sec']:,} ev/s)")

    if args.check:
        for row in rows:
            if row.get("engine") == "slotted" and \
                    row.get("events_per_sec", 0) < floor_events_per_sec:
                failures.append(
                    f"{row['name']}: {row['events_per_sec']:,} ev/s below "
                    f"floor {floor_events_per_sec:,}")
        if speedup < floor_speedup:
            failures.append(
                f"fuzz-33 speedup {speedup:.1f}x below floor "
                f"{floor_speedup:.1f}x")
        if not smoke and r["wall_s"] >= 60.0:
            failures.append(f"chaos-100 took {r['wall_s']:.1f}s (>= 60s)")

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {args.json}")

    if failures:
        print("PERF CHECK FAILED:")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    if args.check:
        print("perf floors ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
