"""Bytes-on-wire: bandwidth-frugal replication knobs vs the baseline stack.

Sweeps bandwidth-constrained links (``bytes_per_ms``) under one scripted
workload — steady write bursts plus a follower that repeatedly lags past
the compaction horizon and must catch up via InstallSnapshot — and compares
two arms that differ ONLY in the wire-efficiency knobs (DESIGN.md
section 13):

- baseline: ``RaftConfig`` knobs off — the schedule-preserving
  configuration the equivalence suite pins.
- frugal: ``delta_snapshots=True`` + ``ack_piggyback=True`` — delta
  InstallSnapshot streams against the follower's last-installed base,
  same-tick acks folded into one reply, redundant empty heartbeats
  suppressed.

An unmeasured pre-cycle gives the follower its first (full) snapshot, so
every measured catch-up in the frugal arm can negotiate a delta — the
steady state of a cluster that keeps re-catching flaky followers.

The schedule is CONVERGENCE-GATED, not wall-clocked: every write is
awaited and every lag cycle runs until the restarted follower holds the
leader's whole log again. Both arms therefore commit exactly the same
entries and finish the same logical schedule; they differ in how many
bytes the links carried (full snapshot streams vs deltas, empty
heartbeats vs suppressed ones) and in how long catch-up took — which is
where frugality turns into throughput once the link is the bottleneck.

Reported per (bandwidth, arm): bytes/commit (total bytes sent on all links
over entries committed in the measured horizon, from the per-link Recorder
accounting), committed ops/sec, and the knob counters.

``--check`` asserts the headline claims: at EVERY swept bandwidth the
frugal arm ships >= 30% fewer bytes/commit and commits no fewer ops/sec
than baseline, and at the most constrained point it commits strictly more.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

from repro.core.raft import RaftConfig
from repro.core.sim import Cluster
from repro.core.statemachine import KVMachine

MTU = 1400.0
CHUNK_BYTES = 600      # snapshot chunks small enough not to hog a thin link
BURST = 6              # writes per batch (one batched append per follower)
N_KEYS = 600           # live KV map the full snapshot must ship
VALUE_PAD = 120        # value size: full snapshot ~ N_KEYS * VALUE_PAD bytes
HOT_KEYS = 8           # keys the measured writes churn (the delta stays tiny)
BURST_PAD = 30         # measured write payload (steady traffic stays modest)
STEADY_BATCHES = 10    # awaited write batches between lag cycles
LAG_BATCHES = 25       # awaited write batches committed past the crashed victim
# Swept link bandwidths (bytes per sim-ms). At the lowest point one full
# snapshot costs seconds of link time; the highest is comfortable.
BANDWIDTHS = (40.0, 100.0, 300.0)


def _config(frugal: bool) -> RaftConfig:
    return RaftConfig(
        snapshot_chunk_bytes=CHUNK_BYTES,
        # Chunk acks drive window refill, so throughput is ack-paced:
        # window * chunk / RTT must exceed the link rate or the transfer
        # crawls regardless of bandwidth.
        snapshot_chunk_window=4,
        # Identical in both arms: on a 40 B/ms link a 1 KB append occupies
        # the wire for 25 ms, so seed-default 150 ms election timeouts
        # would read queueing delay as leader failure and churn. The
        # heartbeat doubles as the retransmission timer that rewinds the
        # chunk window to the acked offset; at the seed-default 50 ms it
        # re-sends chunks still QUEUED on a thin link and the duplicates
        # crowd out fresh data, so both arms space it out.
        heartbeat_interval=250.0,
        election_timeout_min=1500.0,
        election_timeout_max=2250.0,
        max_batch_entries=16,
        delta_snapshots=frugal,
        ack_piggyback=frugal,
    )


def run_arm(frugal: bool, bytes_per_ms: float, cycles: int,
            seed: int = 11) -> Dict[str, float]:
    """One scripted run; returns bytes/commit + ops/sec over the measured
    horizon. The schedule (submissions, crashes, compactions, restarts) is
    identical across arms — only the knobs differ — and every phase is
    gated on commitment/convergence, so both arms do the same logical work
    and the clock measures how fast each wire discipline finishes it."""
    c = Cluster(n=3, protocol="raft", seed=seed, jitter=0.0,
                bytes_per_ms=bytes_per_ms, mtu_bytes=MTU,
                config=_config(frugal), record_bytes=True,
                state_machine_factory=lambda nid: KVMachine())
    assert c.run_until_leader(60_000) is not None
    c.run(500)
    lead = c.leader()
    victim = [n for n in c.nodes if n != lead][0]
    # Seed the live key map (unmeasured). Small sub-batches: one 10-entry
    # append is ~1.6 KB — 40 ms of link time at the thinnest sweep point.
    for b in range(N_KEYS // 10):
        eids = c.submit_batch(
            [f"SET k{b * 10 + i} {'x' * VALUE_PAD}" for i in range(10)],
            via=lead,
        )
        assert c.run_until_committed(eids, 600_000)
    c.run(2000)

    def write(n_batches: int, tag: str) -> None:
        """Awaited hot-key write batches: one batched append per follower,
        committed before the next is submitted."""
        for i in range(n_batches):
            eids = c.submit_batch(
                [f"SET k{(i * BURST + j) % HOT_KEYS} "
                 f"{'y' * BURST_PAD}{tag}{i}_{j}" for j in range(BURST)],
                via=lead,
            )
            assert c.run_until_committed(eids, 600_000)

    def converge(timeout_ms: float = 600_000) -> None:
        """Run until the victim holds the leader's whole log again."""
        target = c.nodes[lead].last_log_index()
        deadline = c.sim.now + timeout_ms
        while c.nodes[victim].last_log_index() < target:
            assert c.sim.now < deadline, "victim failed to converge"
            c.run(50)

    def lag_cycle(tag: str) -> None:
        """Crash the victim, commit past it, compact the leader, restart,
        and run until the victim has fully caught up. The drain before
        restart lets retransmits queued to the dead victim clear the
        serial link so the snapshot stream is not stuck behind them."""
        c.crash(victim)
        write(LAG_BATCHES, f"{tag}b")
        c.nodes[lead].compact()
        c.run(600)
        c.restart(victim)
        converge()

    # Unmeasured pre-cycle: the victim's FIRST catch-up is a full stream in
    # both arms and leaves it holding a base the leader retains.
    lag_cycle("pre")
    c.run(1000)

    t0 = c.sim.now
    bytes0 = c.metrics.total_bytes("sent")
    commits0 = len(c.metrics.committed_at)
    for cycle in range(cycles):
        write(STEADY_BATCHES, f"a{cycle}")
        lag_cycle(f"m{cycle}")
    c.run(1000)  # fixed settle, same in both arms
    elapsed_s = (c.sim.now - t0) / 1000.0
    commits = len(c.metrics.committed_at) - commits0
    sent = c.metrics.total_bytes("sent") - bytes0
    ctr = c.metrics.counters
    return {
        "bytes_sent": float(sent),
        "commits": float(commits),
        "bytes_per_commit": sent / max(commits, 1),
        "ops_per_sec": commits / elapsed_s,
        "acks_folded": float(ctr.get("acks_folded", 0)),
        "heartbeats_suppressed": float(ctr.get("heartbeats_suppressed", 0)),
        "delta_snapshots_sent": float(ctr.get("delta_snapshots_sent", 0)),
        "snapshot_chunks_sent": float(ctr.get("snapshot_chunks_sent", 0)),
        "elections": float(ctr.get("elections", 0)),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI mode: fewer bandwidth points, fewer cycles")
    ap.add_argument("--json", metavar="PATH",
                    help="write result rows as JSON (CI artifact)")
    ap.add_argument("--check", action="store_true",
                    help="assert >=30%% bytes/commit reduction at every "
                         "bandwidth and ops/sec no worse (strictly better "
                         "at the most constrained point)")
    args = ap.parse_args(argv)
    bandwidths = BANDWIDTHS[:2] if args.smoke else BANDWIDTHS
    cycles = 2 if args.smoke else 3

    rows: List[Dict] = []
    print("bandwidth_B_per_ms,arm,bytes_per_commit,ops_per_sec,"
          "acks_folded,heartbeats_suppressed,delta_snapshots_sent")
    failures: List[str] = []
    for bw in bandwidths:
        base = run_arm(frugal=False, bytes_per_ms=bw, cycles=cycles)
        frugal = run_arm(frugal=True, bytes_per_ms=bw, cycles=cycles)
        for arm, r in (("baseline", base), ("frugal", frugal)):
            r.update(arm=arm, bytes_per_ms=bw)
            rows.append(r)
            print(f"{bw:.0f},{arm},{r['bytes_per_commit']:.1f},"
                  f"{r['ops_per_sec']:.1f},{r['acks_folded']:.0f},"
                  f"{r['heartbeats_suppressed']:.0f},"
                  f"{r['delta_snapshots_sent']:.0f}")
        reduction = 1.0 - frugal["bytes_per_commit"] / base["bytes_per_commit"]
        print(f"  -> bytes/commit -{100 * reduction:.1f}%, ops/sec "
              f"{base['ops_per_sec']:.1f} -> {frugal['ops_per_sec']:.1f}")
        if args.check:
            if reduction < 0.30:
                failures.append(
                    f"bw={bw:.0f}: bytes/commit reduction {100 * reduction:.1f}% < 30%"
                )
            if frugal["ops_per_sec"] < base["ops_per_sec"]:
                failures.append(
                    f"bw={bw:.0f}: frugal ops/sec {frugal['ops_per_sec']:.1f} "
                    f"< baseline {base['ops_per_sec']:.1f}"
                )
            if bw == min(bandwidths) and frugal["ops_per_sec"] <= base["ops_per_sec"]:
                failures.append(
                    f"bw={bw:.0f} (most constrained): frugal ops/sec "
                    f"{frugal['ops_per_sec']:.1f} not strictly above baseline "
                    f"{base['ops_per_sec']:.1f}"
                )
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
    for msg in failures:
        print(f"CHECK FAILED: {msg}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
