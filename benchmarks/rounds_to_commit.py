"""The original Fast Raft claim: fewer message rounds to commit in typical
operation. Measured exactly: loss-free network with CONSTANT one-way latency
L and zero jitter, so commit latency / L == number of serial message rounds.

Expected (M=5):
  proposer = leader:      raft 2.0 rounds (append+ack)  fastraft 2.0 (leader
                          uses the classic path — it IS the serialization point)
  proposer = follower:    raft 3.0 (forward+append+ack) fastraft 2.0
                          (propose-to-all + vote; finalize overlaps)
Commit observation point is the leader's apply (client notification adds the
same +1 hop to every variant).
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.sim import Cluster

L = 10.0


def measure(protocol: str, via_leader: bool, n: int = 5, seed: int = 7,
            n_ops: int = 10) -> float:
    c = Cluster(n=n, protocol=protocol, seed=seed, loss=0.0,
                base_latency=L, jitter=0.0)
    lead = c.run_until_leader(60_000)
    c.run(2000)
    lead = c.leader()
    via = lead if via_leader else [x for x in c.nodes if x != lead][0]
    eids = []
    for i in range(n_ops):
        eids.append(c.submit(f"r{i}", via=via))
        c.run(20 * L)  # isolate ops so rounds don't pipeline
    assert c.run_until_committed(eids, 600_000)
    lats = c.metrics.latencies()
    return sum(lats) / len(lats) / L


def main() -> List[Dict]:
    rows = []
    for protocol in ("raft", "fastraft"):
        for via_leader in (True, False):
            rounds = measure(protocol, via_leader)
            rows.append({
                "protocol": protocol,
                "proposer": "leader" if via_leader else "follower",
                "rounds": rounds,
            })
    print("protocol,proposer,rounds_to_commit")
    for r in rows:
        print(f"{r['protocol']},{r['proposer']},{r['rounds']:.2f}")
    return rows


if __name__ == "__main__":
    main()
