"""The original Fast Raft claim: fewer message rounds to commit in typical
operation. Measured exactly: loss-free network with CONSTANT one-way latency
L and zero jitter, so commit latency / L == number of serial message rounds.

Expected (M=5):
  proposer = leader:      raft 2.0 rounds (append+ack)  fastraft 2.0 (leader
                          uses the classic path — it IS the serialization point)
  proposer = follower:    raft 3.0 (forward+append+ack) fastraft 2.0
                          (propose-to-all + vote; finalize overlaps)
Commit observation point is the leader's apply (client notification adds the
same +1 hop to every variant).
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.sim import Cluster

L = 10.0


def measure(protocol: str, via_leader: bool, n: int = 5, seed: int = 7,
            n_ops: int = 10, batch_size: int = 1) -> float:
    """Mean commit latency in units of L = serial message rounds.

    With batch_size > 1, ops are submitted as multi-entry batches (one RPC
    per batch): every op in the window commits in the same number of rounds
    a single op takes, which is exactly the amortization claim — rounds per
    BATCH stay constant as rounds per OP divide by the batch size."""
    c = Cluster(n=n, protocol=protocol, seed=seed, loss=0.0,
                base_latency=L, jitter=0.0)
    lead = c.run_until_leader(60_000)
    c.run(2000)
    lead = c.leader()
    via = lead if via_leader else [x for x in c.nodes if x != lead][0]
    eids = []
    for i in range(0, n_ops, batch_size):
        cmds = [f"r{j}" for j in range(i, min(i + batch_size, n_ops))]
        if batch_size == 1:
            eids.append(c.submit(cmds[0], via=via))
        else:
            eids += c.submit_batch(cmds, via=via)
        c.run(20 * L)  # isolate batches so rounds don't pipeline
    assert c.run_until_committed(eids, 600_000)
    lats = c.metrics.latencies()
    return sum(lats) / len(lats) / L


def main() -> List[Dict]:
    rows = []
    for protocol in ("raft", "fastraft"):
        for via_leader in (True, False):
            for batch_size in (1, 8):
                rounds = measure(protocol, via_leader, batch_size=batch_size)
                rows.append({
                    "protocol": protocol,
                    "proposer": "leader" if via_leader else "follower",
                    "batch": batch_size,
                    "rounds": rounds,
                    "rounds_per_op": rounds / batch_size,
                })
    print("protocol,proposer,batch,rounds_to_commit,rounds_per_op")
    for r in rows:
        print(f"{r['protocol']},{r['proposer']},{r['batch']},{r['rounds']:.2f},"
              f"{r['rounds_per_op']:.2f}")
    return rows


if __name__ == "__main__":
    main()
