"""Paper Figure 1: mean commit latency of Raft vs Fast Raft clusters under
random packet loss (EKS + tc in the paper; seeded simulation here).

Matches the paper's setup: 3-node clusters, bursty client workload submitted
through a non-leader node (the load-tester hits a service IP, not the
leader), loss swept 0..8%. The paper's observed crossover — Fast Raft wins
below ~4% loss, loses above due to fast-track failures + fallback overhead —
is asserted by tests/test_benchmarks.py over this module's output.
"""
from __future__ import annotations

import statistics
from typing import Dict, List

from repro.core.sim import Cluster

LOSS_LEVELS = [0.0, 0.01, 0.02, 0.04, 0.06, 0.08]
N_SEEDS = 5
N_OPS = 30
BASE_LATENCY = 5.0
JITTER = 1.0


def run_cell(protocol: str, loss: float, seed: int, n_nodes: int = 3,
             n_ops: int = N_OPS) -> Dict[str, float]:
    from repro.core.raft import RaftConfig

    # Latency-proportional fast-track timeout (4 RTTs), as a deployed
    # implementation would configure — the protocol default (120 ms) is
    # sized for WAN links and would overweight each fallback here.
    cfg = RaftConfig(fast_vote_timeout=8 * BASE_LATENCY)
    c = Cluster(n=n_nodes, protocol=protocol, seed=seed, loss=loss,
                base_latency=BASE_LATENCY, jitter=JITTER, config=cfg)
    lead = c.run_until_leader(60_000)
    assert lead is not None
    c.run(1000)  # steady state
    lead = c.leader()
    proposers = [n for n in c.nodes if n != lead]
    eids = []
    for i in range(n_ops):
        eids.append(c.submit(f"op{i}", via=proposers[i % len(proposers)]))
        c.run(40.0)  # bursty-but-spaced load
    c.run_until_committed(eids, 300_000)
    c.check_log_consistency()
    lats = c.metrics.latencies()
    return {
        "mean_latency": statistics.fmean(lats) if lats else float("nan"),
        "p99_latency": c.metrics.p99_latency() or float("nan"),
        "commit_rate": c.metrics.commit_rate(),
        "fallback_fraction": c.metrics.fallback_fraction(),
    }


def sweep(n_seeds: int = N_SEEDS, n_ops: int = N_OPS) -> List[Dict]:
    rows = []
    for loss in LOSS_LEVELS:
        for protocol in ("raft", "fastraft"):
            cells = [run_cell(protocol, loss, seed=100 + s, n_ops=n_ops)
                     for s in range(n_seeds)]
            rows.append({
                "loss": loss,
                "protocol": protocol,
                "mean_latency": statistics.fmean(c["mean_latency"] for c in cells),
                "p99_latency": statistics.fmean(c["p99_latency"] for c in cells),
                "commit_rate": statistics.fmean(c["commit_rate"] for c in cells),
                "fallback_fraction": statistics.fmean(
                    c["fallback_fraction"] for c in cells),
            })
    return rows


def main() -> List[Dict]:
    rows = sweep()
    print("loss,protocol,mean_latency_ms,p99_latency_ms,commit_rate,fallback_frac")
    for r in rows:
        print(f"{r['loss']:.2f},{r['protocol']},{r['mean_latency']:.2f},"
              f"{r['p99_latency']:.2f},{r['commit_rate']:.3f},"
              f"{r['fallback_fraction']:.3f}")
    return rows


if __name__ == "__main__":
    main()
