"""Benchmark suite orchestrator: run every entry point, collect artifacts.

Runs each benchmark under ``benchmarks/`` as its own process (exactly the
way CI's bench-smoke lane does) and collects the ``--json`` artifacts into
one directory, default ``bench-out/``::

  PYTHONPATH=src python benchmarks/run.py --smoke            # CI-sized
  PYTHONPATH=src python benchmarks/run.py --out bench-out    # full grids

Each artifact lands as ``bench-out/BENCH_<name>.json`` — a JSON list of
flat row dicts (see docs/benchmarks.md for per-benchmark schemas).
Benchmarks without a ``--json`` flag (pure-CSV tables) get their stdout
captured to ``bench-out/BENCH_<name>.csv`` instead. A non-zero exit from
any benchmark (a failed internal assertion or ``--check`` floor) fails
the whole run after the remaining benchmarks finish.

After the suite, ``perf_report.py`` renders the collected artifacts into
a markdown summary (optionally against a baseline directory).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import List, Optional, Tuple

BENCH_DIR = os.path.dirname(os.path.abspath(__file__))
REPO_ROOT = os.path.dirname(BENCH_DIR)

# (name, module flags, supports --smoke, supports --json)
SUITE: List[Tuple[str, List[str], bool, bool]] = [
    ("throughput", ["--workload", "kv", "--read-ratio", "0.75"], True, True),
    ("snapshot_transfer", [], True, True),
    ("read_latency", [], True, True),
    ("read_latency_scaleout", ["--scale-out"], True, True),
    ("membership_churn", [], True, True),
    ("unreliable_scaleout", ["--check"], True, True),
    ("sim_speed", ["--check"], True, True),
    ("bytes_on_wire", ["--check"], True, True),
    ("latency_vs_loss", [], False, False),
    ("rounds_to_commit", [], False, False),
]

# Entries whose name differs from their module (same module, different flags).
MODULE_OF = {"read_latency_scaleout": "read_latency"}


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _stamp_rows(json_path: str, sha: str, wall_s: float, engine: str) -> None:
    """Embed run provenance into every artifact row (underscore keys so no
    benchmark's own schema can collide): the commit the numbers were
    measured at, how long the benchmark process took in real seconds, and
    which simulator event engine produced the schedule. Comparing two
    artifact directories without this is guesswork — perf_report deltas
    are only meaningful when each side says what it measured."""
    try:
        with open(json_path) as f:
            rows = json.load(f)
    except (OSError, ValueError):
        return
    if not isinstance(rows, list):
        return
    for r in rows:
        if isinstance(r, dict):
            r["_git_sha"] = sha
            r["_wall_clock_s"] = round(wall_s, 2)
            r["_engine"] = engine
    with open(json_path, "w") as f:
        json.dump(rows, f, indent=2)


def run_one(
    name: str, flags: List[str], smoke: bool, has_smoke: bool, has_json: bool,
    out_dir: str, git_sha: str = "unknown",
) -> int:
    module = MODULE_OF.get(name, name)
    cmd = [sys.executable, os.path.join(BENCH_DIR, f"{module}.py"), *flags]
    if smoke and has_smoke:
        cmd.append("--smoke")
    json_path = os.path.join(out_dir, f"BENCH_{name}.json")
    if has_json:
        cmd += ["--json", json_path]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    print(f"== {name}: {' '.join(cmd[1:])}")
    t0 = time.monotonic()
    proc = subprocess.run(
        cmd, cwd=REPO_ROOT, env=env, capture_output=True, text=True
    )
    wall_s = time.monotonic() - t0
    sys.stdout.write(proc.stdout)
    if proc.returncode != 0:
        sys.stdout.write(proc.stderr)
        print(f"== {name}: FAILED (exit {proc.returncode})")
    elif has_json:
        # Engine flags in the entry override the orchestrator default;
        # benchmarks that sweep engines themselves (sim_speed) also carry a
        # per-row `engine` key, which this suite-level stamp never touches.
        engine = flags[flags.index("--engine") + 1] if "--engine" in flags else "slotted"
        _stamp_rows(json_path, git_sha, wall_s, engine)
    if proc.returncode == 0 and not has_json:
        # CSV-table benchmarks: the stdout IS the artifact.
        with open(os.path.join(out_dir, f"BENCH_{name}.csv"), "w") as f:
            f.write(proc.stdout)
    return proc.returncode


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI-sized grids (what the bench-smoke lane runs)",
    )
    ap.add_argument(
        "--out", default="bench-out", metavar="DIR",
        help="artifact directory (default bench-out/)",
    )
    ap.add_argument(
        "--only", metavar="NAME",
        help="run a single suite entry by name (see --list)",
    )
    ap.add_argument(
        "--list", action="store_true", help="list suite entries and exit",
    )
    args = ap.parse_args(argv)

    if args.list:
        for name, flags, has_smoke, has_json in SUITE:
            extra = " ".join(flags)
            print(f"{name:24s} {MODULE_OF.get(name, name)}.py {extra}")
        return 0

    entries = [e for e in SUITE if args.only is None or e[0] == args.only]
    if not entries:
        print(f"unknown benchmark {args.only!r}; use --list")
        return 2
    os.makedirs(args.out, exist_ok=True)
    failures = []
    sha = _git_sha()
    for name, flags, has_smoke, has_json in entries:
        rc = run_one(name, flags, args.smoke, has_smoke, has_json, args.out,
                     git_sha=sha)
        if rc != 0:
            failures.append(name)
    print(
        f"\n{len(entries)} benchmarks, {len(failures)} failed"
        + (f": {', '.join(failures)}" if failures else "")
        + f" · artifacts in {args.out}/"
    )
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
