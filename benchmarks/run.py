"""Benchmark harness: one entry per paper table/figure + the roofline
report. Prints ``name,us_per_call,derived`` CSV rows (us_per_call is
simulated commit latency in microseconds where applicable)."""
from __future__ import annotations

import time


def main() -> None:
    rows = []

    # Figure 1: latency vs packet loss (Raft vs Fast Raft).
    from benchmarks import latency_vs_loss

    fig1 = latency_vs_loss.sweep(n_seeds=3, n_ops=20)
    for r in fig1:
        rows.append((
            f"fig1/{r['protocol']}/loss={r['loss']:.2f}",
            r["mean_latency"] * 1e3,  # sim-ms -> us
            f"commit_rate={r['commit_rate']:.3f};fallback={r['fallback_fraction']:.2f}",
        ))

    # Table: message rounds to commit (the core Fast Raft claim).
    from benchmarks import rounds_to_commit

    for proto in ("raft", "fastraft"):
        for via_leader in (True, False):
            rounds = rounds_to_commit.measure(proto, via_leader)
            rows.append((
                f"rounds/{proto}/{'leader' if via_leader else 'follower'}",
                rounds * rounds_to_commit.L * 1e3,
                f"rounds={rounds:.2f}",
            ))

    # Table: throughput under bursty load.
    from benchmarks import throughput

    for proto in ("raft", "fastraft"):
        for burst in (4, 16):
            r = throughput.run(proto, burst, n_bursts=3)
            rows.append((
                f"throughput/{proto}/burst={burst}",
                r["mean_latency"] * 1e3,
                f"ops_per_s={r['ops_per_sec']:.1f};fast_share={r['fast_share']:.2f}",
            ))

    # Roofline over dry-run artifacts (skipped gracefully if not yet run).
    try:
        from benchmarks import roofline

        table = roofline.build_table("single")
        for r in table:
            if "skipped" in r:
                rows.append((f"roofline/{r['arch']}/{r['shape']}", float("nan"),
                             "skipped"))
            else:
                rows.append((
                    f"roofline/{r['arch']}/{r['shape']}",
                    r["step_s_bound"] * 1e6,
                    f"dominant={r['dominant']};roofline_frac={r['roofline_frac']:.3f}",
                ))
    except Exception as e:  # artifacts missing
        rows.append(("roofline", float("nan"), f"unavailable:{type(e).__name__}"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
