"""Perf-iteration driver (EXPERIMENTS.md §Perf): recompile chosen cells
with implementation-knob overrides and record the roofline terms per
variant in artifacts/perf/<cell>__<variant>.json.

  PYTHONPATH=src python -m benchmarks.perf_iter --arch qwen3-4b \
      --shape decode_32k --variant tponly

Variants (knobs):
  classic   track=classic, fsdp_stream=False   (paper-faithful baseline:
            leader-mediated 2-round vote, naive whole-tree FSDP gather)
  fast      track=fast,    fsdp_stream=False   (paper's fast track fused
            into the gradient psum)
  stream    track=fast,    fsdp_stream=True    (beyond-paper: ZeRO-3 weight
            streaming inside the scan)
  fsdpserve serving with FSDP'd params          (baseline for decode cells)
  tponly    serving with TP-only params         (beyond-paper decode fix)
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512"
    " --xla_disable_hlo_passes=all-reduce-promotion"
)

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.shapes import SHAPES
from repro.launch.dryrun import _shaped, input_specs, parse_collectives
from repro.launch.mesh import make_production_mesh
from repro.models import zoo
from repro.optim.adamw import AdamWConfig
from repro.runtime import sharding as shd
from repro.runtime import spmd

OUT_DIR = os.path.join("artifacts", "perf")


def compile_train(arch, shape_name, mesh, *, track, fsdp_stream):
    cfg = registry.get(arch)
    model = zoo.build(cfg, dtype=jnp.bfloat16)
    opt_cfg = AdamWConfig()
    step_fn, _, _ = spmd.build_train_step(
        model, opt_cfg, mesh, track=track, fsdp_stream=fsdp_stream
    )
    state_tpl = jax.eval_shape(
        lambda rng: spmd.make_train_state(model, opt_cfg, rng, False),
        jax.random.PRNGKey(0),
    )
    specs = spmd.state_specs(model, opt_cfg, mesh, False)
    structs = _shaped(state_tpl, mesh, specs)
    batch = input_specs(arch, shape_name, mesh)
    return step_fn.lower(structs, batch).compile()


def compile_decode(arch, shape_name, mesh, *, fsdp):
    cfg = registry.get(arch)
    shape = SHAPES[shape_name]
    model = zoo.build(cfg, dtype=jnp.bfloat16)
    p_tpl = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = shd.tree_param_specs(p_tpl, mesh, fsdp=fsdp)
    p_structs = _shaped(p_tpl, mesh, p_specs)
    cache_tpl = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len)
    )
    c_specs = shd.tree_cache_specs(cache_tpl, mesh)
    c_structs = _shaped(cache_tpl, mesh, c_specs)
    batch = input_specs(arch, shape_name, mesh)
    fn = jax.jit(model.decode_step, donate_argnums=(1,))
    return fn.lower(p_structs, c_structs, batch).compile()


VARIANTS = {
    "classic": dict(kind="train", track="classic", fsdp_stream=False),
    "fast": dict(kind="train", track="fast", fsdp_stream=False),
    "stream": dict(kind="train", track="fast", fsdp_stream=True),
    # Mesh reshapes (same 256 chips): trade TP activation all-reduces for
    # FSDP weight gathers — the Megatron-vs-ZeRO axis.
    "mesh64x4": dict(kind="train", track="fast", fsdp_stream=True,
                     mesh_shape=(64, 4)),
    "mesh256x1": dict(kind="train", track="fast", fsdp_stream=True,
                      mesh_shape=(256, 1)),
    "fsdpserve": dict(kind="decode", fsdp=True),
    "tponly": dict(kind="decode", fsdp=False),
    "tponly64x4": dict(kind="decode", fsdp=False, mesh_shape=(64, 4)),
}


def run(arch: str, shape_name: str, variant: str):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{arch}__{shape_name}__{variant}.json")
    v = dict(VARIANTS[variant])
    kind = v.pop("kind")
    shape_override = v.pop("mesh_shape", None)
    if shape_override is not None:
        mesh = jax.make_mesh(shape_override, ("data", "model"))
    else:
        mesh = make_production_mesh()
    t0 = time.time()
    if kind == "train":
        compiled = compile_train(arch, shape_name, mesh, **v)
    else:
        compiled = compile_decode(arch, shape_name, mesh, **v)
    t_compile = time.time() - t0
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo, mesh.devices.size)
    from repro.launch import hlo_analysis
    deep = hlo_analysis.analyze(hlo, mesh.devices.size)
    out = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "compile_s": t_compile,
        "cost_analysis": {k: float(val) for k, val in cost.items()
                          if isinstance(val, (int, float))},
        "collectives": coll,
        "hlo_analysis": {k: v for k, v in deep.items() if k != "biggest_collectives"},
    }
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[perf] {arch}/{shape_name}/{variant}: "
          f"flops={deep['flops']:.3g} bytes={deep['bytes_accessed']:.3g} "
          f"coll={deep['collective_bytes']:.3g} "
          f"counts={ {k: int(v) for k, v in deep['collective_counts'].items()} }")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True, choices=list(VARIANTS))
    args = ap.parse_args()
    run(args.arch, args.shape, args.variant)


if __name__ == "__main__":
    main()
