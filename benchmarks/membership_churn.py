"""Throughput / availability during membership churn.

The paper's motivating setting is a *dynamic* network: nodes join, leave,
and get replaced while the system serves traffic. This benchmark drives a
continuous client load through a 5-node cluster while a reconfiguration
runs, and measures what the churn costs:

- ``max_commit_gap_ms`` — the longest interval in which NO command
  committed while the change was in flight: the availability dip. For a
  leaderless moment (replacing the leader itself) the floor is one
  election; the joint-consensus machinery must not add quorum-less gaps on
  top.
- ``gap_timeouts`` — the same dip in units of ``election_timeout_max``
  (the natural unit: any leader churn costs up to one of these).
- ``ops_per_sec_during`` vs ``ops_per_sec_steady`` — throughput paid.

Scenarios:

- ``add_node``        — learner catch-up then joint-consensus promotion
- ``remove_follower`` — joint-consensus removal of a non-leader voter
- ``replace_leader``  — replace_node of the LEADER itself (learner join +
                        one joint swap + leader step-down + re-election)

Asserted in ``main`` at loss=0: the replace-leader availability dip stays
under 2 election timeouts, no acked commit is lost, and the config-change
oracle holds (joint discipline, at most one change in flight, election
safety).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.raft import RaftConfig
from repro.core.sim import Cluster

from tests.commit_history import (
    check_commit_history,
    check_config_oracle,
    committed_acks,
)

INTERVAL = 50.0  # sim-ms between client submissions (continuous load)


def _pump(c: Cluster, via: str, eids: List, label: str, n: int) -> None:
    for i in range(n):
        eids.append(c.submit(f"{label}{i}", via=via))
        c.run(INTERVAL)


def _commit_times(c: Cluster, eids: List) -> List[float]:
    out = []
    for e in eids:
        t = c.metrics.traces.get(e)
        if t is not None and t.committed:
            out.append(t.first_commit_at)
    return sorted(out)


def run_scenario(
    scenario: str,
    protocol: str = "fastraft",
    loss: float = 0.0,
    seed: int = 23,
    steady_ops: int = 20,
    churn_ops: int = 40,
    hardened: bool = False,
) -> Dict[str, float]:
    # hardened = the adversarial-availability knobs (PreVote + CheckQuorum).
    # Replacing the leader is exactly where they could hurt: the step-down
    # re-election must not get slower because candidates now probe first.
    cfg = RaftConfig(heartbeat_interval=50.0, pre_vote=hardened, check_quorum=hardened)
    c = Cluster(
        n=5,
        protocol=protocol,
        seed=seed,
        loss=loss,
        jitter=2.0,
        config=cfg,
    )
    lead = c.run_until_leader(60_000)
    assert lead is not None
    # Load flows through a node that survives every scenario.
    via = [n for n in c.nodes if n != lead][0]
    eids: List = []
    _pump(c, via, eids, "steady", steady_ops)
    steady_times = _commit_times(c, eids)

    churn_start = c.sim.now
    if scenario == "add_node":
        c.add_node("n9")
    elif scenario == "remove_follower":
        victim = [n for n in c.nodes if n not in (lead, via)][0]
        c.remove_node(victim)
    elif scenario == "replace_leader":
        c.replace_node(lead, "n9")
    else:
        raise ValueError(scenario)
    churn_eids: List = []
    _pump(c, via, churn_eids, "churn", churn_ops)
    assert c.run_until_membership(300_000), "membership change did not finish"
    churn_end = c.sim.now
    assert c.run_until_leader(60_000) is not None
    post: List = []
    _pump(c, [n for n in c.nodes if c.nodes[n].alive][0], post, "post", 5)
    c.run(3000)

    # Availability dip: the longest commit silence while the change ran.
    all_times = _commit_times(c, eids + churn_eids + post)
    times = [t for t in all_times if t >= churn_start - INTERVAL]
    gaps = [b - a for a, b in zip(times, times[1:])] or [0.0]
    max_gap = max(gaps)
    steady_gaps = [b - a for a, b in zip(steady_times, steady_times[1:])] or [1.0]

    durable = committed_acks(c, eids + churn_eids + post)
    check_commit_history(c, acked=durable)
    n_cfg = check_config_oracle(c)
    churn_s = max((churn_end - churn_start) / 1000.0, 1e-9)
    churn_committed = len(_commit_times(c, churn_eids))
    return {
        "max_commit_gap_ms": max_gap,
        "gap_timeouts": max_gap / cfg.election_timeout_max,
        "steady_gap_ms": sum(steady_gaps) / len(steady_gaps),
        "ops_per_sec_steady": 1000.0 / INTERVAL,
        "ops_per_sec_during": churn_committed / churn_s,
        "churn_duration_ms": churn_end - churn_start,
        "acked": float(len(durable)),
        "committed": float(len(all_times)),
        "config_entries": float(n_cfg),
    }


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="quick CI mode: loss=0 only, fewer ops",
    )
    ap.add_argument(
        "--json",
        metavar="PATH",
        help="write result rows as JSON (CI artifact)",
    )
    ap.add_argument(
        "--protocol",
        default="fastraft",
        choices=("raft", "fastraft"),
    )
    args = ap.parse_args(argv)
    losses = (0.0,) if args.smoke else (0.0, 0.05, 0.1)
    churn_ops = 20 if args.smoke else 40

    rows: List[Dict] = []
    print(
        "scenario,loss,hardened,max_commit_gap_ms,gap_timeouts,"
        "churn_duration_ms,ops_per_sec_during"
    )
    for scenario in ("add_node", "remove_follower", "replace_leader"):
        for loss in losses:
            # replace_leader additionally runs with PreVote + CheckQuorum
            # on: leader replacement is the availability-sensitive path the
            # hardening must not slow down.
            variants = (False, True) if scenario == "replace_leader" else (False,)
            for hardened in variants:
                r = run_scenario(
                    scenario,
                    protocol=args.protocol,
                    loss=loss,
                    churn_ops=churn_ops,
                    hardened=hardened,
                )
                r.update(
                    scenario=scenario,
                    loss=loss,
                    protocol=args.protocol,
                    hardened=hardened,
                )
                rows.append(r)
                print(
                    f"{scenario},{loss},{int(hardened)},"
                    f"{r['max_commit_gap_ms']:.0f},"
                    f"{r['gap_timeouts']:.2f},{r['churn_duration_ms']:.0f},"
                    f"{r['ops_per_sec_during']:.1f}"
                )

    # Headline guarantee: replacing the LEADER itself costs less than two
    # election timeouts of unavailability at loss=0.
    worst = max(
        r["gap_timeouts"]
        for r in rows
        if r["scenario"] == "replace_leader" and r["loss"] == 0.0
    )
    print(f"replace_leader availability dip at loss=0: {worst:.2f} election timeouts")
    assert worst < 2.0, f"availability dip too long: {worst:.2f} timeouts"
    # The hardened row (PreVote + CheckQuorum) must clear the same bar:
    # probing before the post-swap re-election may not stretch the dip past
    # the guarantee.
    hard = [
        r["gap_timeouts"]
        for r in rows
        if r["scenario"] == "replace_leader" and r["loss"] == 0.0 and r["hardened"]
    ]
    assert hard and max(hard) < 2.0, (
        f"hardened availability dip too long: {max(hard):.2f} timeouts"
    )
    # And no worse than the unhardened baseline beyond one pre-vote probe
    # round (~half a timeout): hardening buys disruption resistance, it
    # must not buy it with leader-replacement availability.
    base = max(
        r["gap_timeouts"]
        for r in rows
        if r["scenario"] == "replace_leader"
        and r["loss"] == 0.0
        and not r["hardened"]
    )
    assert max(hard) <= base + 0.5, (
        f"hardening slowed replacement: {max(hard):.2f} vs {base:.2f} timeouts"
    )
    # Non-leader scenarios should barely dent availability.
    for r in rows:
        if r["loss"] == 0.0 and r["scenario"] != "replace_leader":
            assert r["gap_timeouts"] < 2.0, (r["scenario"], r["gap_timeouts"])

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


if __name__ == "__main__":
    main()
