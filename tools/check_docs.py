"""Docs reference checker: fail CI when the prose drifts from the tree.

Scans README.md, DESIGN.md, and docs/*.md for three kinds of claims and
verifies each against the repository itself:

1. **File references** — markdown links with relative targets, and
   backticked paths rooted in a known top-level directory
   (``src/ tests/ benchmarks/ examples/ docs/ tools/``) or a root-level
   ``*.md``. Each must exist.
2. **CLI flags** — any backticked ``--flag`` token must be defined by an
   ``add_argument`` call somewhere in ``benchmarks/*.py`` or
   ``src/repro/core/fuzzer.py``. Documenting a removed flag fails.
3. **DESIGN sections** — every ``§N`` citation must name an existing
   ``## N.`` section of DESIGN.md.

Run from the repo root (CI docs lane)::

    python tools/check_docs.py
"""
from __future__ import annotations

import os
import re
import sys
from typing import List, Set, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOCS = ["README.md", "DESIGN.md"] + sorted(
    os.path.join("docs", f)
    for f in (os.listdir(os.path.join(REPO, "docs"))
              if os.path.isdir(os.path.join(REPO, "docs")) else [])
    if f.endswith(".md")
)

# Directories whose paths the docs are expected to cite accurately.
# Artifact/output dirs (bench-out/, fuzz-out/) are deliberately absent:
# they exist only after a run.
CHECKED_ROOTS = ("src/", "tests/", "benchmarks/", "examples/", "docs/", "tools/")

FLAG_SOURCES = ["src/repro/core/fuzzer.py"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
TICK_RE = re.compile(r"`([^`]+)`")
FLAG_RE = re.compile(r"(--[a-z][a-z0-9-]*)")
SECTION_REF_RE = re.compile(r"§(\d+)")
SECTION_DEF_RE = re.compile(r"^## (\d+)\.", re.MULTILINE)
ADD_ARG_RE = re.compile(r"add_argument\(\s*['\"](--[a-z][a-z0-9-]*)['\"]")


def _read(rel: str) -> str:
    with open(os.path.join(REPO, rel)) as f:
        return f.read()


def defined_flags() -> Set[str]:
    flags: Set[str] = set()
    bench_dir = os.path.join(REPO, "benchmarks")
    sources = list(FLAG_SOURCES)
    sources += sorted(
        os.path.join("benchmarks", f)
        for f in os.listdir(bench_dir) if f.endswith(".py")
    )
    for rel in sources:
        flags.update(ADD_ARG_RE.findall(_read(rel)))
    return flags


def defined_sections() -> Set[int]:
    return {int(n) for n in SECTION_DEF_RE.findall(_read("DESIGN.md"))}


def check_doc(rel: str, flags: Set[str], sections: Set[int]) -> List[str]:
    text = _read(rel)
    errors: List[str] = []
    base = os.path.dirname(os.path.join(REPO, rel))

    def exists(target: str) -> bool:
        t = target.rstrip("/")
        for cand in (t, t.split(".")[0] + ".py"):  # `dir/file.attr` form
            if os.path.exists(os.path.join(base, cand)) or os.path.exists(
                os.path.join(REPO, cand)
            ):
                return True
        return False

    for lineno, line in enumerate(text.splitlines(), 1):
        for target in LINK_RE.findall(line):
            if target.startswith(("http://", "https://", "#", "mailto:")):
                continue
            if not exists(target.split("#")[0]):
                errors.append(f"{rel}:{lineno}: broken link target `{target}`")
        for tok in TICK_RE.findall(line):
            for flag in FLAG_RE.findall(tok):
                if flag not in flags:
                    errors.append(
                        f"{rel}:{lineno}: flag `{flag}` is not defined by any "
                        "benchmark or the fuzzer CLI"
                    )
            if any(c in tok for c in "<>*{} ("):
                continue  # placeholder / pattern / call, not a literal path
            if tok.startswith(CHECKED_ROOTS) or (
                "/" not in tok and tok.endswith(".md")
            ):
                if not exists(tok):
                    errors.append(f"{rel}:{lineno}: path `{tok}` does not exist")
        for n in SECTION_REF_RE.findall(line):
            if int(n) not in sections:
                errors.append(
                    f"{rel}:{lineno}: cites DESIGN.md §{n}, which does not exist"
                )
    return errors


def main() -> int:
    flags = defined_flags()
    sections = defined_sections()
    errors: List[str] = []
    checked: List[Tuple[str, int]] = []
    for rel in DOCS:
        if not os.path.exists(os.path.join(REPO, rel)):
            errors.append(f"{rel}: missing (the docs lane expects it)")
            continue
        errs = check_doc(rel, flags, sections)
        errors.extend(errs)
        checked.append((rel, len(errs)))
    for rel, n in checked:
        print(f"checked {rel}: {'OK' if n == 0 else f'{n} problem(s)'}")
    if errors:
        print()
        for e in errors:
            print(f"ERROR: {e}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
