"""Host-level control plane: the paper's Fast Raft as the coordination
service of the training fleet (the role etcd/Zookeeper plays elsewhere,
replaced by our own protocol implementation).

One ControlPlane instance represents this host's view of the consensus
group. In CI and single-process runs the group is an embedded simulated
cluster (real protocol, simulated transport — per DESIGN.md the transport
is pluggable); ``propose_and_wait`` drives the simulation until commit,
which makes every control decision synchronous and deterministic for tests
while exercising the exact Fast Raft code paths that run multi-host.

Control records (all committed through the log, fast track first):
  ckpt:<step>:<digest>        checkpoint manifest commits (2-phase)
  lease:<json>                data-shard lease maps
  member:<json>               membership (elastic scaling)
  straggler:<host>:<step>     straggler reports -> exclusion on quorum
  rollout:<version>           serving model-version switches
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, List, Optional

from repro.core.metrics import Recorder
from repro.core.sim import Cluster
from repro.core.types import EntryId
from repro.data.pipeline import ShardLease


class ControlPlane:
    def __init__(
        self,
        n_nodes: int = 3,
        protocol: str = "fastraft",
        seed: int = 0,
        loss: float = 0.0,
        latency: float = 0.5,
    ):
        self.cluster = Cluster(
            n=n_nodes, protocol=protocol, seed=seed, loss=loss,
            base_latency=latency, node_prefix="cp",
        )
        self.cluster.run_until_leader(60_000)
        self.applied: List[str] = []
        self._lease: Optional[ShardLease] = None
        self._members: List[str] = []
        self._straggler_counts: Dict[str, int] = {}
        self.excluded: set = set()
        # Observe applies on one node (logs are consistent by construction).
        watch = next(iter(self.cluster.nodes.values()))
        prev = watch.apply_fn

        def on_apply(index, entry, _prev=prev):
            if _prev is not None:
                _prev(index, entry)
            self._on_apply(entry.command)

        watch.apply_fn = on_apply

    # ------------------------------------------------------------- plumbing

    def propose_and_wait(self, command: str, timeout: float = 60_000.0) -> bool:
        """Propose through a NON-leader node (exercises the fast track) and
        run the simulated group until commit."""
        lead = self.cluster.leader() or self.cluster.run_until_leader(60_000)
        others = [n for n in self.cluster.nodes if n != lead]
        via = others[0] if others else lead
        eid = self.cluster.submit(command, via=via)
        ok = self.cluster.run_until_committed([eid], timeout)
        if ok:
            self.cluster.run(50)  # let applies propagate to the watch node
        return ok

    def _on_apply(self, cmd: Any) -> None:
        if not isinstance(cmd, str):
            return
        self.applied.append(cmd)
        if cmd.startswith("lease:"):
            payload = json.loads(cmd[len("lease:"):])
            self._lease = ShardLease(
                n_shards=payload["n_shards"],
                owners={int(k): v for k, v in payload["owners"].items()},
            )
        elif cmd.startswith("member:"):
            self._members = json.loads(cmd[len("member:"):])
        elif cmd.startswith("straggler:"):
            host = cmd.split(":")[1]
            self._straggler_counts[host] = self._straggler_counts.get(host, 0) + 1
            if self._straggler_counts[host] >= 3:
                self.excluded.add(host)

    # ------------------------------------------------------------ services

    def commit_checkpoint(self, record: str) -> bool:
        return self.propose_and_wait(record)

    def checkpoint_commit_fn(self) -> Callable[[str], bool]:
        return self.commit_checkpoint

    def assign_leases(self, hosts: List[str], n_shards: int) -> ShardLease:
        lease = ShardLease.balanced(hosts, n_shards)
        payload = {"n_shards": lease.n_shards, "owners": lease.owners}
        assert self.propose_and_wait("lease:" + json.dumps(payload))
        return self._lease

    def rebalance_leases(self, live_hosts: List[str]) -> ShardLease:
        assert self._lease is not None
        lease = self._lease.rebalance(live_hosts)
        payload = {"n_shards": lease.n_shards, "owners": lease.owners}
        assert self.propose_and_wait("lease:" + json.dumps(payload))
        return self._lease

    def set_members(self, members: List[str]) -> None:
        assert self.propose_and_wait("member:" + json.dumps(sorted(members)))

    def report_straggler(self, host: str, step: int) -> None:
        self.propose_and_wait(f"straggler:{host}:{step}")

    def rollout(self, version: str) -> bool:
        return self.propose_and_wait(f"rollout:{version}")

    @property
    def lease(self) -> Optional[ShardLease]:
        return self._lease

    @property
    def members(self) -> List[str]:
        return self._members

    def metrics(self) -> Recorder:
        return self.cluster.metrics
