"""Trainer: the fault-tolerant end-to-end training loop.

Wires together: model zoo + sharded SPMD train step (with the in-graph Fast
Raft commit barrier) + deterministic data pipeline under consensus-committed
shard leases + AdamW + consensus-committed checkpoints + straggler
reporting. ``train()`` is restartable: on (re)entry it restores the newest
COMMITTED checkpoint and resumes from its step with the data pipeline
re-addressed — crash-at-any-point leaves the fleet one committed checkpoint
behind, never torn.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.models import zoo
from repro.optim import adamw
from repro.runtime import sharding as shd
from repro.runtime import spmd
from repro.runtime.controlplane import ControlPlane


@dataclasses.dataclass
class TrainerConfig:
    arch: ArchConfig
    steps: int = 50
    opt: adamw.AdamWConfig = dataclasses.field(default_factory=adamw.AdamWConfig)
    global_batch: int = 8
    seq_len: int = 64
    seed: int = 0
    track: str = "fast"            # fast | classic (in-graph consensus)
    compress_pod: bool = False
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0            # 0 = only final
    keep_last: int = 3
    straggler_ms: float = 1e9      # step-time threshold for reports
    dtype: Any = jnp.float32       # fp32 on CPU test runs; bf16 on TPU
    log_every: int = 10


class Trainer:
    def __init__(
        self,
        cfg: TrainerConfig,
        mesh: Optional[Mesh] = None,
        control: Optional[ControlPlane] = None,
        host_id: str = "host0",
    ):
        self.cfg = cfg
        self.mesh = mesh or jax.make_mesh((1, 1), ("data", "model"))
        self.control = control
        self.host_id = host_id
        self.model = zoo.build(cfg.arch, dtype=cfg.dtype)
        self.step_fn, self.state_shardings, self.batch_shard_fn = spmd.build_train_step(
            self.model, cfg.opt, self.mesh, track=cfg.track,
            compress_pod=cfg.compress_pod,
        )
        self.ckpt = (
            CheckpointManager(
                cfg.ckpt_dir,
                commit_fn=control.checkpoint_commit_fn() if control else None,
                keep_last=cfg.keep_last,
            )
            if cfg.ckpt_dir
            else None
        )
        vocab = cfg.arch.vocab_size
        self.data_cfg = DataConfig(
            vocab_size=vocab, seq_len=cfg.seq_len, global_batch=cfg.global_batch,
            seed=cfg.seed,
            emit_embeddings=cfg.arch.d_model if cfg.arch.frontend else 0,
        )
        if control is not None:
            control.assign_leases([host_id], n_shards=1)

    # ----------------------------------------------------------------- state

    def init_state(self) -> spmd.TrainState:
        with self.mesh:
            state = jax.jit(
                lambda rng: spmd.make_train_state(
                    self.model, self.cfg.opt, rng, self.cfg.compress_pod
                ),
                out_shardings=self.state_shardings,
            )(jax.random.PRNGKey(self.cfg.seed))
        return state

    def restore_or_init(self) -> (int, spmd.TrainState):
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            tpl = jax.eval_shape(
                lambda rng: spmd.make_train_state(
                    self.model, self.cfg.opt, rng, self.cfg.compress_pod
                ),
                jax.random.PRNGKey(0),
            )
            step, trees = self.ckpt.restore(
                {"state": tpl}, shardings={"state": self.state_shardings}
            )
            return step, trees["state"]
        return 0, self.init_state()

    # ----------------------------------------------------------------- train

    def train(self) -> List[Dict[str, float]]:
        cfg = self.cfg
        start_step, state = self.restore_or_init()
        data = SyntheticLM(self.data_cfg, shard_id=0, n_shards=1,
                           start_step=start_step)
        it = Prefetcher(data, depth=2)
        logs: List[Dict[str, float]] = []
        with self.mesh:
            for i in range(start_step, cfg.steps):
                t0 = time.perf_counter()
                raw = next(it)
                batch = self._to_model_batch(raw)
                state, metrics = self.step_fn(state, batch)
                m = {k: float(v) for k, v in metrics.items()}
                m["wall_ms"] = (time.perf_counter() - t0) * 1e3
                m["data_step"] = i
                logs.append(m)
                if self.control is not None and m["wall_ms"] > cfg.straggler_ms:
                    self.control.report_straggler(self.host_id, i)
                if self.ckpt and cfg.ckpt_every and (i + 1) % cfg.ckpt_every == 0:
                    self.ckpt.save(i + 1, {"state": state})
            if self.ckpt:
                self.ckpt.save(cfg.steps, {"state": state}, async_=False)
                self.ckpt.wait()
        return logs

    def _to_model_batch(self, raw: Dict[str, np.ndarray]) -> Dict[str, jax.Array]:
        batch = {}
        for k, v in raw.items():
            if k == "embeddings":
                batch[k] = jnp.asarray(v, self.cfg.dtype)
            elif k == "loss_mask":
                batch[k] = jnp.asarray(v, jnp.float32)
            else:
                batch[k] = jnp.asarray(v)
        if self.cfg.arch.frontend is not None:
            batch.pop("tokens", None)
        return batch
