"""SPMD step builders: the data plane of the framework.

Train step = ``shard_map`` MANUAL over the data-parallel axes ("pod","data")
x AUTO over "model" (TP/EP stays with the SPMD partitioner). Manual DP is
what makes the paper's technique first-class in-graph:

  1. FSDP gather:   per-leaf ``all_gather`` over "data" on the leaf's FSDP
                    dim (just-in-time weights; ZeRO-3).
  2. local grad:    each DP replica differentiates its OWN microbatch loss —
                    per-replica gradients exist as real values, not just as
                    HLO internals.
  3. Fast Raft vote: each replica votes "finite & in-bounds". The vote
                    scalar is FUSED into the same psum as the non-FSDP
                    gradient leaves (zero extra rounds — the fast track);
                    FSDP leaves ride ``psum_scatter`` in the same phase.
                    ``track="classic"`` instead runs the two-round
                    gather-to-leader + broadcast baseline.
  4. quorum gate:   the optimizer update applies only on a ceil(3M/4)
                    commit; otherwise every replica rolls the step back —
                    the tentative-slot semantics of the paper, in XLA.
  5. sharded AdamW: optimizer state lives and updates in FSDP+TP shards.

Cross-pod gradient reduction can optionally ride int8 + error feedback
(compress_pod=True) — the DCN hop is the narrow one.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.collective import classic_track_commit, fast_quorum_size
from repro.optim import adamw, compression
from repro.runtime import sharding as shd

Params = Any


class TrainState(NamedTuple):
    params: Params
    opt: adamw.OptState
    ef_residual: Optional[Params]  # error-feedback (compress_pod only)


def _dp_size(mesh: Mesh) -> int:
    n = 1
    for a in shd.batch_axes(mesh):
        n *= mesh.shape[a]
    return n


def _gather_params(params, specs):
    def one(p, spec):
        d = shd.fsdp_dim(spec)
        if d is None:
            return p
        return jax.lax.all_gather(p, "data", axis=d, tiled=True)

    return jax.tree_util.tree_map(one, params, specs,
                                  is_leaf=lambda x: isinstance(x, P))


def make_train_state(model, opt_cfg: adamw.AdamWConfig, rng,
                     compress_pod: bool = False) -> TrainState:
    params = model.init(rng)
    opt = adamw.init(opt_cfg, params)
    ef = compression.init_residual(params) if compress_pod else None
    return TrainState(params, opt, ef)


def state_specs(model, opt_cfg: adamw.AdamWConfig, mesh: Mesh,
                compress_pod: bool = False):
    p_tpl = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = shd.tree_param_specs(p_tpl, mesh)
    m_specs = p_specs
    master_specs = p_specs if opt_cfg.master_weights else None
    opt_specs = adamw.OptState(m=m_specs, v=m_specs, master=master_specs,
                               step=P())
    ef_specs = p_specs if compress_pod else None
    return TrainState(p_specs, opt_specs, ef_specs)


def build_train_step(
    model,
    opt_cfg: adamw.AdamWConfig,
    mesh: Mesh,
    track: str = "fast",
    compress_pod: bool = False,
    vote_max_norm: float = 1e4,
    donate: bool = True,
    fsdp_stream: bool = True,
) -> Tuple[Callable, TrainState, Any]:
    """Returns (jitted step_fn, state_shardings, batch_sharding_fn).

    step_fn: (TrainState, batch) -> (TrainState, metrics)

    fsdp_stream=True (default): layer-group weights are all-gathered INSIDE
    the stack scan (ZeRO-3 streaming — one group of full weights live at a
    time; gradient reduce-scatter per group comes from the gather's autodiff
    transpose). False = gather the whole tree upfront (the naive baseline
    kept for the §Perf comparison; does not fit HBM for the largest archs).

    Consensus gating granularity (see DESIGN.md): per-replica exclusion via
    the fast vote applies to pre-reduction quantities (loss and the
    non-streamed leaves); streamed-stack gradients are reduced inside
    autodiff, so a poisoned replica there is caught by the global finiteness
    check -> the step rolls back (tentative-slot semantics) and repeated
    rollbacks escalate to control-plane exclusion of the host.
    """
    dp_axes = shd.batch_axes(mesh)
    M = _dp_size(mesh)
    fq = fast_quorum_size(M)
    auto_axes = tuple(a for a in mesh.axis_names if a not in dp_axes)
    if auto_axes and any(mesh.shape[a] > 1 for a in auto_axes):
        # Manual-DP x auto-TP needs a partitioner that understands manual
        # subgroups; on legacy jax that means flipping to Shardy (see
        # compat.ensure_partial_auto_partitioner).
        compat.ensure_partial_auto_partitioner()
    specs = state_specs(model, opt_cfg, mesh, compress_pod)
    p_specs = specs.params

    def make_gather_fn(stack_specs):
        """Per-group FSDP gather: specs are for STACKED leaves (leading group
        dim); inside the scan body that dim is gone, so the gather axis
        shifts down by one. After the gather the TP placement is re-PINNED
        with an explicit constraint — without it the SPMD partitioner loses
        the model-axis sharding of scan-carried weights and replicates them
        (12x FLOPs + per-group weight gathers; see EXPERIMENTS.md §Perf)."""

        def gather_group(gp):
            def one(p, spec):
                sub = P(*spec[1:])  # drop the stacked group dim
                d = shd.fsdp_dim(sub)
                if d is not None:
                    p = jax.lax.all_gather(p, "data", axis=d, tiled=True)
                pin = shd.strip_axis(sub, "data")
                if any(e is not None for e in pin) and compat.wsc_in_partial_manual_ok():
                    p = jax.lax.with_sharding_constraint(
                        p, NamedSharding(mesh, pin)
                    )
                return p

            return jax.tree_util.tree_map(one, gp, stack_specs)

        return gather_group

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, jax.Array]]:
        params = state.params

        if fsdp_stream:
            rest = {k: v for k, v in params.items() if k != "stack"}
            rest_specs = {k: p_specs[k] for k in rest}
            rest_full = _gather_params(rest, rest_specs)
            gather_fn = make_gather_fn(p_specs["stack"])

            def loss_fn(diff):
                rf, local_stack = diff
                p = dict(rf)
                p["stack"] = local_stack
                return model.loss(p, batch, gather_fn=gather_fn)

            (loss, metrics), (g_rest, g_stack) = jax.value_and_grad(
                loss_fn, has_aux=True
            )((rest_full, params["stack"]))
            # g_stack is ALREADY reduce-scattered+summed over "data" (gather
            # transpose); g_rest is per-replica and full-shaped.
            grads = dict(g_rest)
            grads["stack"] = g_stack
            prereduction = {k: g_rest[k] for k in g_rest}
        else:
            full_params = _gather_params(params, p_specs)

            def loss_fn(fp):
                return model.loss(fp, batch)

            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                full_params
            )
            prereduction = grads

        # --- Fast Raft vote: this replica's local signals.
        finite = jnp.isfinite(loss)
        sq = jnp.asarray(0.0, jnp.float32)
        for g in jax.tree_util.tree_leaves(prereduction):
            finite = jnp.logical_and(finite, jnp.all(jnp.isfinite(g)))
            sq = sq + jnp.sum(jnp.square(g.astype(jnp.float32)))
        vote = jnp.logical_and(finite, jnp.sqrt(sq) < vote_max_norm).astype(jnp.float32)

        if track == "classic":
            # Baseline: two dedicated vote rounds before the reduction.
            n_yes, committed = classic_track_commit(vote, dp_axes)
            # classic commits on majority; hold it to the same fast quorum for
            # an apples-to-apples gate.
            committed = n_yes >= jnp.asarray(fq, n_yes.dtype)

        # --- Gradient reduction phase.
        flat, treedef = jax.tree_util.tree_flatten_with_path(grads)
        spec_flat = [
            shd.param_spec(shd.path_str(path), g.shape, mesh) for path, g in flat
        ]

        def already_reduced(path, spec) -> bool:
            # Streamed-stack FSDP leaves: the all_gather transpose already
            # reduce-scattered them over "data". Stack leaves WITHOUT an FSDP
            # dim (norm scales, gate biases) stay per-replica and join the
            # fused psum like any other plain leaf.
            return (
                fsdp_stream
                and shd.path_str(path).startswith("stack")
                and shd.fsdp_dim(spec) is not None
            )

        # Per-replica Fast Raft gate on every PRE-reduction leaf: a replica
        # that voted 0 contributes exactly nothing to the committed update.
        flat = [
            (path, g if already_reduced(path, s)
             else (jnp.nan_to_num(g.astype(jnp.float32)) * vote).astype(g.dtype))
            for (path, g), s in zip(flat, spec_flat)
        ]

        fsdp_items = [(i, shd.fsdp_dim(s)) for i, s in enumerate(spec_flat)]
        reduced: list = [None] * len(flat)

        # Non-FSDP, per-replica leaves + the vote ride ONE fused psum (the
        # fast track).
        plain_idx = [i for i, d in fsdp_items if d is None]
        plain = tuple(flat[i][1] for i in plain_idx)
        if track == "fast":
            out = jax.lax.psum((*plain, vote), dp_axes)
            *plain_out, n_yes = out
            committed = n_yes >= jnp.asarray(fq, n_yes.dtype)
        else:
            plain_out = list(jax.lax.psum(plain, dp_axes)) if plain else []
        for i, g in zip(plain_idx, plain_out):
            reduced[i] = g

        # FSDP leaves: reduce_scatter over "data" (unless the streaming
        # gather transpose already did it), then the cross-pod hop
        # (optionally int8 + error feedback on the DCN link).
        ef_leaves = (
            jax.tree_util.tree_flatten_with_path(state.ef_residual)[0]
            if state.ef_residual is not None else None
        )
        new_ef_flat: Dict[int, jax.Array] = {}
        for i, d in fsdp_items:
            path, g = flat[i]
            if d is None:
                continue  # handled in the fused psum above
            pre_done = already_reduced(path, spec_flat[i])
            if (not pre_done) and "data" in dp_axes and mesh.shape["data"] > 1:
                g = jax.lax.psum_scatter(g, "data", scatter_dimension=d, tiled=True)
            if "pod" in dp_axes:
                if compress_pod and ef_leaves is not None:
                    gf = g.astype(jnp.float32) + ef_leaves[i][1]
                    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
                    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
                    new_ef_flat[i] = gf - q.astype(jnp.float32) * scale
                    qs = jax.lax.all_gather(q, "pod")          # int8 on DCN
                    ss = jax.lax.all_gather(scale, "pod")
                    g = jnp.sum(
                        qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * g.ndim),
                        axis=0,
                    ).astype(g.dtype)
                else:
                    g = jax.lax.psum(g, "pod")
            reduced[i] = g
        if state.ef_residual is not None:
            old_flat, ef_def = jax.tree_util.tree_flatten(state.ef_residual)
            new_ef = jax.tree_util.tree_unflatten(
                ef_def,
                [new_ef_flat.get(i, old_flat[i]) for i in range(len(old_flat))],
            )
        else:
            new_ef = None

        grads_r = jax.tree_util.tree_unflatten(
            treedef, reduced
        )
        denom = jnp.maximum(n_yes, 1.0)
        grads_r = jax.tree_util.tree_map(lambda g: g / denom.astype(g.dtype), grads_r)

        # Global rollback condition: quorum AND post-reduction finiteness
        # (catches poisoned contributions inside the streamed reductions).
        all_finite = jnp.asarray(True)
        for g in jax.tree_util.tree_leaves(grads_r):
            all_finite = jnp.logical_and(all_finite, jnp.all(jnp.isfinite(g)))
        committed = jnp.logical_and(committed, all_finite)

        # Global grad norm for clipping (scalar psum over FSDP shards).
        local_sq = jnp.asarray(0.0, jnp.float32)
        repl_sq = jnp.asarray(0.0, jnp.float32)
        flat_r = jax.tree_util.tree_flatten_with_path(grads_r)[0]
        for (path, g), s in zip(flat_r, spec_flat):
            gs = jnp.sum(jnp.square(g.astype(jnp.float32)))
            if shd.fsdp_dim(s) is None:
                repl_sq = repl_sq + gs
            else:
                local_sq = local_sq + gs
        grad_norm = jnp.sqrt(repl_sq + jax.lax.psum(local_sq, ("data",) if "data" in dp_axes else dp_axes))

        # --- Sharded AdamW on local shards; quorum-gated apply.
        new_params, new_opt = adamw.update(
            opt_cfg, grads_r, state.opt, params, grad_norm=grad_norm
        )
        c = committed.astype(jnp.float32)

        def gate(new, old):
            return jax.tree_util.tree_map(
                lambda a, b: (a.astype(jnp.float32) * c
                              + b.astype(jnp.float32) * (1 - c)).astype(a.dtype),
                new, old,
            )

        params_out = gate(new_params, params)
        opt_out = adamw.OptState(
            m=gate(new_opt.m, state.opt.m),
            v=gate(new_opt.v, state.opt.v),
            master=gate(new_opt.master, state.opt.master)
            if state.opt.master is not None else None,
            step=state.opt.step + committed.astype(jnp.int32),
        )

        out_metrics = {
            "loss": jax.lax.psum(jnp.nan_to_num(loss) * vote, dp_axes) / denom,
            "grad_norm": grad_norm,
            "n_yes": n_yes,
            "committed": committed.astype(jnp.float32),
            "step": opt_out.step.astype(jnp.float32),
            **{k: jax.lax.psum(jnp.nan_to_num(v) * vote, dp_axes) / denom
               for k, v in metrics.items()},
        }
        return TrainState(params_out, opt_out, new_ef), out_metrics

    # ---- wrap: shard_map manual over DP, auto over model.
    manual = tuple(dp_axes)
    state_manual = jax.tree_util.tree_map(
        lambda s: shd.manual_only(s, manual), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    batch_spec = P(manual if len(manual) > 1 else manual[0] if manual else None)

    def batch_specs_of(batch):
        return {
            k: P(*( [batch_spec[0]] + [None] * (v.ndim - 1) )) for k, v in batch.items()
        }

    def wrapped(state, batch):
        bs = batch_specs_of(batch)
        f = compat.shard_map(
            step,
            mesh=mesh,
            in_specs=(state_manual, bs),
            out_specs=(state_manual, P()),
            axis_names=set(manual),
            check_vma=False,
        )
        return f(state, batch)

    metrics_sharding = None
    state_shardings = shd.named(mesh, specs)
    jitted = jax.jit(
        wrapped,
        donate_argnums=(0,) if donate else (),
    )

    def shard_batch_spec(batch_tpl):
        return {
            k: NamedSharding(mesh, shd.batch_spec(k, v.shape, mesh))
            for k, v in batch_tpl.items()
        }

    return jitted, state_shardings, shard_batch_spec


# ------------------------------------------------------------------ serving


def build_serve_fns(model, mesh: Mesh, max_len: int):
    """(prefill_fn, decode_fn) jitted with mesh shardings; decode donates the
    cache (in-place KV update)."""

    def prefill(params, batch):
        return model.prefill(params, batch, max_len)

    def decode(params, cache, batch):
        return model.decode_step(params, cache, batch)

    p_tpl = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    # Inference: TP-only shardings (weights replicated over the data axis —
    # no per-step FSDP gathers on the decode path).
    p_specs = shd.tree_param_specs(p_tpl, mesh, fsdp=False)
    p_shard = shd.named(mesh, p_specs)

    prefill_fn = jax.jit(prefill, in_shardings=(p_shard, None))
    decode_fn = jax.jit(decode, in_shardings=(p_shard, None, None),
                        donate_argnums=(1,))
    return prefill_fn, decode_fn
