"""Sharding policy: parameter/batch/cache PartitionSpecs for the production
mesh, derived from parameter *path patterns* (MaxText-style logical rules).

Axes:
  "pod"   — data-parallel across pods (DCN). Batch only; parameters are
            replicated across pods (FSDP gathers stay on ICI).
  "data"  — in-pod data parallelism + FSDP: every large parameter leaf is
            additionally sharded over "data" on one dimension (its marked
            FSDP dim) and all-gathered just-in-time inside the step.
  "model" — tensor parallelism: attention heads / FFN hidden / vocab /
            experts (EP) / SSM channels.

Every rule validates divisibility against the actual mesh before applying an
axis; a non-divisible dim falls back to replication (recorded in the spec),
so every (arch x shape x mesh) cell lowers without manual fixes — e.g.
kv_heads=8 on a 16-way model axis shards head_dim instead.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Path = str


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    n = 1
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    for a in axes:
        n *= mesh_axis_size(mesh, a)
    return n > 1 and dim % n == 0


def _maybe(dim: int, mesh: Mesh, axes):
    """axes if they evenly divide dim, else None (replicate)."""
    return axes if _fits(dim, mesh, axes) else None


def path_str(path) -> str:
    return "/".join(
        str(getattr(k, "key", getattr(k, "idx", k))) for k in path
    )


# --------------------------------------------------------------- parameters


def param_spec(path: Path, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Right-aligned rules on the trailing dims; stacked group dims (scan
    stacking adds leading axes) are replicated."""
    nd = len(shape)
    last = path.rsplit("/", 1)[-1]

    def right(*entries):
        ent = list(entries)[-nd:] if nd <= len(entries) else [None] * (nd - len(entries)) + list(entries)
        return P(*ent)

    d_in, d_out = (shape[-2], shape[-1]) if nd >= 2 else (0, shape[-1] if nd else 0)

    # Embeddings / head.
    if path.endswith("embed/tok"):
        return right(_maybe(d_in, mesh, "model"), _maybe(d_out, mesh, "data"))
    if path.endswith("embed/head"):
        return right(_maybe(d_in, mesh, "data"), _maybe(d_out, mesh, "model"))
    if path.endswith("embed/pos"):
        return right(None, _maybe(d_out, mesh, "model"))

    # MoE experts: (..., E, d, f) / (..., E, f, d) — EP on the expert dim.
    # The "_e" suffix disambiguates from STACKED dense FFN (G, d, f).
    if last in ("w_gate_e", "w_up_e", "w_down_e"):
        E = shape[-3]
        if last == "w_down_e":
            return right(_maybe(E, mesh, "model"), None, _maybe(d_out, mesh, "data"))
        return right(_maybe(E, mesh, "model"), _maybe(d_in, mesh, "data"), None)
    if last == "router":
        return right(_maybe(d_in, mesh, "data"), None)

    # Attention projections.
    if last in ("wq", "wk", "wv") and nd >= 2:
        return right(_maybe(d_in, mesh, "data"), _maybe(d_out, mesh, "model"))
    if last == "wo":
        return right(_maybe(d_in, mesh, "model"), _maybe(d_out, mesh, "data"))
    if last in ("bq", "bk", "bv", "b_up"):
        return right(_maybe(shape[-1], mesh, "model"))

    # Dense FFN.
    if last in ("w_gate", "w_up", "ff_up"):
        return right(_maybe(d_in, mesh, "data"), _maybe(d_out, mesh, "model"))
    if last in ("w_down", "ff_down"):
        return right(_maybe(d_in, mesh, "model"), _maybe(d_out, mesh, "data"))

    # Mamba.
    if last == "in_proj":
        return right(_maybe(d_in, mesh, "data"), _maybe(d_out, mesh, "model"))
    if last == "conv_w":
        return right(None, _maybe(d_out, mesh, "model"))
    if last in ("conv_b", "dt_bias", "D"):
        return right(_maybe(shape[-1], mesh, "model"))
    if last == "x_proj":
        return right(_maybe(d_in, mesh, "model"), None)
    if last == "dt_proj":
        return right(None, _maybe(d_out, mesh, "model"))
    if last == "A_log":
        return right(_maybe(d_in, mesh, "model"), None)
    if last == "out_proj" or last == "down":
        return right(_maybe(d_in, mesh, "model"), _maybe(d_out, mesh, "data"))
    if last == "up":
        return right(_maybe(d_in, mesh, "data"), _maybe(d_out, mesh, "model"))

    # xLSTM block-diagonal projections (H, dh, dh): shard the contraction dim.
    if last in ("wq_blk", "wk_blk", "wv_blk"):
        return right(None, _maybe(d_in, mesh, "model"), None)
    if last.startswith("r_") and nd >= 3:
        return right(None, None, None)
    if last.startswith("w_") and "slstm" not in path and last not in ("w_gates",) and nd >= 2:
        return right(_maybe(d_in, mesh, "data"), _maybe(d_out, mesh, "model"))

    # sLSTM input projections.
    if last in ("w_i", "w_f", "w_z", "w_o"):
        return right(_maybe(d_in, mesh, "data"), None)

    # Everything small (norms, gates, biases): replicate.
    return P(*([None] * nd))


def strip_axis(spec: P, axis: str) -> P:
    """Drop one mesh axis from a spec (e.g. no-FSDP inference shardings)."""
    def proj(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a != axis)
            return kept if len(kept) > 1 else (kept[0] if kept else None)
        return None if entry == axis else entry

    return P(*(proj(e) for e in spec))


def tree_param_specs(template: Any, mesh: Mesh, fsdp: bool = True) -> Any:
    """fsdp=False: parameters shard over 'model' only (inference — weights
    replicated across the data axis, no per-step gathers)."""

    def f(path, leaf):
        s = param_spec(path_str(path), leaf.shape, mesh)
        return s if fsdp else strip_axis(s, "data")

    return jax.tree_util.tree_map_with_path(f, template)


# ------------------------------------------------------------ batch / cache


def batch_spec(path: Path, shape: Tuple[int, ...], mesh: Mesh) -> P:
    axes = batch_axes(mesh)
    b = _maybe(shape[0], mesh, axes) if shape else None
    if b is None and axes:
        # Try in-pod data axis alone (e.g. global_batch == data size).
        b = _maybe(shape[0], mesh, ("data",)) if shape else None
    return P(b, *([None] * (len(shape) - 1)))


def tree_batch_specs(template: Any, mesh: Mesh) -> Any:
    def f(path, leaf):
        return batch_spec(path_str(path), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(f, template)


def cache_spec(path: Path, shape: Tuple[int, ...], mesh: Mesh) -> P:
    nd = len(shape)
    last = path.rsplit("/", 1)[-1]
    if nd == 0 or last == "pos":
        return P()
    axes = batch_axes(mesh)
    # Leading dims: (groups..., B, ...). Caches are stacked over scan groups,
    # so B is the first dim whose index matches the original cache layout —
    # we mark the group dim None and detect B by convention: stacked caches
    # have paths under "layers" with leading group dim.
    stacked = "layers" in path
    b_idx = 1 if stacked and nd >= 2 else 0
    entries = [None] * nd
    B = shape[b_idx]
    b_ax = _maybe(B, mesh, axes) or _maybe(B, mesh, ("data",))
    entries[b_idx] = b_ax
    if last in ("k", "v") and nd >= 4:
        # (..., B, S, Hkv, Dh)
        s_idx, h_idx, d_idx = nd - 3, nd - 2, nd - 1
        if b_ax is None:
            entries[s_idx] = _maybe(shape[s_idx], mesh, ("data",))
        entries[h_idx] = _maybe(shape[h_idx], mesh, "model")
        if entries[h_idx] is None:
            entries[d_idx] = _maybe(shape[d_idx], mesh, "model")
    elif last == "h" and nd >= 3:            # mamba (..., B, d_in, N)
        entries[nd - 2] = _maybe(shape[nd - 2], mesh, "model")
    elif last == "conv" and nd >= 3:         # (..., B, K-1, d_in)
        entries[nd - 1] = _maybe(shape[nd - 1], mesh, "model")
    elif last == "C" and nd >= 4:            # mlstm (..., B, H, dh, dh)
        entries[nd - 2] = _maybe(shape[nd - 2], mesh, "model")
    elif last == "n" and nd >= 3:            # (..., B, H, dh)
        entries[nd - 1] = _maybe(shape[nd - 1], mesh, "model")
    return P(*entries)


def tree_cache_specs(template: Any, mesh: Mesh) -> Any:
    def f(path, leaf):
        return cache_spec(path_str(path), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(f, template)


# -------------------------------------------------------------- utilities


def manual_only(spec: P, manual_axes: Tuple[str, ...]) -> P:
    """Project a spec onto the manual axes (for shard_map in_specs)."""
    def proj(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in manual_axes)
            return kept if len(kept) > 1 else (kept[0] if kept else None)
        return entry if entry in manual_axes else None

    return P(*(proj(e) for e in spec))


def fsdp_dim(spec: P) -> Optional[int]:
    """Index of the dimension sharded over 'data' (the FSDP dim)."""
    for i, entry in enumerate(spec):
        if entry == "data" or (isinstance(entry, (tuple, list)) and "data" in entry):
            return i
    return None


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
