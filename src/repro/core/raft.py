"""Classic Raft (Ongaro & Ousterhout 2014), event-driven and transport-free.

A node never touches a socket or a clock: the harness (``repro.core.sim`` in
CI, a gRPC shim in production) delivers messages via :meth:`on_message`,
drives time via :meth:`on_tick`, and sends whatever list of ``(dst, msg)``
pairs a handler returns. This is what makes hypothesis-driven schedule
exploration possible: every interleaving the simulator can produce is a real
execution of the node code.

The class is written to be subclassed by :class:`repro.core.fast_raft.
FastRaftNode`; the hooks it overrides are marked ``# FastRaft hook``.

Replication is batched and pipelined: client bursts coalesce into
multi-entry AppendEntries batches (``RaftConfig.max_batch_entries``,
optionally buffered for ``batch_window`` sim-ms), and a leader keeps up to
``max_inflight_batches`` un-acked batches in flight per follower — each
heartbeat re-opens the pipeline from ``next_index``, doubling as
retransmission. The committed prefix compacts into a
:class:`repro.core.types.Snapshot` every ``snapshot_threshold`` applied
entries; followers that fall behind the snapshot horizon are caught up via
InstallSnapshot instead of log replay.
"""
from __future__ import annotations

import bisect
import copy
import dataclasses
import json
import random
import zlib
from typing import Any, Callable, Dict, FrozenSet, List, Optional, Tuple

from repro.core.statemachine import DedupTable, LogListMachine, StateMachine
from repro.core.types import (
    AppendEntriesArgs,
    AppendEntriesReply,
    ClusterConfig,
    Entry,
    EntryId,
    ForwardOperation,
    InstallSnapshotArgs,
    InstallSnapshotChunk,
    InstallSnapshotChunkReply,
    InstallSnapshotReply,
    Message,
    NodeId,
    PreVoteArgs,
    PreVoteReply,
    ReadIndexProbe,
    ReadIndexProbeReply,
    ReadQuery,
    ReadReply,
    RequestVoteArgs,
    RequestVoteReply,
    Role,
    Slot,
    SlotState,
    Snapshot,
    majority,
    snapshot_delta_from_bytes,
    snapshot_delta_to_bytes,
    snapshot_from_bytes,
    snapshot_to_bytes,
)

Outputs = List[Tuple[NodeId, Message]]

# Interned message dispatch: (node class, message class) -> unbound handler.
# Replaces the per-message ``getattr(self, f"_handle_{type(msg).__name__}")``
# string formatting + attribute scan on the hottest path in the simulator.
# Keyed per node class so FastRaftNode overrides resolve correctly.
_HANDLER_CACHE: Dict[Tuple[type, type], Optional[Callable]] = {}

CONFIG_PREFIX = "__config__:"  # membership-change commands
NOOP_PREFIX = "__noop__:"      # read-barrier no-op (fresh leader, no
                               # current-term commit yet); state machines
                               # ignore it like other infrastructure cmds
WITNESS_ELIDED = "__witness_elided__"  # payload placeholder in witness logs


def skeleton_entry(e: Entry) -> Entry:
    """The payload-free form of an entry a witness stores/receives: term and
    EntryId (all the protocol identity — log matching and dedup key on
    them), command elided. Infrastructure commands (configs, the read
    barrier no-op) stay intact: a witness must adopt configs at append time
    like every voter, and they are a few bytes anyway."""
    cmd = e.command
    if isinstance(cmd, str) and (
        cmd.startswith(CONFIG_PREFIX) or cmd.startswith(NOOP_PREFIX)
    ):
        return e
    if cmd == WITNESS_ELIDED:
        return e
    return Entry(e.term, WITNESS_ELIDED, e.entry_id, e.proposed_at)


def config_command(cfg) -> str:
    """Log-entry encoding of a :class:`ClusterConfig` (or, legacy, a plain
    member list, which encodes as an all-voter simple config)."""
    if not isinstance(cfg, ClusterConfig):
        cfg = ClusterConfig.of(cfg)
    return CONFIG_PREFIX + json.dumps(cfg.to_wire(), sort_keys=True)


def parse_config_command(cmd: str) -> ClusterConfig:
    """Decode a ``__config__:`` command. The legacy wire form is a bare
    comma-separated member list (pre-joint-consensus single-step changes);
    it decodes as an all-voter simple config so old logs/snapshots replay."""
    body = cmd[len(CONFIG_PREFIX):]
    if body.startswith("{"):
        return ClusterConfig.from_wire(json.loads(body))
    return ClusterConfig.of([m for m in body.split(",") if m])


def is_config_command(command) -> bool:
    return isinstance(command, str) and command.startswith(CONFIG_PREFIX)


@dataclasses.dataclass
class RaftConfig:
    election_timeout_min: float = 150.0
    election_timeout_max: float = 300.0
    heartbeat_interval: float = 50.0
    # Adversarial hardening (both default OFF so seed-era deterministic
    # schedules are untouched; the fuzzer profile and hardened deployments
    # turn them on together):
    #   pre_vote — an election timeout starts a non-term-burning PreVote
    #       probe round; only a candidate that a quorum WOULD elect (log
    #       up to date, no voter has heard from a live leader within
    #       election_timeout_min) bumps its term and campaigns for real. A
    #       rejoining partitioned/removed node therefore never inflates
    #       terms or deposes a healthy leader.
    #   check_quorum — a leader that has not heard from a commit quorum
    #       within election_timeout_max steps down, closing the
    #       partitioned-leader window (stale reads under a lease the
    #       quorum stopped renewing; clients blocked on a zombie leader).
    pre_vote: bool = False
    check_quorum: bool = False
    # Fast Raft only (kept here so one config type serves both protocols):
    fast_track: bool = False
    fast_vote_timeout: float = 120.0  # slot falls back to classic after this
    max_fast_inflight: int = 64
    # Batched + pipelined replication:
    #   max_batch_entries   — entries per AppendEntries / FastPropose window.
    #   max_inflight_batches — un-acked AppendEntries batches a leader keeps
    #       in flight per follower between heartbeats (pipeline depth; the
    #       window re-opens from next_index at every heartbeat, which doubles
    #       as retransmission).
    #   batch_window — leader-side coalescing delay (sim-ms): client commands
    #       buffer up to this long (or max_batch_entries) before one
    #       append+broadcast. 0.0 = replicate immediately (seed behavior).
    #   adaptive_batch_window — when True the leader IGNORES the static
    #       batch_window and derives the coalescing delay from the observed
    #       submit arrival rate (EWMA of inter-arrival gaps): dense traffic
    #       waits just long enough to coalesce ~half a max batch (capped at
    #       one heartbeat interval), sparse traffic replicates immediately.
    #       Default False = schedule-preserving static behavior.
    max_batch_entries: int = 64
    max_inflight_batches: int = 4
    batch_window: float = 0.0
    adaptive_batch_window: bool = False
    # Snapshot / log compaction: once the applied prefix since the last
    # snapshot reaches this many entries, fold it into a Snapshot and drop it
    # from the log. 0 = never compact (seed behavior). Followers whose
    # next_index falls below the snapshot receive InstallSnapshot.
    snapshot_threshold: int = 0
    # Chunked snapshot transfer: when > 0, InstallSnapshot streams the
    # serialized snapshot in chunks of this many bytes (offset-based resume,
    # retransmit on heartbeat) so a lossy link resumes a partial transfer
    # instead of restarting it. 0 = single-message InstallSnapshot (seed
    # behavior).
    snapshot_chunk_bytes: int = 0
    # Pipelined chunk transfer: how many chunks a leader keeps in flight per
    # follower. 1 = strictly serial (one RTT per chunk; the pre-pipelining
    # behavior); larger windows amortize the RTT across the window while the
    # follower's cursor stays authoritative (an out-of-order/lost chunk
    # rewinds the sender to the acked offset exactly once per stall).
    snapshot_chunk_window: int = 1
    # Linearizable read path. Reads never ride the log: the leader either
    # confirms leadership with one ReadIndexProbe quorum round (ReadIndex,
    # always available) or — when lease_duration_ms > 0 — serves with ZERO
    # message rounds under a fresh heartbeat-quorum lease. The effective
    # lease span is min(lease_duration_ms, election_timeout_min) minus
    # clock_skew_ms: a quorum that acked a round sent at local time t has
    # reset its election timers no earlier than t, so no rival leader can
    # exist before t + election_timeout_min; clock_skew_ms is the safety
    # margin for per-node clock drift (sim: Cluster(clock_drift=...)).
    # Lease mode also enables vote stickiness (a follower refuses to grant
    # votes within election_timeout_min of leader contact), without which
    # a disruptive candidate could be elected inside a live leader's lease.
    lease_duration_ms: float = 0.0
    clock_skew_ms: float = 0.0
    # Origin-side read retry interval (lost ReadQuery/ReadReply, leader
    # churn). 0 = use election_timeout_min.
    read_retry_timeout: float = 0.0
    # Read coalescing (etcd-style): when > 0, a leader holds reads that
    # cannot be lease-served for up to this many sim-ms and confirms the
    # whole batch with ONE ReadIndexProbe round; replies to the same origin
    # leave as one grouped ReadReply. 0 = one probe per read (seed
    # behavior). Safety is unchanged — the shared probe is still sent at or
    # after every coalesced read arrived.
    read_coalesce_window: float = 0.0
    # Append the current-term read-barrier no-op EAGERLY on winning an
    # election (standard production-Raft behavior) instead of lazily at the
    # first leader read. Off by default so seed-era deterministic schedules
    # keep their exact commit histories; replica-read deployments turn it
    # on — without a current-term commit a fresh leader can never certify a
    # new read watermark, so on an idle cluster follower/learner reads
    # issued after a leader change would stall until the next write.
    election_noop: bool = False
    # Reliability-weighted leader election (BlackWater regime, DESIGN.md
    # §12). When on, a node's election timeout draw is STRETCHED by up to
    # reliability_election_bias timeout-spreads in proportion to how
    # unreliable the node currently looks — the product of a recent-uptime
    # ramp (time since last (re)start over reliability_uptime_ms) and a
    # leader-contact regularity EWMA. Stable, well-connected nodes keep
    # their unbiased draw and therefore campaign FIRST after a leader
    # failure; recently-crashed or flaky-linked nodes yield to them. Pure
    # liveness shaping: the bias only delays candidacy, never changes who
    # CAN win, so every safety argument is untouched. Off by default —
    # the unbiased draw is bit-identical to the seed schedule.
    reliability_weighted_election: bool = False
    reliability_election_bias: float = 2.0
    reliability_uptime_ms: float = 5000.0
    # Slow-CPU apply lag (failure-profile knob, per-node via
    # sim.FailureProfile): committed entries apply only once they have
    # been committed for this many sim-ms, modeling a node whose state
    # machine cannot keep up with replication. Commit/ack latency is
    # untouched — the node acks and votes at full speed; only its applied
    # state (and thus replica-read freshness) trails. 0 = apply inline.
    apply_lag_ms: float = 0.0
    # ----- wire-efficiency knobs (DESIGN.md §13). Both default OFF: the
    # on-wire behavior, and therefore every deterministic schedule, is
    # bit-identical to the seed until a deployment opts in. -----
    # Delta snapshots: the chunked InstallSnapshot stream negotiates
    # against the follower's advertised snapshot id (AppendEntriesReply.
    # snap_index) and ships only the state DELTA against a retained base
    # the leader still holds — O(changed keys) for KVMachine. Machines
    # without delta support (LogListMachine) and followers whose base
    # drifted fall back to the full stream.
    delta_snapshots: bool = False
    # Ack piggybacking + heartbeat coalescing: followers fold same-tick
    # AppendEntries acks (and FastRaft acceptors their same-tick
    # FastVotes) into ONE reply per delivery tick, and the leader
    # suppresses the empty heartbeat to a peer that already received
    # data-bearing (round-stamped) traffic this interval.
    ack_piggyback: bool = False


@dataclasses.dataclass(slots=True)
class _SnapshotTransfer:
    """Leader-side progress of one chunked snapshot transfer to one
    follower. ``offset`` is the follower-acknowledged cursor — the resume
    point after loss or a heartbeat retransmission. ``send_cursor`` is the
    optimistic send position when a window of chunks is pipelined
    (``RaftConfig.snapshot_chunk_window`` > 1); it rewinds to ``offset``
    when the follower reports a gap (``rewind_mark`` dedups the rewind so a
    burst of stall acks from one lost chunk triggers one resend, not one
    per ack)."""

    last_index: int
    last_term: int
    data: bytes
    offset: int = 0
    send_cursor: int = 0
    rewind_mark: int = -1
    # Base snapshot id this stream is a delta against (-1 = full stream);
    # stamped on every chunk so the receiver validates applicability.
    delta_base: int = -1
    # Acked offset at the last heartbeat-triggered fresh round. Under
    # config.ack_piggyback the heartbeat only rewinds and resends when the
    # offset has not moved past this mark — i.e. the transfer actually
    # stalled. Rewinding on every interval re-sends chunks still QUEUED on
    # a serialization-limited link; the duplicates then crowd out fresh
    # chunks and the queue (and ack RTT) grows until the link collapses.
    hb_mark: int = -1


@dataclasses.dataclass(slots=True)
class _PendingRead:
    """Leader-side linearizable read awaiting confirmation + apply.

    Served once (a) a leadership-confirmation round SENT at or after
    ``arrived_at`` has been acked by a quorum (or the read was admitted
    under a valid lease), (b) an entry of the leader's current term has
    committed (the read barrier), and (c) ``last_applied >= read_index``.
    ``origin`` is the node to send the ReadReply to ("" = a client local to
    this node, delivered via ``read_done_fn``)."""

    read_id: Any
    query: Any
    origin: NodeId
    read_index: int
    arrived_at: float


@dataclasses.dataclass(slots=True)
class _ClientRead:
    """Origin-side bookkeeping for one in-flight read: enough to re-route
    the (idempotent) query after leader churn or message loss."""

    query: Any
    issued_at: float
    last_sent: float = -1.0e18


@dataclasses.dataclass(slots=True)
class _ReplicaRead:
    """A read served LOCALLY at this node (follower, learner, or leader)
    from the leader-published certified watermark — no leader round-trip.

    ``max_staleness`` is the client's staleness contract in sim-ms: the
    served state must reflect every write committed anywhere strictly
    before ``issued_at - max_staleness``. 0 = linearizable (the read waits
    for a watermark certified from a round sent at or after it was
    issued). ``target_index`` latches to the watermark index the FIRST
    time a fresh-enough watermark is adopted — without the latch a busy
    cluster's ever-advancing watermark would starve the read behind
    last_applied forever."""

    read_id: Any
    query: Any
    issued_at: float
    max_staleness: float
    target_index: int = -1
    wm_time: float = -1.0e18


class RaftNode:
    """One Raft participant. Deterministic given (config, seed, schedule)."""

    def __init__(
        self,
        node_id: NodeId,
        members: List[NodeId],
        config: Optional[RaftConfig] = None,
        seed: int = 0,
        apply_fn: Optional[Callable[[int, Entry], None]] = None,
        state_machine: Optional[StateMachine] = None,
        cluster_config: Optional[ClusterConfig] = None,
    ):
        self.id = node_id
        # The cluster configuration, a first-class log-replicated object:
        # every quorum decision flows through it (see ClusterConfig).
        # ``members`` (legacy API) becomes the all-voter initial config.
        # _config_log tracks where each active config came from —
        # [(log index, config), ...] with the base entry at position 0 —
        # so truncation can roll the config back (append-time adoption).
        self.cluster_config: ClusterConfig = cluster_config or ClusterConfig.of(members)
        self._config_log: List[Tuple[int, ClusterConfig]] = [(0, self.cluster_config)]
        self.config = config or RaftConfig()
        # crc32, NOT hash(): string hashing is randomized per process and
        # would silently break cross-process determinism of every sim.
        self.rng = random.Random(zlib.crc32(node_id.encode()) ^ (seed * 2654435761 % 2**32))
        self.apply_fn = apply_fn
        # The replicated state machine. Committed entries are applied to it
        # in index order; snapshots carry ITS reduced state, not entries.
        self.state_machine: StateMachine = state_machine or LogListMachine()
        # Compact exactly-once filter over applied EntryIds: keeps client
        # retry dedup exact after the prefix (and its ids) compacts away.
        self._dedup = DedupTable()

        # Persistent state.
        self.term = 0
        self.voted_for: Optional[NodeId] = None
        # log[p] holds absolute index snapshot_last_index + p + 1; the
        # committed prefix up to ``snapshot`` has been compacted away.
        self.log: List[Slot] = []
        self.snapshot: Optional[Snapshot] = None

        # Volatile state.
        self.role = Role.FOLLOWER
        self.commit_index = 0
        self.last_applied = 0
        self.leader_id: Optional[NodeId] = None

        # Leader state.
        self.next_index: Dict[NodeId, int] = {}
        self.match_index: Dict[NodeId, int] = {}
        # Replication pipeline: un-acked entry batches per follower and the
        # optimistic next send position (>= next_index). Both reset at every
        # heartbeat broadcast, which doubles as retransmission after loss.
        self._inflight: Dict[NodeId, int] = {}
        self._pipe_next: Dict[NodeId, int] = {}
        # Chunked snapshot transfers in progress (leader side), per follower.
        self._snap_xfer: Dict[NodeId, _SnapshotTransfer] = {}
        # Chunked snapshot being received (follower side):
        # {"last_index", "last_term", "delta_base", "data": bytearray}.
        self._incoming_snap: Optional[dict] = None
        # Delta-snapshot negotiation (config.delta_snapshots), leader side:
        # machine states of recently superseded snapshots retained as delta
        # bases (snapshot last_index -> opaque state; bounded, oldest ages
        # out), and each peer's advertised snapshot id from its
        # AppendEntriesReply.snap_index.
        self._delta_bases: Dict[int, Any] = {}
        self._peer_snap_index: Dict[NodeId, int] = {}
        # Heartbeat coalescing (config.ack_piggyback), leader side: peers
        # that received data-bearing traffic since the last broadcast —
        # their empty heartbeat this interval is redundant — and each
        # peer's match_index at the last broadcast, so the broadcast can
        # tell an ack-clocked append pipeline (progress since last beat:
        # leave it alone) from a stalled one (reset and retransmit).
        self._data_sent: set = set()
        self._hb_match: Dict[NodeId, int] = {}

        # Leader-side client-command coalescing (config.batch_window > 0).
        self._batch_buffer: List[Tuple[Any, EntryId]] = []
        self._buffered_ids: set = set()
        self._batch_deadline = 0.0
        # Submit arrival-rate estimate (adaptive_batch_window): EWMA of the
        # gap between successive _leader_append_many calls, in sim-ms.
        # -1.0 = no gap observed yet; 0.0 is a VALID estimate (same-instant
        # bursts are the densest traffic there is). A gap far above the
        # estimate is an idle pause, not a rate sample — it is skipped so
        # a burst boundary cannot balloon the next burst's window.
        self._arrival_gap_ewma = -1.0
        self._last_arrival = -1.0
        # Durable-prefix scan cursor: every slot at index <= _durable_hi is
        # known non-tentative, so _durable_prefix resumes its scan here
        # instead of re-walking the log. A slot only LEAVES the prefix on
        # truncation or snapshot install/restore, which clamp the cursor.
        self._durable_hi = 0
        # Persistence hooks, wired by the harness (e.g. checkpoint.
        # SnapshotStore): snapshot_sink(node_id, snapshot) after each
        # compaction; hard_state_sink(node_id, term, voted_for, seq,
        # floor_index, floor_term) whenever Raft hard state changes —
        # term/voted_for MUST be durable before acting on them (double-vote
        # safety) and seq must never regress (EntryId dedup safety), so a
        # host replacement restoring only persisted state stays correct.
        self.snapshot_sink: Optional[Callable[[NodeId, Snapshot], None]] = None
        self.hard_state_sink: Optional[
            Callable[[NodeId, int, Optional[NodeId], int, int, int], None]
        ] = None
        # Acked-log floor, persisted with the hard state: the highest
        # (term, index) durable log position this node has ever acknowledged
        # to a leader. The store does NOT persist the log itself, so a host
        # restored from store comes back with entries it may have helped
        # commit missing from its log; granting votes on that (empty) log
        # would let a candidate win without those entries and overwrite a
        # committed prefix. The floor makes the restored node refuse such
        # grants (see _vote_floor_position). Tentative fast-track slots are
        # excluded — they are vote-excluded by design and recovered through
        # vote replies, not through up-to-dateness.
        self._ack_floor: Tuple[int, int] = (0, 0)  # (term, index)

        # Deferred-apply queue (config.apply_lag_ms > 0): (ready_at,
        # commit_index) pairs in commit order; entries apply only once
        # their commit has aged past the lag. Always empty when the knob
        # is off, so the zero-lag apply path is untouched.
        self._apply_pending: List[Tuple[float, int]] = []
        # Reliability signal for weighted elections (config.
        # reliability_weighted_election): when this incarnation started
        # (start()/restart() stamp it) and an EWMA of leader-contact
        # regularity in [0,1] — 1.0 = every contact arrived within a few
        # heartbeat intervals of the last. Tracked unconditionally (no RNG,
        # no messages — schedule-neutral); only the timeout draw consults
        # the knob.
        self._started_at = 0.0
        self._contact_ewma = 1.0

        # Candidate state.
        self.votes_received: Dict[NodeId, RequestVoteReply] = {}
        # PreVote campaign state (config.pre_vote): the prospective term we
        # are probing for (0 = no campaign) and the voters that granted it.
        # Volatile — a probe round is never persisted.
        self._prevote_term = 0
        self._prevotes: set = set()
        # When we became leader (sim time): the quorum-contact floor for
        # CheckQuorum — winning the election IS hearing from a quorum.
        self._lead_since = -1.0e18

        # Timers (absolute sim times).
        self.election_deadline = 0.0
        self.next_heartbeat = 0.0

        # Dedup / bookkeeping.
        self._entry_index: Dict[EntryId, int] = {}
        self._pending_client: List[Tuple[Any, EntryId]] = []  # no-leader queue
        self._seq = 0
        self.alive = True
        self.metrics = None  # injected by the harness (core.metrics.Recorder)

        # ----- Linearizable read path -----
        # Simulated local clock: local_time(now) = offset + now*(1+drift).
        # Constant offsets cancel out of duration arithmetic; RATE drift is
        # the real-world hazard the lease's clock_skew_ms margin covers.
        # The harness (sim.Cluster) sets these per node.
        self.clock_offset = 0.0
        self.clock_drift = 0.0
        # Origin-side in-flight reads (this node is where the client
        # submitted); completion is delivered through read_done_fn.
        self._reads_inflight: Dict[Any, _ClientRead] = {}
        self.read_done_fn: Optional[Callable[[Any, dict], None]] = None
        # Leader-side pending reads + the quorum-round/lease accounting.
        # _hb_round is a monotone round counter shared by heartbeat
        # broadcasts and ReadIndexProbes; _round_sent maps round -> (sim
        # send time, local-clock send time, commit_index at send under the
        # term barrier, else -1); a quorum of echoes for round r confirms
        # leadership as of r's send time — which both renews the lease and
        # certifies (commit-at-send, send-time) as a read watermark.
        self._reads_pending: List[_PendingRead] = []
        self._reads_pending_ids: set = set()
        self._hb_round = 0
        self._round_sent: Dict[int, Tuple[float, float, int]] = {}
        self._peer_acked_round: Dict[NodeId, int] = {}
        self._quorum_round = 0
        self._confirmed_sent_sim = -1.0e18   # sim send time of newest
                                             # quorum-confirmed round
        self._lease_expiry_local = -1.0e18   # local-clock lease expiry
        self._noop_term = 0                  # term we appended a barrier
                                             # no-op for (at most one each)
        # Follower-side: last time a valid leader contacted us, for vote
        # stickiness under lease mode (see RaftConfig.lease_duration_ms).
        self._last_leader_contact = -1.0e18
        # Replica-read state (ANY role): the newest certified watermark
        # this node holds — adopted from current-term leader traffic
        # (AppendEntries/probes), or self-certified in _note_round_ack when
        # this node IS the leader — plus the reads waiting on it. The pair
        # claims "every write committed anywhere strictly before sim time
        # _wm_time has index <= _wm_index"; it is invalidated on every term
        # bump (leader-change invalidation) and never survives a restart.
        self._wm_index = -1
        self._wm_time = -1.0e18
        self._replica_reads: List[_ReplicaRead] = []
        self._replica_read_ids: set = set()
        # Replies generated at points with no Outputs channel (e.g. reads
        # unblocked inside _advance_commit); drained by on_message/on_tick.
        self._outbox: Outputs = []
        # Ack piggybacking (config.ack_piggyback), non-leader side: success
        # AppendEntries replies buffered per leader and folded, flushed
        # into the outbox once sim time advances past the buffering tick
        # (one reply per delivery tick; a tick always arrives within
        # tick_interval, bounding the delay). _ack_buf_time < 0 = empty.
        self._ack_buf: Dict[NodeId, AppendEntriesReply] = {}
        self._ack_buf_time = -1.0
        # Membership-change driving (leader side): set when a committed
        # final config excludes us as a voter — we broadcast the commit
        # once more, then step down (dissertation rule: a removed leader
        # manages the cluster until C_new commits, not a moment longer).
        self._pending_stepdown = False
        # Read coalescing: deadline of the probe that will confirm the
        # currently-buffered reads (0.0 = none scheduled).
        self._probe_deadline = 0.0
        # When True every hot-path shortcut below (handler dispatch table,
        # incremental quorum trackers, idle-tick early-out, sort-free round
        # pruning, shared-Entry replication) is bypassed in favor of the
        # pre-optimization code, so the legacy engine reproduces the old
        # cost profile and the equivalence suite can replay both paths.
        # Set by Cluster(engine="legacy").
        self._legacy_mode = False
        # Incremental quorum-ack tracker: per active voter set, an
        # ascending sorted list of the values _quorum_acked_round would
        # otherwise sort on every ack (self's _hb_round + peer acked
        # rounds). Lazily rebuilt when dirty (config change, leadership
        # reset, restart); single-value bisect updates otherwise.
        self._ack_dirty = True
        self._ack_sets: List[Tuple[FrozenSet[NodeId], List[int], int]] = []
        # Incremental commit-match tracker: per active voter set, the
        # ascending sorted match_index values of its non-self voters,
        # giving _leader_advance_commit its quorum threshold without a
        # per-reply set comprehension over all peers.
        self._match_dirty = True
        self._match_sets: List[
            Tuple[FrozenSet[NodeId], List[int], int, bool]
        ] = []

    # ---------------------------------------------------------------- util

    @property
    def members(self) -> List[NodeId]:
        """All replication targets (voters of every active config +
        learners), sorted. Read-only: membership changes flow through the
        log as ``__config__:`` entries, never by assignment."""
        return list(self.cluster_config.members)

    @property
    def m(self) -> int:
        return len(self.cluster_config.members)

    def quorum(self) -> int:
        """Majority of the CURRENT voter set. Debug/back-compat only: real
        quorum decisions go through ClusterConfig (joint configs need a
        majority of BOTH voter sets — see election_won/commit_ok)."""
        return majority(len(self.cluster_config.voters))

    def is_voter(self) -> bool:
        return self.cluster_config.is_voter(self.id)

    def is_witness(self) -> bool:
        """Quorum-only member (ClusterConfig.witnesses): votes and acks
        rounds, stores payload-free log skeletons, runs no state machine,
        never campaigns, never serves reads."""
        return self.cluster_config.is_witness(self.id)

    def committed_config(self) -> ClusterConfig:
        """The config as of commit_index (what a membership operation
        polls for completion)."""
        return self._config_at(self.commit_index)

    def _config_at(self, index: int) -> ClusterConfig:
        cfg = self._config_log[0][1]
        for i, c in self._config_log:
            if i <= index:
                cfg = c
            else:
                break
        return cfg

    def config_change_in_flight(self) -> bool:
        """True while an appended config entry is uncommitted OR a joint
        transition awaits its final config — the at-most-one-change rule."""
        return self._config_log[-1][0] > self.commit_index or self.cluster_config.joint

    @property
    def snapshot_last_index(self) -> int:
        return self.snapshot.last_index if self.snapshot is not None else 0

    def last_log_index(self) -> int:
        return self.snapshot_last_index + len(self.log)

    def term_at(self, index: int) -> int:
        if index == 0:
            return 0
        if self.snapshot is not None and index <= self.snapshot.last_index:
            # Interior terms compacted away with the entries (the snapshot
            # state is opaque). last_term is exact at the boundary; for
            # interior indexes it is an approximation that is only ever used
            # as a heartbeat prev_log_term while a snapshot transfer is in
            # flight — a mismatch there just makes the follower reply false,
            # and the snapshot installs either way.
            return self.snapshot.last_term
        return self.log[index - self.snapshot_last_index - 1].entry.term

    def slot(self, index: int) -> Optional[Slot]:
        """The live (uncompacted) slot at absolute ``index``; None if the
        index is beyond the log OR compacted into the snapshot."""
        p = index - self.snapshot_last_index
        if 1 <= p <= len(self.log):
            return self.log[p - 1]
        return None

    def peers(self) -> List[NodeId]:
        return [n for n in self.members if n != self.id]

    def next_seq(self) -> int:
        self._seq += 1
        self._persist_hard_state()
        return self._seq

    def _persist_hard_state(self) -> None:
        # Fold the current durable log tip into the ack floor. Raft's
        # up-to-dateness order (term, then index) keeps the floor monotone
        # even across conflict truncations: an overwrite is always issued
        # by a leader of a >= term, so the replacement tip never compares
        # below a previously persisted floor it supersedes.
        dp = self._durable_prefix()
        tip = (self.term_at(dp), dp)
        if tip > self._ack_floor:
            self._ack_floor = tip
        if self.hard_state_sink is not None:
            self.hard_state_sink(
                self.id, self.term, self.voted_for, self._seq,
                self._ack_floor[1], self._ack_floor[0],
            )

    def _seen(self, entry_id: EntryId) -> bool:
        """Has this EntryId been observed as a live log entry or an applied
        (possibly compacted) one? The client-retry dedup predicate."""
        return entry_id in self._entry_index or self._dedup.contains(entry_id)

    def _count(self, kind: str, n: int = 1) -> None:
        if self.metrics is not None:
            self.metrics.count(kind, n)

    # ---------------------------------------------------- read-path helpers

    def local_time(self, now: float) -> float:
        """This node's wall clock (sim time + offset + rate drift). Lease
        arithmetic runs on local clocks only — that is exactly the skew
        hazard clock_skew_ms must cover."""
        return self.clock_offset + now * (1.0 + self.clock_drift)

    def _lease_span(self) -> float:
        """Effective lease duration. Capped at election_timeout_min (no
        follower can grant a vote sooner than that after acking us — the
        safety bound) minus the clock-skew margin; <= 0 disables leases."""
        c = self.config
        if c.lease_duration_ms <= 0:
            return 0.0
        return min(c.lease_duration_ms, c.election_timeout_min) - c.clock_skew_ms

    def _lease_valid(self, now: float) -> bool:
        return (
            self.role is Role.LEADER
            and self._lease_span() > 0.0
            and self.local_time(now) < self._lease_expiry_local
        )

    def _term_barrier_ok(self) -> bool:
        """A leader may serve reads only after an entry of ITS term has
        committed (Raft §8): before that, commit_index may lag entries
        earlier leaders committed that we haven't learned are committed.
        When no write traffic would ever satisfy this, _leader_read appends
        a __noop__ barrier entry (once per term)."""
        return self.commit_index > 0 and self.term_at(self.commit_index) == self.term

    def _read_index(self) -> int:
        """The index a pending read must see applied before it can be
        served. FastRaft hook (fast-track commits advance commit_index
        synchronously with apply, so commit_index stays exact there too)."""
        return self.commit_index

    def _record_round(self, now: float) -> Tuple[float, float, int]:
        """The per-round record (sim send time, local send time,
        watermark-publishable commit index). The commit index is captured
        at SEND time and only under the current-term read barrier: a
        quorum echo of this round then proves (a) no rival leadership
        existed before the send — the standard ReadIndex argument — and
        (b) via the barrier, commit_index covered every prior-term commit.
        Together: every write committed anywhere strictly before the send
        time has index <= the recorded commit — a certifiable watermark."""
        return (
            now,
            self.local_time(now),
            self._read_index() if self._term_barrier_ok() else -1,
        )

    # ------------------------------------------------------ election state

    def _reset_election_timer(self, now: float) -> None:
        c = self.config
        span = self.rng.uniform(c.election_timeout_min, c.election_timeout_max)
        if c.reliability_weighted_election:
            # Stretch the draw by up to reliability_election_bias spreads
            # in proportion to current unreliability: stable nodes keep the
            # unbiased draw and campaign first. Liveness-only — the RNG
            # draw above is identical either way, and with the knob off the
            # deadline is bit-identical to the seed schedule.
            spread = c.election_timeout_max - c.election_timeout_min
            span += spread * (1.0 - self._reliability(now)) * c.reliability_election_bias
        self.election_deadline = now + span

    def _reliability(self, now: float) -> float:
        """Recent-uptime/contact score in [0, 1]: a linear uptime ramp
        (time since this incarnation started, saturating at
        reliability_uptime_ms) times the leader-contact regularity EWMA.
        A freshly-restarted node scores ~0 regardless of its links; a
        long-lived node on flaky links is pulled down by the EWMA."""
        h = max(1e-9, self.config.reliability_uptime_ms)
        up = min(1.0, max(0.0, now - self._started_at) / h)
        return up * self._contact_ewma

    def _become_follower(self, term: int, now: float) -> None:
        was_leader = self.role is Role.LEADER
        if term > self.term:
            self.term = term
            self.voted_for = None
            self._persist_hard_state()
            # Leader-change invalidation: a term bump means a new
            # leadership may exist; only watermarks certified (directly or
            # transitively) in the NEW term may serve reads issued from
            # here on. Pending replica reads keep an already-latched
            # target_index — a certified watermark is a historical fact
            # that no later leadership can falsify.
            self._wm_index = -1
            self._wm_time = -1.0e18
        self.role = Role.FOLLOWER
        self.votes_received = {}
        self._prevote_term = 0
        self._prevotes = set()
        # Commands coalescing in the leader batch buffer were never appended;
        # put them back on the client queue so they re-route to the new leader.
        if self._batch_buffer:
            self._pending_client.extend(self._batch_buffer)
            self._batch_buffer = []
            self._buffered_ids.clear()
        self._inflight = {}
        self._pipe_next = {}
        self._snap_xfer = {}
        self._hb_match = {}
        self._pending_stepdown = False
        self._reset_read_leadership_state()
        self._reset_election_timer(now)
        if was_leader:
            self._on_leadership_lost(now)  # FastRaft hook

    def _reset_read_leadership_state(self) -> None:
        """Drop all leadership-scoped read/lease state. Pending reads from
        remote origins get a retry-hint reply (via the outbox); local
        origins stay in _reads_inflight and re-route on the next tick."""
        for r in self._reads_pending:
            if r.origin and r.origin != self.id:
                self._outbox.append(
                    (
                        r.origin,
                        ReadReply(term=self.term, src=self.id, read_id=r.read_id,
                                  ok=False, leader_hint=self.leader_id),
                    )
                )
        self._reads_pending = []
        self._reads_pending_ids = set()
        self._round_sent = {}
        self._peer_acked_round = {}
        self._quorum_round = 0
        self._confirmed_sent_sim = -1.0e18
        self._lease_expiry_local = -1.0e18
        self._probe_deadline = 0.0
        self._ack_dirty = True

    def _become_candidate(self, now: float) -> Outputs:
        self.term += 1
        self.role = Role.CANDIDATE
        self.voted_for = self.id
        self._persist_hard_state()
        self.leader_id = None
        self.votes_received = {}
        self._reset_election_timer(now)
        self._count("elections")
        lli, llt = self._election_log_position()
        args = RequestVoteArgs(
            term=self.term,
            src=self.id,
            candidate_id=self.id,
            last_log_index=lli,
            last_log_term=llt,
        )
        # Vote for self (record a synthetic reply so recovery sees our tail).
        self.votes_received[self.id] = RequestVoteReply(
            term=self.term,
            src=self.id,
            vote_granted=True,
            tentative_tail=self._tentative_tail(),
            last_log_index=self.last_log_index(),
        )
        out: Outputs = [(p, args) for p in self.peers()]
        return out + self._maybe_win_election(now)

    def _become_leader(self, now: float) -> Outputs:
        self.role = Role.LEADER
        self.leader_id = self.id
        self._lead_since = now
        self.next_index = {p: self.last_log_index() + 1 for p in self.peers()}
        self.match_index = {p: 0 for p in self.peers()}
        self._match_dirty = True
        self._inflight = {}
        self._pipe_next = {}
        self._snap_xfer = {}
        self._hb_match = {}
        self._reset_read_leadership_state()
        self.next_heartbeat = now  # fire immediately
        self._count("leader_elected")
        if self.metrics is not None:
            self.metrics.leader_elected(self.id, self.term)
        out = self._on_leadership_acquired(now)  # FastRaft hook (recovery)
        if self.config.election_noop:
            out += self._append_term_noop(now)
        out += self._flush_pending(now)
        return out + self._broadcast_append_entries(now)

    def _maybe_win_election(self, now: float) -> Outputs:
        granted = {n for n, r in self.votes_received.items() if r.vote_granted}
        if self.role is Role.CANDIDATE and self.cluster_config.election_won(granted):
            return self._become_leader(now)
        return []

    # ------------------------------------------------------------- pre-vote

    def _begin_prevote(self, now: float) -> Outputs:
        """Start a PreVote probe round for term + 1. The node stays a
        FOLLOWER and burns no term: only a quorum of grants (per every
        active voter set, like a real election) converts the probe into
        :meth:`_become_candidate`."""
        self._reset_election_timer(now)
        self._prevote_term = self.term + 1
        self._prevotes = {self.id}
        self._count("prevote_rounds")
        lli, llt = self._election_log_position()
        args = PreVoteArgs(
            term=self._prevote_term,
            src=self.id,
            candidate_id=self.id,
            last_log_index=lli,
            last_log_term=llt,
        )
        out: Outputs = [(p, args) for p in self.peers()]
        self._count("msgs_out", len(out))
        return out + self._maybe_win_prevote(now)

    def _maybe_win_prevote(self, now: float) -> Outputs:
        if self._prevote_term and self.cluster_config.election_won(self._prevotes):
            self._prevote_term = 0
            self._prevotes = set()
            return self._become_candidate(now)
        return []

    def _handle_PreVoteArgs(self, msg: PreVoteArgs, now: float) -> Outputs:
        # msg.term is PROSPECTIVE — never adopted (on_message defers the
        # generic term bump for this type). Grant iff the candidate would
        # win a real vote here AND nothing suggests a live leader: pre-vote
        # recency gating is unconditional (not lease-gated) because it
        # costs no liveness — a genuinely dead leader stops refreshing
        # _last_leader_contact everywhere within one election timeout.
        grant = False
        if msg.term > self.term and not self._vote_is_disruptive(
            msg.candidate_id, now, prevote=True
        ):
            lli, llt = self._vote_floor_position()
            grant = (msg.last_log_term, msg.last_log_index) >= (llt, lli)
        # Granting records nothing and resets no timer: a pre-vote is a
        # prediction, not a promise.
        return [
            (
                msg.src,
                PreVoteReply(
                    term=self.term,
                    src=self.id,
                    vote_granted=grant,
                    prospective_term=msg.term,
                ),
            )
        ]

    def _handle_PreVoteReply(self, msg: PreVoteReply, now: float) -> Outputs:
        # A higher real term in the reply was already adopted by the
        # generic bump in on_message (which also cancelled the campaign).
        if (
            self._prevote_term == 0
            or msg.prospective_term != self._prevote_term
            or not msg.vote_granted
        ):
            return []
        self._prevotes.add(msg.src)
        return self._maybe_win_prevote(now)

    # -------------------------------------------- disruption defense helpers

    def _quorum_contact_age(self, now: float) -> float:
        """How long since this LEADER last heard from a commit quorum.
        The basis is the send time of the newest quorum-confirmed
        heartbeat/probe round (tracked unconditionally by _note_round_ack),
        floored at election win time; a singleton quorum is always in
        contact with itself."""
        if self.cluster_config.commit_ok({self.id}):
            return 0.0
        return now - max(self._confirmed_sent_sim, self._lead_since)

    def _has_recent_leader_contact(self, now: float) -> bool:
        """Evidence of a live current leadership within one minimum
        election timeout: for a follower/candidate, contact FROM a leader;
        for a leader, contact WITH its quorum (a deposed leader stranded in
        a minority loses this within one timeout and stops rejecting)."""
        if self.role is Role.LEADER:
            return self._quorum_contact_age(now) < self.config.election_timeout_min
        return now - self._last_leader_contact < self.config.election_timeout_min

    def _vote_is_disruptive(
        self, candidate: NodeId, now: float, prevote: bool
    ) -> bool:
        """Should this vote/pre-vote request be refused as disruption?

        - A candidate OUTSIDE every active voter set (a removed node, or a
          node campaigning on a stale config) is refused whenever we have
          recent evidence of a live leadership — the removed-node defense.
          Refused requests also never bump our term (see on_message), so a
          rejoining removed node cannot inflate terms or depose anyone.
        - An in-config candidate is refused on leader-contact recency:
          always for pre-votes (that is PreVote's semantics), but for REAL
          votes only under lease mode (vote stickiness) — lease-free
          configs keep the seed's classic-Raft behavior.
        """
        if self.cluster_config.is_witness(candidate):
            # A witness is never electable: it holds no payloads, so a
            # leadership it won could serve nothing and certify nothing.
            # Unconditional (not recency-gated) — and like every refusal
            # here it never bumps our term.
            return True
        recent = self._has_recent_leader_contact(now)
        if not self.cluster_config.is_voter(candidate):
            return recent
        if prevote:
            return recent
        return self.config.lease_duration_ms > 0 and recent

    def _note_leader_contact(self, now: float) -> None:
        """Record valid-leader contact (AppendEntries / probe / snapshot
        traffic): the vote-stickiness clock restarts and any PreVote
        campaign in progress is abandoned — there IS a live leader."""
        if self._last_leader_contact > -1.0e17:
            # Contact-regularity EWMA for weighted elections: a gap of a
            # few heartbeat intervals is regular, anything longer counts
            # against this node's links. State-only (no RNG, no messages).
            gap = now - self._last_leader_contact
            good = 1.0 if gap <= 3.0 * self.config.heartbeat_interval else 0.0
            self._contact_ewma = 0.8 * self._contact_ewma + 0.2 * good
        self._last_leader_contact = now
        self._prevote_term = 0
        self._prevotes = set()

    # ---- Hooks overridden by FastRaftNode -------------------------------

    def _election_log_position(self) -> Tuple[int, int]:
        """(last_log_index, last_log_term) used in up-to-dateness checks.

        FastRaft hook: tentative fast-track slots are *excluded* there —
        they are recovered by the new leader from vote replies instead.
        """
        return self.last_log_index(), self.term_at(self.last_log_index())

    def _vote_floor_position(self) -> Tuple[int, int]:
        """(last_log_index, last_log_term) a candidate must reach for OUR
        vote: the election log position raised to the persisted ack floor.

        Only the GRANT side uses this. A campaigning node always advertises
        its real log (_election_log_position) — folding the floor into the
        advertisement would let a store-restored node claim entries it does
        not hold and win an election it cannot safely lead.
        """
        lli, llt = self._election_log_position()
        ft, fi = self._ack_floor
        if (ft, fi) > (llt, lli):
            return fi, ft
        return lli, llt

    def _tentative_tail(self) -> Optional[dict]:
        return None  # FastRaft hook

    def _on_leadership_acquired(self, now: float) -> Outputs:
        return []  # FastRaft hook: slot recovery

    def _on_leadership_lost(self, now: float) -> None:
        pass  # FastRaft hook: drop leader-volatile fast-track state

    def _on_slot_overwritten(self, index: int, old: Slot, new: Slot) -> None:
        pass  # FastRaft hook: re-propose displaced commands

    # --------------------------------------------------------------- ticks

    def start(self, now: float) -> None:
        self._started_at = now
        self._reset_election_timer(now)

    def on_tick(self, now: float) -> Outputs:
        if not self.alive:
            return []
        if self._ack_buf_time >= 0 and now > self._ack_buf_time:
            self._flush_acks()
        if (
            not self._legacy_mode
            and self.role is not Role.LEADER
            and now < self.election_deadline
            and not self._reads_inflight
            and not self._replica_reads
            and not self._outbox
            and not self._apply_pending
            and self._ack_buf_time < 0
            and self._protocol_idle()
        ):
            # Idle non-leader fast path: with the election timer unexpired
            # and no reads, outbox traffic, or protocol work pending, the
            # full body below provably produces no output and mutates no
            # state — skip it. This is where most simulated ticks land on
            # large clusters (one leader, N-1 mostly-idle followers).
            return []
        out: Outputs = []
        if self.role is Role.LEADER:
            # CheckQuorum: a leader that cannot confirm a commit quorum
            # within a full election timeout abdicates — somewhere a
            # majority has stopped hearing it and may elect (or already
            # elected) a successor; lingering only strands clients and
            # (under leases) risks serving reads a rival has overwritten.
            if (
                self.config.check_quorum
                and self._quorum_contact_age(now) > self.config.election_timeout_max
            ):
                self._count("checkquorum_stepdowns")
                self.leader_id = None
                self._become_follower(self.term, now)
                return self._drain_outbox(out)
            if self._batch_buffer and now >= self._batch_deadline:
                out += self._flush_batch(now)
            out += self._config_tick(now)
            if self.role is Role.LEADER and now >= self.next_heartbeat:
                self.next_heartbeat = now + self.config.heartbeat_interval
                out += self._broadcast_append_entries(now)
            # Coalesced-read window close: serve or confirm the batch.
            if (
                self.role is Role.LEADER
                and self._probe_deadline > 0.0
                and now >= self._probe_deadline
            ):
                self._probe_deadline = 0.0
                if self._reads_pending and self.peers():
                    # The lease MUST be re-validated HERE, at serve time —
                    # never trusted from admission time. A batch whose lease
                    # was (or went) dead inside the window falls back to a
                    # full ReadIndexProbe round; a batch whose lease is live
                    # NOW serves with zero rounds (each pending read arrived
                    # at or before now, so applied state at now is a valid
                    # linearization point for all of them).
                    if self._term_barrier_ok() and self._lease_valid(now):
                        out += self._serve_ready_reads(
                            now, confirmed_at=now, count_as="lease_reads"
                        )
                    if self._reads_pending:
                        out += self._send_read_probe(now)
        elif now >= self.election_deadline:
            # Learners, removed members, and witnesses never campaign:
            # learners/removed are in no voter set, and a witness holds no
            # log payload, so an election it started could only disrupt
            # (and it could never serve clients if it won).
            if self.is_voter() and not self.is_witness():
                if self.config.pre_vote:
                    # A timed-out CANDIDATE (split vote / lost quorum mid-
                    # election) also reverts to probing: with PreVote on, a
                    # term is only ever burned behind a winning probe.
                    self.role = Role.FOLLOWER
                    out += self._begin_prevote(now)
                else:
                    out += self._become_candidate(now)
            else:
                self._reset_election_timer(now)
        # Matured apply-lag targets drain on ticks (their replies and
        # read wakeups leave via the outbox).
        if self._apply_pending:
            self._drain_apply(now)
        out += self._tick_protocol(now)  # FastRaft hook (fast-slot timeouts)
        # Origin-side read retries: reads are idempotent, so lost
        # ReadQuery/ReadReply messages and leader churn are handled by
        # simply re-routing toward the current leader.
        if self._reads_inflight:
            retry = self.config.read_retry_timeout or self.config.election_timeout_min
            for rid in list(self._reads_inflight):
                cr = self._reads_inflight.get(rid)
                if cr is not None and now - cr.last_sent >= retry:
                    if cr.last_sent > -1.0e17:
                        self._count("read_retries")
                    out += self._route_read(rid, now)
        # Replica reads re-check on ticks too: the leader-singleton
        # watermark (commit_index, now) advances with time alone, and a
        # role change can make previously-blocked reads servable.
        if self._replica_reads:
            out += self._serve_replica_reads(now)
        return self._drain_outbox(out)

    def _drain_outbox(self, out: Outputs) -> Outputs:
        if self._outbox:
            out = out + self._outbox
            self._outbox = []
        return out

    def _flush_acks(self) -> None:
        """Release piggybacked acks (config.ack_piggyback) into the outbox.

        Called from the on_tick preamble at the first tick strictly after
        the last buffering time, so every ack folded within one tick window
        leaves as one reply — even when serialization-delayed links spread
        the arrivals across the window. The delay is bounded by
        tick_interval, indistinguishable from network latency to the leader
        (which already tolerates arbitrarily reordered replies).
        FastRaft hook: overridden to flush buffered FastVotes too."""
        if self._ack_buf:
            for dst, reply in self._ack_buf.items():
                self._outbox.append((dst, reply))
            self._ack_buf = {}
        self._ack_buf_time = -1.0

    def _tick_protocol(self, now: float) -> Outputs:
        return []

    def _protocol_idle(self) -> bool:
        """True iff _tick_protocol would provably be a state-free no-op.

        FastRaft hook: overridden to check fast-slot tallies, held
        finalizations, and inflight proposals. Used by on_tick's idle
        non-leader early-out; must stay conservative (False when unsure).
        """
        return True

    # ------------------------------------------------------------ messages

    def on_message(self, msg: Message, now: float) -> Outputs:
        if not self.alive:
            return []
        self._count("msgs_in")
        # Standard term rule — with one carve-out: vote REQUESTS defer the
        # bump to their handler, which adopts the term only when the
        # request is not refused as disruption (_vote_is_disruptive). A
        # rejoining removed/partitioned node with an inflated term would
        # otherwise depose a healthy leader through the bump alone, vote
        # denied or not. (A PreVoteArgs term is prospective and is NEVER
        # adopted; PreVoteReply carries the voter's real term and bumps
        # normally, cancelling the campaign.)
        if msg.term > self.term and not isinstance(
            msg, (RequestVoteArgs, PreVoteArgs)
        ):
            self._become_follower(msg.term, now)
        if self._legacy_mode:
            handler = getattr(self, f"_handle_{type(msg).__name__}", None)
            if handler is None:
                return self._drain_outbox([])
            return self._drain_outbox(handler(msg, now))
        key = (type(self), type(msg))
        handler = _HANDLER_CACHE.get(key)
        if handler is None:
            if key in _HANDLER_CACHE:  # cached "no handler"
                return self._drain_outbox([])
            handler = getattr(type(self), f"_handle_{type(msg).__name__}", None)
            _HANDLER_CACHE[key] = handler
            if handler is None:
                return self._drain_outbox([])
        return self._drain_outbox(handler(self, msg, now))

    # -- RequestVote

    def _handle_RequestVoteArgs(self, msg: RequestVoteArgs, now: float) -> Outputs:
        grant = False
        # Disruption defense (see _vote_is_disruptive): vote stickiness for
        # in-config rivals under lease mode — without it a disruptive
        # candidate could win DURING an active lease and commit writes the
        # lease holder's local reads would then miss — plus the
        # out-of-config (removed node) rejection. Refused requests do not
        # bump our term either: the deferred on_message rule.
        if msg.term >= self.term and not self._vote_is_disruptive(
            msg.candidate_id, now, prevote=False
        ):
            if msg.term > self.term:
                self._become_follower(msg.term, now)
            lli, llt = self._vote_floor_position()
            up_to_date = (msg.last_log_term, msg.last_log_index) >= (llt, lli)
            if up_to_date and self.voted_for in (None, msg.candidate_id):
                grant = True
                self.voted_for = msg.candidate_id
                self._persist_hard_state()
                self._reset_election_timer(now)
        reply = RequestVoteReply(
            term=self.term,
            src=self.id,
            vote_granted=grant,
            tentative_tail=self._tentative_tail() if grant else None,
            last_log_index=self.last_log_index(),
        )
        return [(msg.src, reply)]

    def _handle_RequestVoteReply(self, msg: RequestVoteReply, now: float) -> Outputs:
        if self.role is not Role.CANDIDATE or msg.term < self.term:
            return []
        self.votes_received[msg.src] = msg
        return self._maybe_win_election(now)

    # -- AppendEntries

    def _broadcast_append_entries(self, now: float) -> Outputs:
        """(Re)send replication traffic to every follower.

        Each broadcast re-opens the per-follower pipeline from next_index —
        the known-replicated point — so a broadcast doubles as retransmission
        of batches lost since the last one. Followers with nothing to pull
        get a plain heartbeat.

        Every broadcast is a leadership-confirmation round: it gets a fresh
        round id stamped on its messages, and a quorum of echoes renews the
        lease / confirms pending ReadIndex reads (see _note_round_ack).
        """
        self._hb_round += 1
        if not self._legacy_mode:
            self._ack_note_value(self.id, self._hb_round - 1, self._hb_round)
        self._round_sent[self._hb_round] = self._record_round(now)
        if len(self._round_sent) > 1024:
            # A leader cut off from its quorum keeps broadcasting; dropping
            # the oldest unconfirmed rounds only delays a (doomed) lease
            # renewal, never extends one.
            if self._legacy_mode:
                for r in sorted(self._round_sent)[: len(self._round_sent) - 1024]:
                    del self._round_sent[r]
            else:
                # Keys enter _round_sent in ascending round order, so dict
                # insertion order IS sorted order: pop oldest-first.
                while len(self._round_sent) > 1024:
                    del self._round_sent[next(iter(self._round_sent))]
        if self.config.ack_piggyback:
            had_data, self._data_sent = self._data_sent, set()
        else:
            had_data = ()
        out: Outputs = []
        for p in self.peers():
            # Under ack piggybacking the broadcast is a STALL-RECOVERY
            # timer, not an unconditional retransmitter: a pipeline whose
            # acked cursor (chunk offset, or match_index with traffic
            # outstanding) advanced since the last broadcast is ack-clocked
            # and alive, and re-opening its window would re-send bytes
            # still QUEUED on the link — on a serialization-limited link
            # the duplicates crowd out fresh data until progress collapses.
            # Only a pipeline that went a whole interval without progress
            # gets the classic reset-and-resend. Knob off, every broadcast
            # resets, exactly the seed behavior.
            xfer = self._snap_xfer.get(p)
            if xfer is not None:
                progressed = (
                    self.config.ack_piggyback and xfer.offset != xfer.hb_mark
                )
                xfer.hb_mark = xfer.offset
            else:
                m = self.match_index.get(p, 0)
                progressed = (
                    self.config.ack_piggyback
                    and m > self._hb_match.get(p, -1)
                    and self._inflight.get(p, 0) > 0
                )
                self._hb_match[p] = m
            if not progressed:
                self._inflight[p] = 0
                self._pipe_next[p] = self.next_index.get(
                    p, self.last_log_index() + 1
                )
            msgs = self._replicate_to_peer(p)
            if not msgs:
                if p in had_data:
                    # Heartbeat coalescing (config.ack_piggyback): this
                    # peer received data-bearing round-stamped traffic
                    # since the last broadcast, so the empty heartbeat is
                    # redundant — its liveness/commit/watermark payload
                    # already traveled. The lease basis may trail by one
                    # interval (the data carried the PREVIOUS round id),
                    # which only shortens the lease — the safe direction —
                    # and the next quiet interval resumes heartbeats.
                    self._count("heartbeats_suppressed")
                    continue
                msgs = [(p, self._heartbeat_for(p))]
            out += msgs
        self._count("msgs_out", len(out))
        return out

    def _heartbeat_for(self, peer: NodeId) -> AppendEntriesArgs:
        prev = min(
            self.next_index.get(peer, self.last_log_index() + 1) - 1,
            self.last_log_index(),
        )
        return AppendEntriesArgs(
            term=self.term,
            src=self.id,
            leader_id=self.id,
            prev_log_index=prev,
            prev_log_term=self.term_at(prev),
            entries=(),
            leader_commit=self.commit_index,
            hb_id=self._hb_round,
            read_wm=self._wm_index,
            read_wm_ts=self._wm_time,
        )

    def _replicate_to_peer(self, peer: NodeId) -> Outputs:
        """Entry-bearing traffic for one follower: consecutive AppendEntries
        batches of <= max_batch_entries, pipelined up to max_inflight_batches
        outstanding — or one InstallSnapshot when the follower's next entry
        was compacted away."""
        ni = self.next_index.get(peer, self.last_log_index() + 1)
        peer_is_witness = self.cluster_config.is_witness(peer)
        if self.snapshot is not None and ni <= self.snapshot.last_index:
            snap_out = (
                self._send_witness_base(peer)
                if peer_is_witness
                else self._send_snapshot(peer)
            )
            if snap_out and self.config.ack_piggyback:
                self._data_sent.add(peer)
            return snap_out
        out: Outputs = []
        batch = max(1, self.config.max_batch_entries)
        depth = max(1, self.config.max_inflight_batches)
        start = max(ni, self._pipe_next.get(peer, ni))
        while start <= self.last_log_index() and self._inflight.get(peer, 0) < depth:
            lo = start - self.snapshot_last_index - 1  # list position
            if peer_is_witness:
                # Witnesses store log SKELETONS: the payload is elided on
                # the wire (bandwidth is the point of the role), but the
                # (term, entry_id) agreement data — and config/noop
                # commands, which witnesses must act on — survive intact.
                entries = tuple(
                    Slot(skeleton_entry(s.entry), s.state)
                    for s in self.log[lo : lo + batch]
                )
            elif self._legacy_mode:
                entries = tuple(s.clone() for s in self.log[lo : lo + batch])
            else:
                # Entry objects are immutable after construction, so the
                # message shares them. Slot.state only ever flips AWAY from
                # TENTATIVE, so a non-tentative slot is state-immutable too
                # and the message can share the whole Slot; only a tentative
                # slot (which can flip between send and delivery) gets a
                # fresh wrapper. Receivers wrap their own Slot on append.
                entries = tuple(
                    s if s.state is not SlotState.TENTATIVE
                    else Slot(s.entry, s.state)
                    for s in self.log[lo : lo + batch]
                )
            out.append(
                (
                    peer,
                    AppendEntriesArgs(
                        term=self.term,
                        src=self.id,
                        leader_id=self.id,
                        prev_log_index=start - 1,
                        prev_log_term=self.term_at(start - 1),
                        entries=entries,
                        leader_commit=self.commit_index,
                        # Replication sent between broadcasts reuses the
                        # last round id: its send time is recorded as the
                        # (earlier) broadcast time, which only SHORTENS the
                        # lease this ack can grant — the safe direction.
                        hb_id=self._hb_round,
                        read_wm=self._wm_index,
                        read_wm_ts=self._wm_time,
                    ),
                )
            )
            self._inflight[peer] = self._inflight.get(peer, 0) + 1
            start += len(entries)
            self._pipe_next[peer] = start
        if out and self.config.ack_piggyback:
            self._data_sent.add(peer)
        return out

    def _send_witness_base(self, peer: NodeId) -> Outputs:
        """Advance a witness past the compaction horizon WITHOUT shipping
        state: a witness holds no state machine, so the compacted prefix
        it needs is just the (last_index, last_term, config) base marker.
        One tiny monolithic InstallSnapshot carries exactly that — never
        the chunked stream, never the machine state or dedup filter."""
        if self._inflight.get(peer, 0) > 0:
            return []  # one base message in flight at a time
        self._inflight[peer] = 1
        self._count("witness_base_advances")
        base = Snapshot(
            last_index=self.snapshot.last_index,
            last_term=self.snapshot.last_term,
            state=None,
            members=tuple(self.snapshot.members),
            dedup=None,
            config=self.snapshot.config,
        )
        return [
            (
                peer,
                InstallSnapshotArgs(
                    term=self.term,
                    src=self.id,
                    leader_id=self.id,
                    snapshot=base,
                    leader_commit=self.commit_index,
                ),
            )
        ]

    def _send_snapshot(self, peer: NodeId) -> Outputs:
        """Catch a follower up past the compaction horizon: one monolithic
        InstallSnapshot (snapshot_chunk_bytes == 0) or a window of chunks of
        a streamed transfer (``snapshot_chunk_window`` in flight at once;
        1 = strictly serial). The heartbeat broadcast clears the inflight
        count and re-sends from the follower-acked offset, which doubles as
        retransmission after loss."""
        chunk = self.config.snapshot_chunk_bytes
        if chunk <= 0:
            if self._inflight.get(peer, 0) > 0:
                return []  # one snapshot message in flight at a time
            self._inflight[peer] = 1
            self._count("snapshots_sent")
            # Pre-warm the size cache on OUR snapshot so every clone sent
            # (one per retransmission) inherits it instead of re-serializing
            # the whole state for the link model's size estimate.
            self.snapshot.size_bytes()
            return [
                (
                    peer,
                    InstallSnapshotArgs(
                        term=self.term,
                        src=self.id,
                        leader_id=self.id,
                        snapshot=self.snapshot.clone(),
                        leader_commit=self.commit_index,
                    ),
                )
            ]
        w = max(1, self.config.snapshot_chunk_window)
        if self._inflight.get(peer, 0) >= w:
            return []
        xfer = self._snap_xfer.get(peer)
        if xfer is None or xfer.last_index != self.snapshot.last_index:
            # New transfer (or the leader compacted again mid-transfer, which
            # changes the snapshot identity and restarts the stream).
            data, delta_base = self._snapshot_stream_for(peer)
            xfer = _SnapshotTransfer(
                last_index=self.snapshot.last_index,
                last_term=self.snapshot.last_term,
                data=data,
                delta_base=delta_base,
            )
            self._snap_xfer[peer] = xfer
            self._count("snapshots_sent")
        if self._inflight.get(peer, 0) == 0:
            # Fresh round (first send, or a heartbeat retransmission after
            # the window went quiet): resume from the acked cursor — unless
            # ack piggybacking is on AND the acked cursor advanced since the
            # last fresh round, in which case the ack-clocked pipeline is
            # alive and rewinding would only inject duplicate chunks into
            # the link queue; top up from send_cursor instead.
            if not self.config.ack_piggyback or xfer.offset == xfer.hb_mark:
                xfer.send_cursor = xfer.offset
            xfer.hb_mark = xfer.offset
        out: Outputs = []
        while self._inflight.get(peer, 0) < w:
            off = xfer.send_cursor
            data = xfer.data[off : off + chunk]
            done = off + len(data) >= len(xfer.data)
            if not data and len(xfer.data) > 0:
                break  # window ran past the end; await acks
            self._count("snapshot_chunks_sent")
            out.append(
                (
                    peer,
                    InstallSnapshotChunk(
                        term=self.term,
                        src=self.id,
                        leader_id=self.id,
                        last_index=xfer.last_index,
                        last_term=xfer.last_term,
                        offset=off,
                        data=data,
                        data_crc=zlib.crc32(data),
                        total_bytes=len(xfer.data),
                        done=done,
                        leader_commit=self.commit_index,
                        delta_base=xfer.delta_base,
                    ),
                )
            )
            self._inflight[peer] = self._inflight.get(peer, 0) + 1
            xfer.send_cursor = off + len(data)
            if done:
                break
        return out

    def _snapshot_stream_for(self, peer: NodeId) -> Tuple[bytes, int]:
        """The serialized stream a chunked transfer to ``peer`` will carry:
        the state DELTA against a retained base both sides hold when delta
        negotiation succeeds (config.delta_snapshots, the peer advertised a
        base we retained, and the machine supports deltas), else the full
        snapshot. Returns (data, delta_base); delta_base == -1 for full."""
        if self.config.delta_snapshots:
            base_idx = self._peer_snap_index.get(peer, -1)
            base_state = self._delta_bases.get(base_idx)
            if 0 < base_idx < self.snapshot.last_index and base_state is not None:
                delta = self.state_machine.snapshot_delta(
                    base_state, self.snapshot.state
                )
                if delta is not None:
                    self._count("delta_snapshots_sent")
                    return (
                        snapshot_delta_to_bytes(self.snapshot, delta, base_idx),
                        base_idx,
                    )
        return snapshot_to_bytes(self.snapshot), -1

    def _handle_AppendEntriesArgs(self, msg: AppendEntriesArgs, now: float) -> Outputs:
        if msg.term < self.term:
            return [(msg.src, AppendEntriesReply(term=self.term, src=self.id))]
        # Valid leader for this term.
        first_leader_contact = self.leader_id != msg.leader_id
        self.leader_id = msg.leader_id
        if self.role is not Role.FOLLOWER:
            self._become_follower(msg.term, now)
        self._reset_election_timer(now)
        self._note_leader_contact(now)
        self._adopt_watermark(msg.read_wm, msg.read_wm_ts, now)
        deferred: Outputs = self._flush_pending(now) if first_leader_contact else []

        # Consistency check. Tentative slots don't count as matching history:
        # only CLASSIC/FINALIZED slots anchor prev_log_term. A prev inside
        # our snapshot is committed history and matches by definition.
        if msg.prev_log_index > self.snapshot_last_index:
            s = self.slot(msg.prev_log_index)
            if s is None or (
                s.entry.term != msg.prev_log_term and s.state is not SlotState.TENTATIVE
            ) or (s.state is SlotState.TENTATIVE):
                # A tentative slot at prev is not authoritative history; ask
                # the leader to back up and ship it classically.
                return deferred + [
                    (
                        msg.src,
                        AppendEntriesReply(
                            term=self.term, src=self.id, success=False,
                            match_index=0, hb_id=msg.hb_id,
                        ),
                    )
                ]
        # Append / overwrite.
        log_mutated = False
        for k, incoming in enumerate(msg.entries):
            idx = msg.prev_log_index + 1 + k
            if idx <= self.snapshot_last_index:
                continue  # compacted == committed; nothing to reconcile
            cur = self.slot(idx)
            if (
                cur is not None
                and cur.entry.term == incoming.entry.term
                and cur.entry.same_entry(incoming.entry)
            ):
                # Matching entry: possibly upgrade state (tentative->classic).
                if cur.state is SlotState.TENTATIVE:
                    cur.state = incoming.state
                    log_mutated = True
                continue
            if cur is not None:
                # Conflict: truncate from idx (Raft rule), after notifying.
                self._on_slot_overwritten(idx, cur, incoming)
                self._truncate_from(idx)
            if self._legacy_mode:
                self._append_slot(incoming.clone())
            else:
                # Entry is immutable — share it; only the Slot wrapper
                # (whose .state this replica may later flip) must be ours.
                self._append_slot(Slot(incoming.entry, incoming.state))
            log_mutated = True
        if log_mutated:
            # The success reply below acks these entries into the leader's
            # commit quorum: the ack floor must be durable before it leaves.
            self._persist_hard_state()
        if msg.leader_commit > self.commit_index:
            self._advance_commit(min(msg.leader_commit, self._durable_prefix()), now)
        reply = AppendEntriesReply(
            term=self.term,
            src=self.id,
            success=True,
            match_index=msg.prev_log_index + len(msg.entries),
            hb_id=msg.hb_id,
            snap_index=(
                self.snapshot_last_index if self.config.delta_snapshots else -1
            ),
        )
        if self.config.ack_piggyback:
            # Fold same-tick acks to this leader into ONE reply. Safe
            # because the leader already treats match_index and acked
            # rounds as monotone maxima (network reordering forces that),
            # so the folded reply carries everything the individual acks
            # did; n_acks releases their pipeline slots in one step.
            buf = self._ack_buf.get(msg.src)
            if buf is not None and buf.term == self.term:
                reply.match_index = max(reply.match_index, buf.match_index)
                reply.hb_id = max(reply.hb_id, buf.hb_id)
                reply.n_acks = buf.n_acks + 1
                self._count("acks_folded")
            self._ack_buf[msg.src] = reply
            self._ack_buf_time = now
            return deferred
        return deferred + [(msg.src, reply)]

    def _handle_AppendEntriesReply(self, msg: AppendEntriesReply, now: float) -> Outputs:
        if self.role is not Role.LEADER or msg.term < self.term:
            return []
        # Any equal-term reply — success or not — is the follower's word
        # that it still recognizes this leadership; echoed round ids feed
        # the lease / ReadIndex confirmation accounting.
        ack_out = self._note_round_ack(msg.src, msg.hb_id, now)
        if msg.snap_index >= 0:
            self._peer_snap_index[msg.src] = msg.snap_index
        if msg.success:
            # n_acks > 1 = a piggybacked reply folding that many acks;
            # release all their pipeline slots (default 1 otherwise).
            self._inflight[msg.src] = max(
                0, self._inflight.get(msg.src, 0) - msg.n_acks
            )
            old_match = self.match_index.get(msg.src, 0)
            if msg.match_index > old_match:
                self.match_index[msg.src] = msg.match_index
                if not self._legacy_mode:
                    self._match_note_value(msg.src, old_match, msg.match_index)
            self.next_index[msg.src] = self.match_index[msg.src] + 1
            self._pipe_next[msg.src] = max(
                self._pipe_next.get(msg.src, 0), self.next_index[msg.src]
            )
            out = self._leader_advance_commit(now)
            # Keep the pipeline full: the freed inflight slot immediately
            # carries the next batch if the follower still lags.
            more = self._replicate_to_peer(msg.src)
            self._count("msgs_out", len(more))
            return ack_out + out + more
        # Back up (simple decrement; fine at sim scale) and restart the
        # pipeline from the new next_index.
        self.next_index[msg.src] = max(1, self.next_index.get(msg.src, 1) - 8)
        self._inflight[msg.src] = 0
        self._pipe_next[msg.src] = self.next_index[msg.src]
        more = self._replicate_to_peer(msg.src)
        self._count("msgs_out", len(more))
        return ack_out + more

    # -- client path

    def client_request(
        self, command: Any, now: float, entry_id: Optional[EntryId] = None
    ) -> Outputs:
        """Entry point for a client command submitted at this node."""
        if not self.alive:
            return []
        entry_id = entry_id or EntryId(self.id, self.next_seq())
        if self._seen(entry_id) or entry_id in self._buffered_ids:
            return []  # duplicate retry
        if self.metrics is not None:
            self.metrics.submitted(entry_id, now, mode=self._submit_mode())
        if self.role is Role.LEADER:
            return self._leader_append(command, entry_id, now)
        return self._non_leader_submit(command, entry_id, now)

    def client_request_batch(
        self, pairs: List[Tuple[Any, EntryId]], now: float
    ) -> Outputs:
        """Batched entry point: a burst of client (command, entry_id) pairs
        submitted together moves as ONE batch — one multi-entry append on a
        leader, one relay RPC from a classic follower, one multi-slot
        FastPropose window on a fast-track proposer."""
        if not self.alive or not pairs:
            return []
        fresh = [
            (c, e)
            for c, e in pairs
            if not self._seen(e) and e not in self._buffered_ids
        ]
        if not fresh:
            return []
        mode = self._submit_mode()
        if self.metrics is not None:
            for _, e in fresh:
                self.metrics.submitted(e, now, mode=mode)
        if self.role is Role.LEADER:
            return self._leader_append_many(fresh, now)
        return self._non_leader_submit_batch(fresh, now)

    def _submit_mode(self) -> str:
        return "classic"  # FastRaft hook

    def _non_leader_submit(self, command: Any, entry_id: EntryId, now: float) -> Outputs:
        # Classic track: forward to the last known leader. FastRaft overrides.
        if self.leader_id is not None and self.leader_id != self.id:
            fwd = ForwardOperation(
                term=self.term, src=self.id, command=command, entry_id=entry_id
            )
            self._count("forwards")
            return [(self.leader_id, fwd)]
        # No leader known yet: queue and flush once one is discovered.
        self._pending_client.append((command, entry_id))
        return []

    def _non_leader_submit_batch(
        self, pairs: List[Tuple[Any, EntryId]], now: float
    ) -> Outputs:
        # Classic track: one relay RPC carries the whole burst. FastRaft
        # overrides with a multi-slot FastPropose window.
        if self.leader_id is not None and self.leader_id != self.id:
            head_cmd, head_id = pairs[0]
            fwd = ForwardOperation(
                term=self.term,
                src=self.id,
                command=head_cmd,
                entry_id=head_id,
                batch=tuple(pairs[1:]),
            )
            self._count("forwards")
            return [(self.leader_id, fwd)]
        self._pending_client.extend(pairs)
        return []

    def _flush_pending(self, now: float) -> Outputs:
        if not self._pending_client:
            return []
        pending, self._pending_client = self._pending_client, []
        fresh = [(c, e) for c, e in pending if not self._seen(e)]
        if not fresh:
            return []
        if self.role is Role.LEADER:
            return self._leader_append_many(fresh, now)
        # Flush the whole queue as ONE relay RPC: per-entry forwards would
        # race each other through link jitter and break per-client FIFO for
        # a batch queued before the leader was known.
        return self._non_leader_submit_batch(fresh, now)

    def _handle_ForwardOperation(self, msg: ForwardOperation, now: float) -> Outputs:
        if self.role is not Role.LEADER:
            if self.leader_id and self.leader_id != self.id:
                return [(self.leader_id, msg)]  # re-forward
            return []
        pairs = [(msg.command, msg.entry_id)] + list(msg.batch)
        return self._leader_append_many(pairs, now)

    # ----------------------------------------------- linearizable read path

    def client_read(
        self,
        query: Any,
        now: float,
        read_id: Any = None,
        mode: str = "leader",
        max_staleness_ms: float = 0.0,
    ) -> Outputs:
        """Entry point for a read submitted at this node.

        ``mode="leader"`` (default): linearizable via the leader. The read
        never touches the log: it is routed to the leader, which serves it
        from its local state machine after proving it is still the leader —
        one ReadIndexProbe quorum round, or zero rounds under a fresh
        heartbeat-quorum lease.

        ``mode="replica"``: served LOCALLY at this node (follower, learner,
        or leader) from the leader-published certified watermark — zero
        messages to the leader, which is the whole read scale-out story.
        With ``max_staleness_ms == 0`` the read is linearizable (waits for
        a watermark certified at or after issue — about two heartbeat
        intervals of latency); with ``max_staleness_ms > 0`` it serves as
        soon as a watermark within the staleness bound is held, trading an
        explicit bounded-staleness contract for latency: the result
        reflects every write committed before ``now - max_staleness_ms``.

        Completion is delivered through ``read_done_fn(read_id, result)``."""
        if not self.alive:
            return []
        if read_id is None:
            read_id = EntryId(f"{self.id}/read", self.next_seq())
        if mode == "replica":
            if self.is_witness():
                # A witness has no state machine to serve from. Refuse
                # immediately so the client re-targets a real replica
                # instead of waiting out a watermark that can never serve.
                if self.read_done_fn is not None:
                    self.read_done_fn(
                        read_id, {"ok": False, "error": "witness"}
                    )
                return []
            if read_id in self._replica_read_ids:
                return []  # duplicate client retry
            self._replica_read_ids.add(read_id)
            self._replica_reads.append(
                _ReplicaRead(
                    read_id=read_id,
                    query=query,
                    issued_at=now,
                    max_staleness=max(0.0, max_staleness_ms),
                )
            )
            self._count("replica_reads_submitted")
            return self._drain_outbox(self._serve_replica_reads(now))
        if read_id in self._reads_inflight:
            return []  # duplicate client retry
        self._reads_inflight[read_id] = _ClientRead(query=query, issued_at=now)
        self._count("reads_submitted")
        return self._drain_outbox(self._route_read(read_id, now))

    def _route_read(self, read_id: Any, now: float) -> Outputs:
        cr = self._reads_inflight.get(read_id)
        if cr is None:
            return []
        if self.role is Role.LEADER:
            cr.last_sent = now
            return self._leader_read(read_id, cr.query, "", now)
        if self.leader_id is not None and self.leader_id != self.id:
            cr.last_sent = now
            self._count("read_forwards")
            return [
                (
                    self.leader_id,
                    ReadQuery(term=self.term, src=self.id, read_id=read_id,
                              query=cr.query),
                )
            ]
        return []  # no leader known yet; the tick loop retries

    def _handle_ReadQuery(self, msg: ReadQuery, now: float) -> Outputs:
        if msg.read_id is None:
            return []
        if self.role is Role.LEADER:
            return self._leader_read(msg.read_id, msg.query, msg.src, now)
        if (
            self.leader_id is not None
            and self.leader_id not in (self.id, msg.src)
        ):
            return [(self.leader_id, msg)]  # re-forward toward the leader
        return [
            (
                msg.src,
                ReadReply(term=self.term, src=self.id, read_id=msg.read_id,
                          ok=False, leader_hint=self.leader_id),
            )
        ]

    def _handle_ReadReply(self, msg: ReadReply, now: float) -> Outputs:
        if msg.ok and msg.batch:
            # Grouped reply: complete every batched read (same origin, same
            # served state). _read_complete drops ids already completed.
            for rid, value in msg.batch:
                self._read_complete(
                    rid,
                    {"ok": True, "value": value, "served_index": msg.served_index},
                )
        cr = self._reads_inflight.get(msg.read_id)
        if cr is None:
            return []  # completed already (duplicate serve) or unknown
        if msg.ok:
            self._read_complete(
                msg.read_id,
                {"ok": True, "value": msg.value, "served_index": msg.served_index},
            )
            return []
        # The serving node lost leadership: fail over toward its hint, or
        # wait for the tick retry to discover the new leader. A hint
        # pointing back at us while we are NOT leader is stale topology —
        # re-routing instantly would ping-pong between two confused nodes,
        # so that case waits for the (rate-limited) tick retry.
        self._count("read_failovers")
        if self.role is Role.LEADER:
            return self._route_read(msg.read_id, now)
        if msg.leader_hint and msg.leader_hint not in (self.id, msg.src):
            cr.last_sent = now
            return [
                (
                    msg.leader_hint,
                    ReadQuery(term=self.term, src=self.id, read_id=msg.read_id,
                              query=cr.query),
                )
            ]
        return []

    def _read_complete(self, read_id: Any, result: dict) -> None:
        cr = self._reads_inflight.pop(read_id, None)
        if cr is not None and self.read_done_fn is not None:
            self.read_done_fn(read_id, result)

    def _leader_read(self, read_id: Any, query: Any, origin: NodeId, now: float) -> Outputs:
        """Admit a read at the leader: serve instantly under a valid lease,
        else queue it behind one leadership-confirmation round."""
        if read_id in self._reads_pending_ids:
            return []  # duplicate (origin retry raced our reply)
        out: Outputs = []
        barrier_ok = self._term_barrier_ok()
        if not barrier_ok:
            out += self._append_term_noop(now)
        if barrier_ok and self._lease_valid(now):
            self._count("lease_reads")
            return out + self._finish_read(
                _PendingRead(read_id, query, origin, self._read_index(), now), now
            )
        self._reads_pending.append(
            _PendingRead(read_id, query, origin, self._read_index(), now)
        )
        self._reads_pending_ids.add(read_id)
        if self.peers():
            w = self.config.read_coalesce_window
            if w <= 0:
                out += self._send_read_probe(now)
            elif self._probe_deadline <= 0.0:
                # Coalesce: every read arriving within the window shares the
                # probe fired at the deadline (sent AFTER all of them
                # arrived, so one quorum round confirms the whole batch).
                self._probe_deadline = now + w
        return out

    def _append_term_noop(self, now: float) -> Outputs:
        """Read barrier for a fresh leader with no current-term commit: one
        no-op entry per term, appended lazily only when a read needs it."""
        if self._noop_term == self.term:
            return []
        self._noop_term = self.term
        self._count("read_barrier_noops")
        return self._leader_append(
            NOOP_PREFIX + str(self.term), EntryId(self.id, self.next_seq()), now
        )

    def _send_read_probe(self, now: float) -> Outputs:
        """One leadership-confirmation round for the pending reads. Shares
        the round-id space with heartbeat broadcasts; a lost probe is
        covered by the next heartbeat round (sent after the read arrived,
        so its quorum confirms the read too)."""
        self._hb_round += 1
        if not self._legacy_mode:
            self._ack_note_value(self.id, self._hb_round - 1, self._hb_round)
        self._round_sent[self._hb_round] = self._record_round(now)
        probe = ReadIndexProbe(term=self.term, src=self.id, leader_id=self.id,
                               probe_id=self._hb_round,
                               read_wm=self._wm_index, read_wm_ts=self._wm_time)
        out: Outputs = [(p, probe) for p in self.peers()]
        self._count("read_probes")
        self._count("msgs_out", len(out))
        return out

    def _handle_ReadIndexProbe(self, msg: ReadIndexProbe, now: float) -> Outputs:
        if msg.term < self.term:
            return [
                (
                    msg.src,
                    ReadIndexProbeReply(term=self.term, src=self.id,
                                        probe_id=msg.probe_id, ok=False),
                )
            ]
        # Acking a probe is the same promise as acking a heartbeat: we
        # recognize this leader NOW and restart our election timer — which
        # is exactly what makes the ack usable as a lease basis.
        self.leader_id = msg.leader_id
        if self.role is not Role.FOLLOWER:
            self._become_follower(msg.term, now)
        self._reset_election_timer(now)
        self._note_leader_contact(now)
        self._adopt_watermark(msg.read_wm, msg.read_wm_ts, now)
        return [
            (
                msg.src,
                ReadIndexProbeReply(term=self.term, src=self.id,
                                    probe_id=msg.probe_id, ok=True),
            )
        ]

    def _handle_ReadIndexProbeReply(self, msg: ReadIndexProbeReply, now: float) -> Outputs:
        if self.role is not Role.LEADER or msg.term < self.term or not msg.ok:
            return []
        return self._note_round_ack(msg.src, msg.probe_id, now)

    def _quorum_acked_round(self) -> int:
        """The newest round id a quorum of EVERY active voter set has
        acked (self implicitly acks its own latest round). Joint configs
        take the min across C_old and C_new — leadership is only confirmed
        when both halves confirm it, exactly like elections and commits."""
        if self._legacy_mode:
            q: Optional[int] = None
            for vs in self.cluster_config.voter_sets():
                rounds = sorted(
                    (
                        self._hb_round
                        if p == self.id
                        else self._peer_acked_round.get(p, 0)
                        for p in vs
                    ),
                    reverse=True,
                )
                need = majority(len(vs))
                r = rounds[need - 1] if len(rounds) >= need else 0
                q = r if q is None else min(q, r)
            return q or 0
        if self._ack_dirty:
            self._ack_rebuild()
        qr: Optional[int] = None
        for _members, vals, need in self._ack_sets:
            n = len(vals)
            r = vals[n - need] if n >= need else 0
            qr = r if qr is None else min(qr, r)
        return qr or 0

    def _ack_rebuild(self) -> None:
        """Rebuild the incremental quorum-ack tracker from scratch. Called
        lazily on the first quorum query after an invalidation (config
        change, leadership reset, restart)."""
        self._ack_sets = []
        for vs in self.cluster_config.voter_sets():
            vals = sorted(
                self._hb_round if p == self.id else self._peer_acked_round.get(p, 0)
                for p in vs
            )
            self._ack_sets.append((frozenset(vs), vals, majority(len(vs))))
        self._ack_dirty = False

    def _ack_note_value(self, nid: NodeId, old: int, new: int) -> None:
        """Single-value update of the quorum-ack tracker: nid's tracked
        round moved old -> new. No-op while dirty (the rebuild will read
        current state)."""
        if self._ack_dirty:
            return
        for members, vals, _need in self._ack_sets:
            if nid in members:
                del vals[bisect.bisect_left(vals, old)]
                bisect.insort(vals, new)

    def _note_round_ack(self, peer: NodeId, round_id: int, now: float) -> Outputs:
        """A peer echoed round ``round_id`` in the current term. When the
        round every voter-set quorum has acked advances, leadership is
        confirmed as of that round's SEND time: the lease extends from it,
        and pending reads that arrived at or before it become servable."""
        if self.role is not Role.LEADER or round_id <= 0:
            return []
        old_acked = self._peer_acked_round.get(peer, 0)
        if round_id > old_acked:
            self._peer_acked_round[peer] = round_id
            if not self._legacy_mode:
                self._ack_note_value(peer, old_acked, round_id)
        if not self._legacy_mode and round_id <= self._quorum_round:
            # Monotonicity early-out: raising one tracked value to at most
            # the already-confirmed round cannot lift any voter set's
            # need-th-largest past it, so the full computation below would
            # land in the "no progress" branch anyway.
            return []
        q = self._quorum_acked_round()
        if q <= self._quorum_round or q not in self._round_sent:
            return []  # no progress, or a stale echo from pruned history
        self._quorum_round = q
        sent_sim, sent_local, commit_pub = self._round_sent[q]
        self._confirmed_sent_sim = sent_sim
        span = self._lease_span()
        if span > 0:
            self._lease_expiry_local = max(
                self._lease_expiry_local, sent_local + span
            )
        # Quorum confirmation CERTIFIES the round's watermark: the commit
        # index captured at send (under the term barrier) now provably
        # covers everything committed before the send time. The leader
        # adopts it for its own replica-mode reads and publishes it on
        # every subsequent heartbeat/probe.
        if commit_pub >= 0 and sent_sim > self._wm_time:
            self._wm_index = commit_pub
            self._wm_time = sent_sim
            self._count("wm_certified")
        if self._legacy_mode:
            for r in [r for r in self._round_sent if r < q]:
                del self._round_sent[r]
        else:
            # Ascending-key insertion order: pop oldest until we reach q
            # (q is present — checked above — so this terminates).
            while self._round_sent:
                r = next(iter(self._round_sent))
                if r >= q:
                    break
                del self._round_sent[r]
        return self._serve_ready_reads(now) + self._serve_replica_reads(now)

    def _serve_ready_reads(
        self,
        now: float,
        confirmed_at: Optional[float] = None,
        count_as: str = "readindex_reads",
    ) -> Outputs:
        """Serve every pending read whose confirmation round was sent at or
        after it arrived, once the read barrier holds and the read index is
        applied. Called from ack paths and (via the outbox) from
        _advance_commit, so fast-track merges and barrier commits release
        waiting reads immediately. ``confirmed_at`` overrides the
        quorum-round confirmation time — the coalesce-window lease serve
        passes ``now`` after re-validating the lease at serve time."""
        if not self._reads_pending or self.role is not Role.LEADER:
            return []
        if not self._term_barrier_ok():
            return []
        if confirmed_at is None:
            confirmed_at = self._confirmed_sent_sim
            if self.cluster_config.commit_ok({self.id}):
                confirmed_at = now  # self IS every quorum (singleton group)
        served: List[_PendingRead] = []
        keep: List[_PendingRead] = []
        for r in self._reads_pending:
            if confirmed_at >= r.arrived_at and self.last_applied >= r.read_index:
                self._reads_pending_ids.discard(r.read_id)
                self._count(count_as)
                served.append(r)
            else:
                keep.append(r)
        self._reads_pending = keep
        # Group replies per origin: all reads released by one confirmation
        # round to the same origin share ONE ReadReply (read coalescing's
        # reply half); local-origin and lone-remote reads go through the
        # same _finish_read path the lease serve uses.
        out: Outputs = []
        by_origin: Dict[NodeId, List[_PendingRead]] = {}
        for r in served:
            by_origin.setdefault("" if r.origin == self.id else r.origin, []).append(r)
        for origin, rs in by_origin.items():
            if origin == "" or len(rs) == 1:
                for r in rs:
                    out += self._finish_read(r, now)
                continue
            self._count("read_reply_batches")
            pairs = [(r.read_id, self._eval_read(r)) for r in rs]
            head_id, head_value = pairs[0]
            out.append(
                (
                    origin,
                    ReadReply(
                        term=self.term, src=self.id, read_id=head_id, ok=True,
                        value=head_value, served_index=self.last_applied,
                        batch=tuple(pairs[1:]),
                    ),
                )
            )
        return out

    def _eval_read(self, r: _PendingRead) -> Any:
        """Evaluate one (read-only) query against the local machine."""
        value = self.state_machine.query(r.query)
        self._count("reads_served")
        return value

    def _finish_read(self, r: _PendingRead, now: float) -> Outputs:
        """Evaluate the (read-only) query against the local machine and
        deliver the result to the origin."""
        value = self._eval_read(r)
        if r.origin in ("", self.id):
            self._read_complete(
                r.read_id,
                {"ok": True, "value": value, "served_index": self.last_applied},
            )
            return []
        return [
            (
                r.origin,
                ReadReply(term=self.term, src=self.id, read_id=r.read_id, ok=True,
                          value=value, served_index=self.last_applied),
            )
        ]

    # ------------------------------------------- replica (watermark) reads

    def _adopt_watermark(self, wm: int, wm_ts: float, now: float) -> None:
        """Adopt a leader-published certified watermark. Callers are the
        valid-leader-contact points (AppendEntries / probe handlers) AFTER
        the term check, so ``msg.term == self.term`` here — a deposed
        leader's stale watermark can never reach this (its message carries
        a lower term and is rejected, or a higher term already bumped us
        and cleared the watermark). Adoption is monotone on certify time;
        the watermark survives snapshot jumps untouched (it is a lower
        bound on the committed prefix, and an installed snapshot only ever
        advances our applied prefix)."""
        if wm < 0 or wm_ts <= self._wm_time:
            return
        self._wm_index = wm
        self._wm_time = wm_ts
        if self._replica_reads:
            self._outbox += self._serve_replica_reads(now)

    def _serve_replica_reads(self, now: float) -> Outputs:
        """Serve pending replica-mode reads from local applied state.

        A read serves once (a) a certified watermark fresh enough for its
        staleness contract is held — certify time >= issue time minus the
        staleness bound — and (b) ``last_applied`` has reached the
        watermark index latched when (a) first held. Everything is local:
        no message ever leaves this node for a replica read."""
        if not self._replica_reads:
            return []
        wm_i, wm_t = self._wm_index, self._wm_time
        if (
            self.role is Role.LEADER
            and self._term_barrier_ok()
            and self.cluster_config.commit_ok({self.id})
        ):
            # Singleton voter set: self IS every quorum, so the current
            # commit index is trivially certified as of now.
            wm_i, wm_t = self._read_index(), now
        keep: List[_ReplicaRead] = []
        for r in self._replica_reads:
            if r.target_index < 0 and wm_i >= 0 and wm_t >= r.issued_at - r.max_staleness:
                r.target_index = wm_i
                r.wm_time = wm_t
            if 0 <= r.target_index <= self.last_applied:
                self._replica_read_ids.discard(r.read_id)
                self._count(
                    "replica_reads_served" if r.max_staleness <= 0.0
                    else "stale_reads_served"
                )
                value = self.state_machine.query(r.query)
                self._count("reads_served")
                if self.read_done_fn is not None:
                    self.read_done_fn(
                        r.read_id,
                        {
                            "ok": True,
                            "value": value,
                            "served_index": self.last_applied,
                            "mode": "replica",
                            "staleness_ms": r.max_staleness,
                            "wm_index": r.target_index,
                            "wm_time": r.wm_time,
                        },
                    )
            else:
                keep.append(r)
        self._replica_reads = keep
        return []

    def _leader_append(self, command: Any, entry_id: EntryId, now: float) -> Outputs:
        return self._leader_append_many([(command, entry_id)], now)

    def _leader_append_many(
        self, pairs: List[Tuple[Any, EntryId]], now: float
    ) -> Outputs:
        """Append a burst of commands. With batch_window > 0 they coalesce in
        the leader buffer (flushed by size or deadline); otherwise they are
        appended and replicated immediately in one broadcast."""
        pairs = [
            (c, e)
            for c, e in pairs
            if not self._seen(e) and e not in self._buffered_ids
        ]
        if not pairs:
            return []
        if self.config.adaptive_batch_window:
            if self._last_arrival >= 0:
                gap = now - self._last_arrival
                idle_cut = max(8.0 * max(self._arrival_gap_ewma, 0.25), 5.0)
                if gap >= idle_cut:
                    # Idle pause, not a rate sample: keep the estimate — a
                    # burst's density, not its spacing from the previous
                    # one, is what the window must match.
                    pass
                elif self._arrival_gap_ewma >= 0:
                    self._arrival_gap_ewma += 0.2 * (gap - self._arrival_gap_ewma)
                else:
                    self._arrival_gap_ewma = gap
            self._last_arrival = now
        window = self._effective_batch_window()
        if window > 0:
            if not self._batch_buffer:
                self._batch_deadline = now + window
            elif self.config.adaptive_batch_window:
                # A tighter estimate mid-buffer pulls the flush in; the
                # deadline only ever shrinks, so a stale early estimate
                # cannot strand the batch.
                self._batch_deadline = min(self._batch_deadline, now + window)
            for c, e in pairs:
                self._batch_buffer.append((c, e))
                self._buffered_ids.add(e)
            if len(self._batch_buffer) >= self.config.max_batch_entries:
                return self._flush_batch(now)
            return []
        if self._batch_buffer:
            # The adaptive policy flipped to streaming mid-buffer (arrivals
            # turned too sparse for a window): release everything together
            # rather than stranding the buffered prefix until a tick.
            for c, e in pairs:
                self._batch_buffer.append((c, e))
                self._buffered_ids.add(e)
            return self._flush_batch(now)
        return self._append_and_replicate(pairs, now)

    def _effective_batch_window(self) -> float:
        """Coalescing delay for the next batch. Static mode returns
        config.batch_window untouched (schedule-preserving). Adaptive mode
        sizes the window from the observed submit inter-arrival gap: wait
        just long enough to coalesce ~half a max batch, never longer than a
        heartbeat interval, and not at all when traffic is sparse (a gap of
        a heartbeat or more means waiting buys nothing but latency)."""
        if not self.config.adaptive_batch_window:
            return self.config.batch_window
        gap = self._arrival_gap_ewma
        cap = self.config.heartbeat_interval / 4.0
        # Stream (no window) while there is no rate estimate, or when
        # arrivals are too sparse for the capped window to coalesce even
        # ~2 commands — waiting would add latency and save nothing.
        if gap < 0.0 or gap > cap / 2.0:
            return 0.0
        # Window = expected time for a FULL batch to arrive at the observed
        # rate (the size cap flushes earlier whenever the batch actually
        # fills), clamped to a quarter heartbeat so the worst-case latency
        # cost stays small. The floor keeps same-instant bursts (gap ~ 0)
        # coalescing instead of broadcasting per command.
        return min(max(gap, 0.25) * self.config.max_batch_entries, cap)

    def _flush_batch(self, now: float) -> Outputs:
        pairs, self._batch_buffer = self._batch_buffer, []
        self._buffered_ids.clear()
        return self._append_and_replicate(pairs, now)

    def _append_and_replicate(
        self, pairs: List[Tuple[Any, EntryId]], now: float
    ) -> Outputs:
        appended = False
        for command, entry_id in pairs:
            if self._seen(entry_id):
                continue
            e = Entry(term=self.term, command=command, entry_id=entry_id, proposed_at=now)
            self._append_slot(Slot(e, SlotState.CLASSIC))
            self._count("proposals")
            appended = True
        if not appended:
            return []
        # The leader counts its own log in the commit quorum — its append IS
        # an ack, so the floor goes durable with it.
        self._persist_hard_state()
        # Replicate immediately (don't wait for the heartbeat).
        return self._broadcast_append_entries(now)

    # ---------------------------------------------------------- log & commit

    def _append_slot(self, s: Slot) -> None:
        if self.is_witness():
            # Central payload-elision choke point: every storage path
            # (AppendEntries, fast-track slots, local leader appends if a
            # misconfiguration ever made a witness leader) funnels through
            # here, so a witness can never accumulate payload bytes.
            # EntryId and term survive, keeping log matching, dedup, and
            # the commit oracles exact.
            s = Slot(skeleton_entry(s.entry), s.state)
        self.log.append(s)
        self._entry_index[s.entry.entry_id] = self.last_log_index()
        # Configs take effect the moment they enter the log (dissertation
        # rule): C_new's quorum constraints must bind before the entry is
        # durable anywhere, or two disjoint majorities could elect.
        if is_config_command(s.entry.command):
            self._adopt_config(self.last_log_index(), parse_config_command(s.entry.command))

    def _truncate_from(self, index: int) -> None:
        start = index - self.snapshot_last_index
        assert start >= 1, f"cannot truncate compacted prefix at {index}"
        for p in range(start - 1, len(self.log)):
            self._entry_index.pop(self.log[p].entry.entry_id, None)
        del self.log[start - 1 :]
        if self._durable_hi >= index:
            self._durable_hi = index - 1
        # Roll the config back if its entry was truncated away.
        while len(self._config_log) > 1 and self._config_log[-1][0] >= index:
            self._config_log.pop()
        self._set_cluster_config(self._config_log[-1][1])

    def _durable_prefix(self) -> int:
        """Largest index i such that slots 1..i are all non-tentative.

        Amortized O(1): the scan resumes from ``_durable_hi`` (state flips
        only go tentative -> classic/finalized, so the prefix shrinks only
        at the truncate/install/restore sites that clamp the cursor). The
        full per-call walk was a top-two hot spot on long uncompacted logs
        — it runs once per commit advance on every replica."""
        if self._legacy_mode:
            i = self.snapshot_last_index  # compacted prefix is committed
            for s in self.log:
                if s.state is SlotState.TENTATIVE:
                    break
                i += 1
            return i
        base = self.snapshot_last_index
        i = self._durable_hi
        if i < base:
            i = base
        log = self.log
        n = len(log)
        p = i - base
        while p < n and log[p].state is not SlotState.TENTATIVE:
            p += 1
        self._durable_hi = base + p
        return base + p

    def _leader_advance_commit(self, now: float) -> Outputs:
        # Largest N replicated on a quorum of EVERY active voter set with
        # term == current term. The leader counts itself only where it is a
        # voter (a leader being removed during joint consensus commits via
        # the other voters' matches — the dissertation's rule).
        if self._legacy_mode:
            for n in range(self.last_log_index(), self.commit_index, -1):
                s = self.slot(n)
                if s.state is SlotState.TENTATIVE or self.term_at(n) != self.term:
                    continue
                acked = {self.id} | {
                    p for p in self.peers() if self.match_index.get(p, 0) >= n
                }
                if self.cluster_config.commit_ok(acked):
                    self._advance_commit(n, now)
                    break
            return []
        # commit_ok({self} | {p: match_p >= n}) is monotone in n and holds
        # exactly for n <= _commit_quorum_index(); the answer is therefore
        # the highest non-tentative current-term index at or below it.
        top = self._commit_quorum_index()
        if top > self.last_log_index():
            top = self.last_log_index()
        for n in range(top, self.commit_index, -1):
            s = self.slot(n)
            if s.state is SlotState.TENTATIVE or self.term_at(n) != self.term:
                continue
            self._advance_commit(n, now)
            break
        return []

    def _commit_match_rebuild(self) -> None:
        """Rebuild the incremental commit-match tracker (sorted non-self
        voter match_index values per active voter set)."""
        self._match_sets = []
        for vs in self.cluster_config.voter_sets():
            others = frozenset(p for p in vs if p != self.id)
            vals = sorted(self.match_index.get(p, 0) for p in others)
            self._match_sets.append(
                (others, vals, majority(len(vs)), self.id in vs)
            )
        self._match_dirty = False

    def _match_note_value(self, nid: NodeId, old: int, new: int) -> None:
        """Single-value update of the commit-match tracker: nid's
        match_index moved old -> new (either direction — snapshot delivery
        can rewind it)."""
        if self._match_dirty:
            return
        for members, vals, _need, _self_in in self._match_sets:
            if nid in members:
                del vals[bisect.bisect_left(vals, old)]
                bisect.insort(vals, new)

    def _commit_quorum_index(self) -> int:
        """Largest n for which every active voter set has a commit quorum
        at match >= n, the leader's own log counted where it votes."""
        if self._match_dirty:
            self._commit_match_rebuild()
        top: Optional[int] = None
        for _members, vals, need, self_in in self._match_sets:
            k = need - 1 if self_in else need
            if k <= 0:
                r = self.last_log_index()  # leader alone is a quorum here
            elif len(vals) >= k:
                r = vals[len(vals) - k]
            else:
                r = 0
            top = r if top is None else min(top, r)
        return 0 if top is None else top

    def _advance_commit(self, new_commit: int, now: float) -> None:
        new_commit = min(new_commit, self._durable_prefix())
        if new_commit <= self.commit_index:
            return
        self.commit_index = new_commit
        if self.config.apply_lag_ms > 0.0:
            # Slow-CPU apply model: the commit point advances immediately
            # (replication is a network fact), but the state machine only
            # catches up after this node's apply lag. Targets mature in
            # the queue and drain from on_tick / later commit advances.
            self._apply_pending.append((now + self.config.apply_lag_ms, new_commit))
        self._drain_apply(now)

    def _drain_apply(self, now: float) -> None:
        """Apply committed entries up to the current apply target.

        With ``apply_lag_ms == 0`` the target is always ``commit_index``
        and this is exactly the historical inline apply loop of
        ``_advance_commit`` (schedules stay bit-identical). With lag, the
        target is the largest matured entry of ``_apply_pending``.
        """
        target = self.commit_index
        if self._apply_pending:
            target = self.last_applied
            keep: List[Tuple[float, int]] = []
            for ready_at, idx in self._apply_pending:
                if ready_at <= now:
                    target = max(target, idx)
                else:
                    keep.append((ready_at, idx))
            self._apply_pending = keep
            target = min(target, self.commit_index)
        while self.last_applied < target:
            self.last_applied += 1
            s = self.slot(self.last_applied)
            self._apply(self.last_applied, s.entry, now)
        t = self.config.snapshot_threshold
        if t > 0 and self.last_applied - self.snapshot_last_index >= t:
            self.compact()
        # Commit/apply progress can be what a pending read was waiting for
        # (the term-barrier no-op landing, or a classic/fast-track commit
        # advancing the read-visible index). No Outputs channel here, so
        # replies leave via the outbox.
        if self.role is Role.LEADER and self._reads_pending:
            self._outbox += self._serve_ready_reads(now)
        # Apply progress is also what a replica read with a latched
        # watermark target waits for (any role).
        if self._replica_reads:
            self._outbox += self._serve_replica_reads(now)

    # ---------------------------------------------------- snapshot/compaction

    def compact(self) -> None:
        """Fold the whole applied prefix into ``self.snapshot`` — the state
        machine's reduced state plus the dedup filter — and drop it from the
        log. Safe at any time: only applied == committed entries are
        compacted, and followers that still need them are caught up via
        InstallSnapshot.

        A witness compacts too — its skeleton log must stay bounded — but
        to a payload-free base marker (``state=None``, ``dedup=None``)
        that is never fed to the snapshot store: there is no machine
        state to persist, only the (last_index, last_term, config) base
        that log matching needs."""
        upto = self.last_applied
        if upto <= self.snapshot_last_index:
            return
        keep = upto - self.snapshot_last_index
        last_term = self.term_at(upto)
        for s in self.log[:keep]:
            # Applied ids live on in the dedup filter; drop the log mapping
            # so node memory tracks the machine's reduced state, not history.
            self._entry_index.pop(s.entry.entry_id, None)
        cfg_at = self._config_at(upto)
        witness = self.is_witness()
        if (
            self.config.delta_snapshots
            and not witness
            and self.snapshot is not None
            and self.snapshot.state is not None
        ):
            # Retain the outgoing snapshot's machine state as a delta
            # base: a follower still holding it is caught up with only
            # the changed keys. Bounded retention — oldest bases age out,
            # and a peer whose base aged out just gets the full stream.
            self._delta_bases[self.snapshot.last_index] = self.snapshot.state
            while len(self._delta_bases) > 4:
                del self._delta_bases[min(self._delta_bases)]
        self.snapshot = Snapshot(
            last_index=upto,
            last_term=last_term,
            state=None if witness else self.state_machine.snapshot(),
            members=tuple(cfg_at.members),
            dedup=None if witness else self._dedup.state(),
            config=cfg_at,
        )
        del self.log[:keep]
        # Squash compacted config history into the snapshot's base entry.
        above = [(i, c) for i, c in self._config_log if i > upto]
        self._config_log = [(upto, cfg_at)] + above
        self._count("compactions")
        if self.snapshot_sink is not None and not witness:
            self.snapshot_sink(self.id, self.snapshot)

    def restore_snapshot(self, snap: Snapshot) -> None:
        """Cold-start from a persisted snapshot (fresh host replacing a lost
        one): the snapshot becomes the whole committed state. The state
        machine jumps to the snapshot state — nothing is re-applied."""
        self.snapshot = snap.clone()
        self.log = []
        self._entry_index = {}
        self._durable_hi = snap.last_index
        self.state_machine.restore(copy.deepcopy(snap.state))
        self._dedup = DedupTable.from_state(snap.dedup)
        self.commit_index = snap.last_index
        self.last_applied = snap.last_index
        self.term = max(self.term, snap.last_term)
        self._rebuild_config_log_from(snap)
        # Floor for seq reuse from the snapshot's dedup filter; the
        # authoritative value comes from restore_hard_state (seqs burned
        # after the last compaction are not in the snapshot).
        self._seq = max(self._seq, self._dedup.max_seq(self.id))
        if (snap.last_term, snap.last_index) > self._ack_floor:
            self._ack_floor = (snap.last_term, snap.last_index)

    def restore_hard_state(
        self,
        term: int,
        voted_for: Optional[NodeId],
        seq: int,
        floor_index: int = 0,
        floor_term: int = 0,
    ) -> None:
        """Adopt persisted Raft hard state on a cold start. Without this a
        replaced node could double-vote in a term it already voted in, or
        mint EntryIds that collide with ones it burned before the crash.
        The ack floor keeps it from electing candidates that lack entries
        it acked before the crash (the log itself is not in the store)."""
        if term >= self.term:
            self.term = term
            self.voted_for = voted_for
        self._seq = max(self._seq, seq)
        if (floor_term, floor_index) > self._ack_floor:
            self._ack_floor = (floor_term, floor_index)

    def _install_snapshot(self, snap: Snapshot, now: float) -> None:
        """Follower-side InstallSnapshot: adopt the leader's compacted prefix.

        If the snapshot is ahead of our applied state, the state machine
        JUMPS to the snapshot state (reduced state replaces replay — the
        whole point of state-machine snapshots); any log suffix beyond the
        snapshot that matches last_term is retained.
        """
        if snap.last_index <= self.snapshot_last_index:
            return
        # Retain a matching live suffix; drop everything else. (If we had
        # applied past snap.last_index, those entries are committed, so our
        # term at snap.last_index necessarily matches and the suffix stays.)
        suffix: List[Slot] = []
        if self.last_log_index() > snap.last_index and self.term_at(
            snap.last_index
        ) == snap.last_term:
            lo = snap.last_index - self.snapshot_last_index
            if lo >= 0:
                suffix = self.log[lo:]
        if snap.last_index > self.last_applied:
            if not self.is_witness():
                self.state_machine.restore(copy.deepcopy(snap.state))
                self._dedup = DedupTable.from_state(snap.dedup)
            self.last_applied = snap.last_index
        self.commit_index = max(self.commit_index, snap.last_index)
        self.snapshot = snap.clone()
        self.log = suffix
        # Compacted prefix is durable; a retained suffix keeps its absolute
        # indices so a larger cursor stays valid, but never past the end
        # (the suffix is dropped entirely on a term mismatch).
        self._durable_hi = min(
            max(self._durable_hi, snap.last_index),
            snap.last_index + len(suffix),
        )
        self._entry_index = {
            s.entry.entry_id: snap.last_index + p + 1
            for p, s in enumerate(self.log)
        }
        self._rebuild_config_log_from(snap)
        self._count("snapshots_installed")
        # A snapshot jump can move last_applied past a replica read's
        # latched watermark target in one step — the snapshot-jump case of
        # the watermark protocol. The watermark itself needs no adjustment:
        # it lower-bounds the committed prefix, and the jump only advanced
        # our view of that prefix.
        if self._replica_reads:
            self._outbox += self._serve_replica_reads(now)

    def _handle_InstallSnapshotArgs(self, msg: InstallSnapshotArgs, now: float) -> Outputs:
        if msg.term < self.term or msg.snapshot is None:
            return [
                (msg.src, InstallSnapshotReply(term=self.term, src=self.id, match_index=0))
            ]
        self.leader_id = msg.leader_id
        if self.role is not Role.FOLLOWER:
            self._become_follower(msg.term, now)
        self._reset_election_timer(now)
        self._note_leader_contact(now)
        snap = msg.snapshot
        if snap.last_index > self.commit_index:
            self._install_snapshot(snap, now)
        if msg.leader_commit > self.commit_index:
            self._advance_commit(min(msg.leader_commit, self._durable_prefix()), now)
        # Ack with what we durably hold so the leader resumes AppendEntries
        # pipelining right above it.
        match = max(snap.last_index, self.commit_index)
        return [
            (msg.src, InstallSnapshotReply(term=self.term, src=self.id, match_index=match))
        ]

    def _handle_InstallSnapshotReply(self, msg: InstallSnapshotReply, now: float) -> Outputs:
        if self.role is not Role.LEADER or msg.term < self.term:
            return []
        self._inflight[msg.src] = 0
        if msg.match_index <= 0:
            return []
        return self._snapshot_delivered(msg.src, msg.match_index, now)

    def _snapshot_delivered(self, peer: NodeId, match_index: int, now: float) -> Outputs:
        """Leader bookkeeping once a follower holds the snapshot: resume
        normal AppendEntries pipelining right above it.

        The reply's match_index OVERWRITES (not maxes) our record: a host
        replaced from its checkpoint volume legitimately regresses below the
        match its lost incarnation reached, and keeping the stale (higher)
        match would pin next_index above entries the replacement does not
        have — an AppendEntries-reject / InstallSnapshot livelock whenever
        our own snapshot horizon sits below the stale match. The converse
        hazard (a jitter-delayed old reply briefly regressing a healthy
        follower's match) self-heals in one round: the follower's next
        AppendEntries/chunk reply reports its true position — chunk
        requests at or below its commit short-circuit with
        match_index=commit_index — so at most one redundant message is
        sent, which is the right trade against a permanent livelock."""
        self._snap_xfer.pop(peer, None)
        old_match = self.match_index.get(peer, 0)
        self.match_index[peer] = match_index
        if not self._legacy_mode and match_index != old_match:
            self._match_note_value(peer, old_match, match_index)
        self.next_index[peer] = self.match_index[peer] + 1
        self._pipe_next[peer] = self.next_index[peer]
        out = self._leader_advance_commit(now)
        more = self._replicate_to_peer(peer)
        self._count("msgs_out", len(more))
        return out + more

    # ------------------------------------------------- chunked transfer

    def _handle_InstallSnapshotChunk(self, msg: InstallSnapshotChunk, now: float) -> Outputs:
        if zlib.crc32(bytes(msg.data)) != msg.data_crc:
            # Payload corrupted in flight: treat exactly like loss — no ack,
            # no buffer append; the cursor-based heartbeat retransmission
            # resends from the last acked offset.
            self._count("corrupt_chunks_dropped")
            return []
        if msg.term < self.term:
            return [
                (
                    msg.src,
                    InstallSnapshotChunkReply(
                        term=self.term, src=self.id, last_index=msg.last_index
                    ),
                )
            ]
        self.leader_id = msg.leader_id
        if self.role is not Role.FOLLOWER:
            self._become_follower(msg.term, now)
        self._reset_election_timer(now)
        self._note_leader_contact(now)
        if msg.last_index <= self.commit_index:
            # Already caught up past this snapshot (e.g. a duplicate final
            # chunk after install): tell the leader where to resume.
            return [
                (
                    msg.src,
                    InstallSnapshotChunkReply(
                        term=self.term,
                        src=self.id,
                        last_index=msg.last_index,
                        match_index=self.commit_index,
                    ),
                )
            ]
        if msg.delta_base >= 0 and self.snapshot_last_index != msg.delta_base:
            # A delta stream against a base we no longer hold (we restarted
            # from an older checkpoint, or installed a different snapshot
            # since advertising): unappliable. Ask for the full stream.
            self._count("delta_snapshot_rejects")
            self._incoming_snap = None
            return [
                (
                    msg.src,
                    InstallSnapshotChunkReply(
                        term=self.term,
                        src=self.id,
                        last_index=msg.last_index,
                        next_offset=0,
                        need_full=True,
                    ),
                )
            ]
        buf = self._incoming_snap
        if (
            buf is None
            or buf["last_index"] != msg.last_index
            or buf.get("delta_base", -1) != msg.delta_base
        ):
            if buf is not None:
                # A different snapshot supersedes the partial transfer (the
                # leader compacted again, or a new leader took over with a
                # different horizon). Plain loss never lands here: retries
                # carry the same identity and resume at our cursor.
                self._count("snapshot_transfer_restarts")
            buf = {
                "last_index": msg.last_index,
                "last_term": msg.last_term,
                "delta_base": msg.delta_base,
                "data": bytearray(),
            }
            self._incoming_snap = buf
        cursor = len(buf["data"])
        if msg.offset == cursor and msg.data:
            buf["data"] += msg.data
            cursor = len(buf["data"])
        elif msg.offset < cursor:
            self._count("snapshot_chunk_dups")  # retransmit of acked bytes
        # msg.offset > cursor: a gap (we lost our buffer, e.g. restart
        # mid-transfer); replying with our cursor rewinds the leader.
        if msg.done and cursor >= msg.total_bytes:
            if msg.delta_base >= 0:
                return self._finish_delta_snapshot(msg, buf, cursor, now)
            try:
                snap = snapshot_from_bytes(bytes(buf["data"]))
            except (ValueError, KeyError, UnicodeDecodeError):
                # Assembled bytes fail to decode (a corrupted chunk slipped
                # past an older sender, or the buffer got mixed across
                # transfers): discard the buffer and rewind the leader to
                # offset 0 — a decode failure must restart the transfer,
                # never crash the node.
                self._count("snapshot_decode_failures")
                self._incoming_snap = None
                return [
                    (
                        msg.src,
                        InstallSnapshotChunkReply(
                            term=self.term,
                            src=self.id,
                            last_index=msg.last_index,
                            next_offset=0,
                        ),
                    )
                ]
            self._incoming_snap = None
            if snap.last_index > self.commit_index:
                self._install_snapshot(snap, now)
            if msg.leader_commit > self.commit_index:
                self._advance_commit(
                    min(msg.leader_commit, self._durable_prefix()), now
                )
            return [
                (
                    msg.src,
                    InstallSnapshotChunkReply(
                        term=self.term,
                        src=self.id,
                        last_index=msg.last_index,
                        next_offset=cursor,
                        match_index=max(snap.last_index, self.commit_index),
                    ),
                )
            ]
        return [
            (
                msg.src,
                InstallSnapshotChunkReply(
                    term=self.term,
                    src=self.id,
                    last_index=msg.last_index,
                    next_offset=cursor,
                ),
            )
        ]

    def _finish_delta_snapshot(
        self, msg: InstallSnapshotChunk, buf: dict, cursor: int, now: float
    ) -> Outputs:
        """Final chunk of a DELTA stream: reconstruct the full snapshot by
        applying the shipped delta to our base snapshot's state. Any
        failure — decode error, base drift mid-transfer, a machine without
        delta support — falls back to requesting the full stream; it never
        crashes the node or splices bad state."""
        self._incoming_snap = None
        base = self.snapshot
        doc = None
        state = None
        try:
            doc = snapshot_delta_from_bytes(bytes(buf["data"]))
        except (ValueError, KeyError, UnicodeDecodeError):
            self._count("snapshot_decode_failures")
        if (
            doc is not None
            and base is not None
            and base.state is not None
            and base.last_index == doc.get("delta_base")
        ):
            try:
                state = self.state_machine.apply_delta(base.state, doc["delta"])
            except (NotImplementedError, TypeError, KeyError, AttributeError):
                self._count("delta_apply_failures")
        if doc is None or state is None:
            self._count("delta_snapshot_rejects")
            return [
                (
                    msg.src,
                    InstallSnapshotChunkReply(
                        term=self.term,
                        src=self.id,
                        last_index=msg.last_index,
                        next_offset=0,
                        need_full=True,
                    ),
                )
            ]
        cfg = doc.get("config")
        snap = Snapshot(
            last_index=doc["last_index"],
            last_term=doc["last_term"],
            state=state,
            members=tuple(doc["members"]),
            dedup=doc.get("dedup"),
            config=None if cfg is None else ClusterConfig.from_wire(cfg),
            delta_base=doc["delta_base"],
        )
        self._count("delta_snapshots_installed")
        if snap.last_index > self.commit_index:
            self._install_snapshot(snap, now)
        if msg.leader_commit > self.commit_index:
            self._advance_commit(min(msg.leader_commit, self._durable_prefix()), now)
        return [
            (
                msg.src,
                InstallSnapshotChunkReply(
                    term=self.term,
                    src=self.id,
                    last_index=msg.last_index,
                    next_offset=cursor,
                    match_index=max(snap.last_index, self.commit_index),
                ),
            )
        ]

    def _handle_InstallSnapshotChunkReply(
        self, msg: InstallSnapshotChunkReply, now: float
    ) -> Outputs:
        if self.role is not Role.LEADER or msg.term < self.term:
            return []
        if (
            self.config.ack_piggyback
            and not msg.need_full
            and msg.match_index > 0
            and self._snap_xfer.get(msg.src) is None
            and msg.match_index <= self.match_index.get(msg.src, 0)
        ):
            # Duplicate ack of an already-completed transfer (chunk
            # retransmissions on a slow link produce a burst of these).
            # It carries no new position, and the classic path would
            # regress _pipe_next to match+1 and re-send the whole append
            # window once per straggler — on a serialization-limited link
            # those duplicates congest the queue into a self-sustaining
            # flood. A replacement incarnation genuinely below our match
            # still recovers via the AppendEntries failure/backoff path.
            return []
        w = max(1, self.config.snapshot_chunk_window)
        if w <= 1:
            self._inflight[msg.src] = 0
        else:
            self._inflight[msg.src] = max(0, self._inflight.get(msg.src, 0) - 1)
        if msg.need_full:
            # The follower cannot apply the negotiated delta: drop the
            # delta transfer and the stale base advertisement; the next
            # _replicate_to_peer (right below) builds the full stream.
            self._peer_snap_index.pop(msg.src, None)
            self._snap_xfer.pop(msg.src, None)
            self._inflight[msg.src] = 0
            self._count("delta_snapshot_fallbacks")
            more = self._replicate_to_peer(msg.src)
            self._count("msgs_out", len(more))
            return more
        if msg.match_index > 0:
            return self._snapshot_delivered(msg.src, msg.match_index, now)
        xfer = self._snap_xfer.get(msg.src)
        if xfer is None or xfer.last_index != msg.last_index:
            # Stale reply for a superseded transfer; the next
            # _replicate_to_peer (below or at the heartbeat) restarts it.
            more = self._replicate_to_peer(msg.src)
            self._count("msgs_out", len(more))
            return more
        if msg.next_offset == xfer.offset:
            if w <= 1:
                # Duplicate ack of the position we are already at (a
                # heartbeat retransmission produced a second reply, or our
                # chunk is still in flight). Reacting would fork a parallel
                # chunk stream — the heartbeat covers the genuinely-lost-
                # chunk case.
                return []
            # Pipelined window: a no-progress ack is either a duplicate or
            # the first gap report after a lost/reordered chunk. Rewind the
            # send cursor to the acked offset ONCE per stall position
            # (rewind_mark dedups the burst of gap acks one lost chunk
            # produces); anything pathological beyond that rides the
            # heartbeat retransmission.
            if xfer.send_cursor > xfer.offset and xfer.rewind_mark != xfer.offset:
                xfer.rewind_mark = xfer.offset
                xfer.send_cursor = xfer.offset
                self._inflight[msg.src] = 0
                more = self._replicate_to_peer(msg.src)
                self._count("msgs_out", len(more))
                return more
            return []
        # The follower's cursor is authoritative: normally it advances past
        # the chunk we sent; after a follower restart it legitimately
        # rewinds to 0. Either way the transfer RESUMES there (a backward
        # rewind also restarts the optimistic send window).
        new_off = max(0, min(msg.next_offset, len(xfer.data)))
        if new_off < xfer.offset:
            xfer.send_cursor = new_off
            self._inflight[msg.src] = 0
        else:
            xfer.send_cursor = max(xfer.send_cursor, new_off)
        xfer.offset = new_off
        more = self._replicate_to_peer(msg.src)
        self._count("msgs_out", len(more))
        return more

    def _apply(self, index: int, entry: Entry, now: float) -> None:
        cmd = entry.command
        if is_config_command(cmd):
            self._on_config_committed(index, parse_config_command(cmd), now)
        if self.is_witness():
            # Witnesses track commit progress (config commits above DO
            # matter to them) but run no state machine: the payload was
            # elided at append time, so there is nothing true to apply,
            # dedup, or report as this node's committed value.
            return
        self._dedup.add(entry.entry_id)
        self.state_machine.apply(index, entry)
        if self.metrics is not None:
            self.metrics.committed(self.id, index, entry, now)
        if self.apply_fn is not None:
            self.apply_fn(index, entry)

    # ------------------------------------------------------------ membership

    def _set_cluster_config(self, cfg: ClusterConfig) -> None:
        """Adopt ``cfg`` as the active config and realign leader peer
        bookkeeping (new peers start pipelining from our log head; removed
        peers are pruned)."""
        if cfg == self.cluster_config:
            return
        self.cluster_config = cfg
        self._ack_dirty = True
        self._match_dirty = True
        if self.role is Role.LEADER:
            for p in self.peers():
                self.next_index.setdefault(p, self.last_log_index() + 1)
                self.match_index.setdefault(p, 0)
            self.next_index = {p: self.next_index[p] for p in self.peers()}
            self.match_index = {p: self.match_index[p] for p in self.peers()}
            self._inflight = {p: self._inflight.get(p, 0) for p in self.peers()}
            self._pipe_next = {p: self._pipe_next.get(p, self.next_index[p])
                               for p in self.peers()}
            self._snap_xfer = {p: x for p, x in self._snap_xfer.items()
                               if p in self.next_index}

    def _adopt_config(self, index: int, cfg: ClusterConfig) -> None:
        """A config entry entered the log at ``index`` (append-time
        adoption). Truncation pops it back off; see _truncate_from."""
        self._config_log.append((index, cfg))
        self._set_cluster_config(cfg)
        self._count("config_adoptions")

    def _rebuild_config_log_from(self, snap: Snapshot) -> None:
        """After a snapshot jump/restore: base config comes from the
        snapshot, then any retained log suffix re-applies its config
        entries on top."""
        self._config_log = [(snap.last_index, snap.cluster_config())]
        for p, s in enumerate(self.log):
            if is_config_command(s.entry.command):
                self._config_log.append(
                    (snap.last_index + p + 1, parse_config_command(s.entry.command))
                )
        self._set_cluster_config(self._config_log[-1][1])

    def _on_config_committed(self, index: int, cfg: ClusterConfig, now: float) -> None:
        """A config entry committed. Two transitions are driven from here
        (both deferred to _config_tick — this runs inside the apply loop):
        a committed JOINT config is followed by its final config, and a
        committed final config that drops this leader from the voters
        triggers step-down."""
        if not cfg.joint and self.role is Role.LEADER and not cfg.is_voter(self.id):
            self._pending_stepdown = True

    def _config_tick(self, now: float) -> Outputs:
        """Leader-side membership-change driving, once per tick:

        - committed final config without us -> broadcast the commit once
          more so C_new learns it, then step down (a new election among
          C_new follows);
        - committed joint config -> append the final C_new config (phase
          two of joint consensus). Idempotent across leader changes: any
          leader that finds a committed joint config finishes it;
        - an inherited uncommitted config entry from a prior term cannot
          commit by counting alone (Raft section 5.4.2) -> append the
          once-per-term barrier no-op to drag it over the line.
        """
        out: Outputs = []
        if self._pending_stepdown:
            self._pending_stepdown = False
            out += self._broadcast_append_entries(now)
            self._become_follower(self.term, now)
            self._count("leader_stepdowns")
            return out
        cfg = self.cluster_config
        latest_idx = self._config_log[-1][0]
        if cfg.joint and latest_idx <= self.commit_index:
            eid = EntryId(self.id, self.next_seq())
            self._count("joint_finalizations")
            out += self._append_and_replicate(
                [(config_command(cfg.final()), eid)], now
            )
        elif latest_idx > self.commit_index and self.term_at(latest_idx) < self.term:
            out += self._append_term_noop(now)
        return out

    def propose_config_change(
        self,
        voters: Optional[List[NodeId]] = None,
        learners: Optional[List[NodeId]] = None,
        now: float = 0.0,
        witnesses: Optional[List[NodeId]] = None,
    ) -> Tuple[Optional[EntryId], Outputs]:
        """Leader-only entry point for a membership change. Returns
        ``(entry_id, outputs)`` of the appended config entry, or
        ``(None, [])`` when refused: not leader, a change is already in
        flight (at most ONE uncommitted config ever exists), or the change
        is a no-op.

        A voter-set change goes through joint consensus: this appends
        C_old,new; once it commits, _config_tick appends the final C_new.
        A learner-only change (add/remove/catch-up joiners) never alters
        any quorum, so it ships as a single simple config entry directly.
        Config entries bypass the client batch buffer: they must adopt at
        append time, and the at-most-one guard counts appended entries.
        """
        if self.role is not Role.LEADER or not self.alive:
            return None, []
        if self.config_change_in_flight():
            return None, []
        cur = self.cluster_config
        new_voters = tuple(sorted(set(voters if voters is not None else cur.voters)))
        new_learners = tuple(
            sorted(
                set(learners if learners is not None else cur.learners)
                - set(new_voters)
            )
        )
        new_witnesses = tuple(
            sorted(
                (set(witnesses if witnesses is not None else cur.witnesses))
                & set(new_voters)
            )
        )
        if not new_voters:
            return None, []
        if set(new_voters) == set(new_witnesses):
            return None, []  # a cluster of only witnesses can elect no one
        if (
            new_voters == cur.voters
            and new_learners == cur.learners
            and new_witnesses == cur.witnesses
        ):
            return None, []
        if new_voters != cur.voters:
            # Joint phase: keep the old set's witness markers alive too so
            # a witness in C_old stays payload-free while its votes still
            # count there.
            joint_w = tuple(sorted(set(new_witnesses) | (set(cur.witnesses) & set(cur.voters))))
            cfg = ClusterConfig(
                voters=new_voters, learners=new_learners, old_voters=cur.voters,
                witnesses=joint_w,
            )
            self._count("joint_changes_started")
        else:
            cfg = ClusterConfig(
                voters=new_voters, learners=new_learners, witnesses=new_witnesses
            )
            self._count("learner_changes")
        eid = EntryId(self.id, self.next_seq())
        return eid, self._append_and_replicate([(config_command(cfg), eid)], now)

    @staticmethod
    def config_command(members: List[NodeId]) -> str:
        """Legacy helper: an all-voter simple config command."""
        return config_command(ClusterConfig.of(members))

    # --------------------------------------------------------------- debug

    def committed_entries(self) -> List[Entry]:
        """All committed entries this node can enumerate, in index order.

        With the default LogListMachine the machine retains the full applied
        history, so this is the complete committed sequence exactly as in
        the seed. Reduced-state machines (KV) cannot enumerate the compacted
        prefix; only the applied-through-live-log tail is returned (use the
        machine's own state for cross-node divergence checks). A witness
        can enumerate NOTHING: its log holds payload-elided skeletons, so
        surfacing them as committed commands would only poison agreement
        checks with ``__witness_elided__`` markers."""
        if self.is_witness():
            return []
        out = self.state_machine.applied_entries()
        if out is None:
            out = []
            base = self.last_applied - self.snapshot_last_index
            for p in range(max(0, base)):
                out.append(self.log[p].entry)
            return out
        # The machine's history covers 1..last_applied; last_applied tracks
        # commit_index everywhere in this codebase (commit applies eagerly).
        return out

    def committed_commands(self) -> List[Any]:
        return [e.command for e in self.committed_entries()]

    def committed_by_index(self) -> Dict[int, Entry]:
        """Enumerable committed entries keyed by ABSOLUTE log index.

        The single source of truth for cross-node agreement checks: a
        reduced-state machine's history is a tail starting above its own
        compaction horizon, so comparisons must align on absolute index
        (the enumerable range always ends at last_applied)."""
        hist = self.committed_entries()
        start = self.last_applied - len(hist) + 1
        return {start + i: e for i, e in enumerate(hist)}

    def has_applied(self, entry_id: EntryId) -> bool:
        """Exact membership oracle over this node's applied (= committed)
        entries, valid across compaction for ANY state machine — the dedup
        filter carries it even when entries can no longer be enumerated."""
        return self._dedup.contains(entry_id)

    def log_summary(self) -> List[Tuple[int, str, str]]:
        return [
            (s.entry.term, str(s.entry.entry_id), s.state.value) for s in self.log
        ]

    def crash(self) -> None:
        self.alive = False

    def restart(self, now: float) -> None:
        """Crash-recovery: persistent state (term, voted_for, log, snapshot)
        survives; volatile state resets. The state machine rolls back to the
        last snapshot (or empty) and the suffix re-applies as commit
        re-advances — exactly the snapshot-plus-replay recovery a durable
        deployment performs."""
        self.alive = True
        self.role = Role.FOLLOWER
        self.leader_id = None
        self.votes_received = {}
        self._prevote_term = 0
        self._prevotes = set()
        self._lead_since = -1.0e18
        self.next_index = {}
        self.match_index = {}
        self._inflight = {}
        self._pipe_next = {}
        self._snap_xfer = {}
        self._incoming_snap = None
        # Delta bases and peer advertisements are leader-volatile (retained
        # states died with the process); buffered piggyback acks are
        # in-flight wire state and die like any unsent message.
        self._delta_bases = {}
        self._peer_snap_index = {}
        self._data_sent = set()
        self._hb_match = {}
        self._ack_buf = {}
        self._ack_buf_time = -1.0
        self._batch_buffer = []
        self._buffered_ids = set()
        # Read/lease state is volatile: in-flight client reads die with the
        # process (clients re-issue), leases and pending reads are
        # leadership-scoped, the outbox never survives a crash.
        self._reads_inflight = {}
        self._reads_pending = []
        self._reads_pending_ids = set()
        # The watermark is volatile by design: a restarted node re-adopts
        # from current-term leader traffic before serving replica reads.
        self._wm_index = -1
        self._wm_time = -1.0e18
        self._replica_reads = []
        self._replica_read_ids = set()
        self._round_sent = {}
        self._peer_acked_round = {}
        self._quorum_round = 0
        self._confirmed_sent_sim = -1.0e18
        self._lease_expiry_local = -1.0e18
        self._last_leader_contact = -1.0e18
        self._outbox = []
        self._pending_stepdown = False
        self._probe_deadline = 0.0
        self._ack_dirty = True
        self._match_dirty = True
        # Reliability tracking restarts from scratch: a freshly-restarted
        # node has zero recent uptime, which is exactly what weighted
        # elections should see. Pending applies died with the process.
        self._apply_pending = []
        self._started_at = now
        self._contact_ewma = 1.0
        if self.snapshot is not None:
            self.state_machine.restore(copy.deepcopy(self.snapshot.state))
            self._dedup = DedupTable.from_state(self.snapshot.dedup)
        else:
            self.state_machine.restore(None)
            self._dedup = DedupTable()
        self.commit_index = self.snapshot_last_index
        self.last_applied = self.snapshot_last_index
        self._reset_election_timer(now)
