"""Classic Raft (Ongaro & Ousterhout 2014), event-driven and transport-free.

A node never touches a socket or a clock: the harness (``repro.core.sim`` in
CI, a gRPC shim in production) delivers messages via :meth:`on_message`,
drives time via :meth:`on_tick`, and sends whatever list of ``(dst, msg)``
pairs a handler returns. This is what makes hypothesis-driven schedule
exploration possible: every interleaving the simulator can produce is a real
execution of the node code.

The class is written to be subclassed by :class:`repro.core.fast_raft.
FastRaftNode`; the hooks it overrides are marked ``# FastRaft hook``.
"""
from __future__ import annotations

import dataclasses
import random
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.types import (
    AppendEntriesArgs,
    AppendEntriesReply,
    ClientReply,
    Entry,
    EntryId,
    ForwardOperation,
    Message,
    NodeId,
    RequestVoteArgs,
    RequestVoteReply,
    Role,
    Slot,
    SlotState,
    majority,
)

Outputs = List[Tuple[NodeId, Message]]

CONFIG_PREFIX = "__config__:"  # membership-change commands


@dataclasses.dataclass
class RaftConfig:
    election_timeout_min: float = 150.0
    election_timeout_max: float = 300.0
    heartbeat_interval: float = 50.0
    # Fast Raft only (kept here so one config type serves both protocols):
    fast_track: bool = False
    fast_vote_timeout: float = 120.0  # slot falls back to classic after this
    max_fast_inflight: int = 64


class RaftNode:
    """One Raft participant. Deterministic given (config, seed, schedule)."""

    def __init__(
        self,
        node_id: NodeId,
        members: List[NodeId],
        config: Optional[RaftConfig] = None,
        seed: int = 0,
        apply_fn: Optional[Callable[[int, Entry], None]] = None,
    ):
        self.id = node_id
        self.members: List[NodeId] = list(members)
        self.config = config or RaftConfig()
        # crc32, NOT hash(): string hashing is randomized per process and
        # would silently break cross-process determinism of every sim.
        self.rng = random.Random(zlib.crc32(node_id.encode()) ^ (seed * 2654435761 % 2**32))
        self.apply_fn = apply_fn

        # Persistent state.
        self.term = 0
        self.voted_for: Optional[NodeId] = None
        self.log: List[Slot] = []  # log[p] holds index p+1

        # Volatile state.
        self.role = Role.FOLLOWER
        self.commit_index = 0
        self.last_applied = 0
        self.leader_id: Optional[NodeId] = None

        # Leader state.
        self.next_index: Dict[NodeId, int] = {}
        self.match_index: Dict[NodeId, int] = {}

        # Candidate state.
        self.votes_received: Dict[NodeId, RequestVoteReply] = {}

        # Timers (absolute sim times).
        self.election_deadline = 0.0
        self.next_heartbeat = 0.0

        # Dedup / bookkeeping.
        self._entry_index: Dict[EntryId, int] = {}
        self._pending_client: List[Tuple[Any, EntryId]] = []  # no-leader queue
        self._seq = 0
        self.alive = True
        self.metrics = None  # injected by the harness (core.metrics.Recorder)

    # ---------------------------------------------------------------- util

    @property
    def m(self) -> int:
        return len(self.members)

    def quorum(self) -> int:
        return majority(self.m)

    def last_log_index(self) -> int:
        return len(self.log)

    def term_at(self, index: int) -> int:
        if index == 0:
            return 0
        return self.log[index - 1].entry.term

    def slot(self, index: int) -> Optional[Slot]:
        if 1 <= index <= len(self.log):
            return self.log[index - 1]
        return None

    def peers(self) -> List[NodeId]:
        return [n for n in self.members if n != self.id]

    def next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def _count(self, kind: str, n: int = 1) -> None:
        if self.metrics is not None:
            self.metrics.count(kind, n)

    # ------------------------------------------------------ election state

    def _reset_election_timer(self, now: float) -> None:
        c = self.config
        self.election_deadline = now + self.rng.uniform(
            c.election_timeout_min, c.election_timeout_max
        )

    def _become_follower(self, term: int, now: float) -> None:
        if term > self.term:
            self.term = term
            self.voted_for = None
        self.role = Role.FOLLOWER
        self.votes_received = {}
        self._reset_election_timer(now)

    def _become_candidate(self, now: float) -> Outputs:
        self.term += 1
        self.role = Role.CANDIDATE
        self.voted_for = self.id
        self.leader_id = None
        self.votes_received = {}
        self._reset_election_timer(now)
        self._count("elections")
        lli, llt = self._election_log_position()
        args = RequestVoteArgs(
            term=self.term,
            src=self.id,
            candidate_id=self.id,
            last_log_index=lli,
            last_log_term=llt,
        )
        # Vote for self (record a synthetic reply so recovery sees our tail).
        self.votes_received[self.id] = RequestVoteReply(
            term=self.term,
            src=self.id,
            vote_granted=True,
            tentative_tail=self._tentative_tail(),
            last_log_index=self.last_log_index(),
        )
        out: Outputs = [(p, args) for p in self.peers()]
        return out + self._maybe_win_election(now)

    def _become_leader(self, now: float) -> Outputs:
        self.role = Role.LEADER
        self.leader_id = self.id
        self.next_index = {p: self.last_log_index() + 1 for p in self.peers()}
        self.match_index = {p: 0 for p in self.peers()}
        self.next_heartbeat = now  # fire immediately
        self._count("leader_elected")
        if self.metrics is not None:
            self.metrics.leader_elected(self.id, self.term)
        out = self._on_leadership_acquired(now)  # FastRaft hook (recovery)
        out += self._flush_pending(now)
        return out + self._broadcast_append_entries(now)

    def _maybe_win_election(self, now: float) -> Outputs:
        grants = sum(1 for r in self.votes_received.values() if r.vote_granted)
        if self.role is Role.CANDIDATE and grants >= self.quorum():
            return self._become_leader(now)
        return []

    # ---- Hooks overridden by FastRaftNode -------------------------------

    def _election_log_position(self) -> Tuple[int, int]:
        """(last_log_index, last_log_term) used in up-to-dateness checks.

        FastRaft hook: tentative fast-track slots are *excluded* there —
        they are recovered by the new leader from vote replies instead.
        """
        return self.last_log_index(), self.term_at(self.last_log_index())

    def _tentative_tail(self) -> Optional[dict]:
        return None  # FastRaft hook

    def _on_leadership_acquired(self, now: float) -> Outputs:
        return []  # FastRaft hook: slot recovery

    def _on_slot_overwritten(self, index: int, old: Slot, new: Slot) -> None:
        pass  # FastRaft hook: re-propose displaced commands

    # --------------------------------------------------------------- ticks

    def start(self, now: float) -> None:
        self._reset_election_timer(now)

    def on_tick(self, now: float) -> Outputs:
        if not self.alive:
            return []
        out: Outputs = []
        if self.role is Role.LEADER:
            if now >= self.next_heartbeat:
                self.next_heartbeat = now + self.config.heartbeat_interval
                out += self._broadcast_append_entries(now)
        elif now >= self.election_deadline:
            out += self._become_candidate(now)
        out += self._tick_protocol(now)  # FastRaft hook (fast-slot timeouts)
        return out

    def _tick_protocol(self, now: float) -> Outputs:
        return []

    # ------------------------------------------------------------ messages

    def on_message(self, msg: Message, now: float) -> Outputs:
        if not self.alive:
            return []
        self._count("msgs_in")
        if msg.term > self.term:
            self._become_follower(msg.term, now)
        handler = getattr(self, f"_handle_{type(msg).__name__}", None)
        if handler is None:
            return []
        return handler(msg, now)

    # -- RequestVote

    def _handle_RequestVoteArgs(self, msg: RequestVoteArgs, now: float) -> Outputs:
        grant = False
        if msg.term >= self.term:
            lli, llt = self._election_log_position()
            up_to_date = (msg.last_log_term, msg.last_log_index) >= (llt, lli)
            if up_to_date and self.voted_for in (None, msg.candidate_id):
                grant = True
                self.voted_for = msg.candidate_id
                self._reset_election_timer(now)
        reply = RequestVoteReply(
            term=self.term,
            src=self.id,
            vote_granted=grant,
            tentative_tail=self._tentative_tail() if grant else None,
            last_log_index=self.last_log_index(),
        )
        return [(msg.src, reply)]

    def _handle_RequestVoteReply(self, msg: RequestVoteReply, now: float) -> Outputs:
        if self.role is not Role.CANDIDATE or msg.term < self.term:
            return []
        self.votes_received[msg.src] = msg
        return self._maybe_win_election(now)

    # -- AppendEntries

    def _broadcast_append_entries(self, now: float) -> Outputs:
        out: Outputs = []
        for p in self.peers():
            out.append((p, self._make_append_entries(p)))
        self._count("msgs_out", len(out))
        return out

    def _make_append_entries(self, peer: NodeId) -> AppendEntriesArgs:
        ni = self.next_index.get(peer, self.last_log_index() + 1)
        prev = ni - 1
        entries = tuple(s.clone() for s in self.log[prev : prev + 64])
        return AppendEntriesArgs(
            term=self.term,
            src=self.id,
            leader_id=self.id,
            prev_log_index=prev,
            prev_log_term=self.term_at(prev),
            entries=entries,
            leader_commit=self.commit_index,
        )

    def _handle_AppendEntriesArgs(self, msg: AppendEntriesArgs, now: float) -> Outputs:
        if msg.term < self.term:
            return [(msg.src, AppendEntriesReply(term=self.term, src=self.id))]
        # Valid leader for this term.
        first_leader_contact = self.leader_id != msg.leader_id
        self.leader_id = msg.leader_id
        if self.role is not Role.FOLLOWER:
            self._become_follower(msg.term, now)
        self._reset_election_timer(now)
        deferred: Outputs = self._flush_pending(now) if first_leader_contact else []

        # Consistency check. Tentative slots don't count as matching history:
        # only CLASSIC/FINALIZED slots anchor prev_log_term.
        if msg.prev_log_index > 0:
            s = self.slot(msg.prev_log_index)
            if s is None or (
                s.entry.term != msg.prev_log_term and s.state is not SlotState.TENTATIVE
            ) or (s.state is SlotState.TENTATIVE):
                # A tentative slot at prev is not authoritative history; ask
                # the leader to back up and ship it classically.
                return deferred + [
                    (
                        msg.src,
                        AppendEntriesReply(
                            term=self.term, src=self.id, success=False, match_index=0
                        ),
                    )
                ]
        # Append / overwrite.
        for k, incoming in enumerate(msg.entries):
            idx = msg.prev_log_index + 1 + k
            cur = self.slot(idx)
            if cur is not None and cur.entry.term == incoming.entry.term and cur.entry.same_entry(incoming.entry):
                # Matching entry: possibly upgrade state (tentative->classic).
                if cur.state is SlotState.TENTATIVE:
                    cur.state = incoming.state
                continue
            if cur is not None:
                # Conflict: truncate from idx (Raft rule), after notifying.
                self._on_slot_overwritten(idx, cur, incoming)
                self._truncate_from(idx)
            self._append_slot(incoming.clone())
        if msg.leader_commit > self.commit_index:
            self._advance_commit(min(msg.leader_commit, self._durable_prefix()), now)
        reply = AppendEntriesReply(
            term=self.term,
            src=self.id,
            success=True,
            match_index=msg.prev_log_index + len(msg.entries),
        )
        return deferred + [(msg.src, reply)]

    def _handle_AppendEntriesReply(self, msg: AppendEntriesReply, now: float) -> Outputs:
        if self.role is not Role.LEADER or msg.term < self.term:
            return []
        if msg.success:
            self.match_index[msg.src] = max(self.match_index.get(msg.src, 0), msg.match_index)
            self.next_index[msg.src] = self.match_index[msg.src] + 1
            return self._leader_advance_commit(now)
        # Back up (simple decrement; fine at sim scale).
        self.next_index[msg.src] = max(1, self.next_index.get(msg.src, 1) - 8)
        return [(msg.src, self._make_append_entries(msg.src))]

    # -- client path

    def client_request(
        self, command: Any, now: float, entry_id: Optional[EntryId] = None
    ) -> Outputs:
        """Entry point for a client command submitted at this node."""
        if not self.alive:
            return []
        entry_id = entry_id or EntryId(self.id, self.next_seq())
        if entry_id in self._entry_index:
            return []  # duplicate retry
        if self.metrics is not None:
            self.metrics.submitted(entry_id, now, mode=self._submit_mode())
        if self.role is Role.LEADER:
            return self._leader_append(command, entry_id, now)
        return self._non_leader_submit(command, entry_id, now)

    def _submit_mode(self) -> str:
        return "classic"  # FastRaft hook

    def _non_leader_submit(self, command: Any, entry_id: EntryId, now: float) -> Outputs:
        # Classic track: forward to the last known leader. FastRaft overrides.
        if self.leader_id is not None and self.leader_id != self.id:
            fwd = ForwardOperation(
                term=self.term, src=self.id, command=command, entry_id=entry_id
            )
            self._count("forwards")
            return [(self.leader_id, fwd)]
        # No leader known yet: queue and flush once one is discovered.
        self._pending_client.append((command, entry_id))
        return []

    def _flush_pending(self, now: float) -> Outputs:
        if not self._pending_client:
            return []
        pending, self._pending_client = self._pending_client, []
        out: Outputs = []
        for command, entry_id in pending:
            if entry_id in self._entry_index:
                continue
            if self.role is Role.LEADER:
                out += self._leader_append(command, entry_id, now)
            else:
                out += self._non_leader_submit(command, entry_id, now)
        return out

    def _handle_ForwardOperation(self, msg: ForwardOperation, now: float) -> Outputs:
        if self.role is not Role.LEADER:
            if self.leader_id and self.leader_id != self.id:
                return [(self.leader_id, msg)]  # re-forward
            return []
        return self._leader_append(msg.command, msg.entry_id, now)

    def _leader_append(self, command: Any, entry_id: EntryId, now: float) -> Outputs:
        if entry_id in self._entry_index:
            return []
        e = Entry(term=self.term, command=command, entry_id=entry_id, proposed_at=now)
        self._append_slot(Slot(e, SlotState.CLASSIC))
        self._count("proposals")
        # Replicate immediately (don't wait for the heartbeat).
        return self._broadcast_append_entries(now)

    # ---------------------------------------------------------- log & commit

    def _append_slot(self, s: Slot) -> None:
        self.log.append(s)
        self._entry_index[s.entry.entry_id] = len(self.log)

    def _truncate_from(self, index: int) -> None:
        for p in range(index - 1, len(self.log)):
            self._entry_index.pop(self.log[p].entry.entry_id, None)
        del self.log[index - 1 :]

    def _durable_prefix(self) -> int:
        """Largest index i such that slots 1..i are all non-tentative."""
        i = 0
        for s in self.log:
            if s.state is SlotState.TENTATIVE:
                break
            i += 1
        return i

    def _leader_advance_commit(self, now: float) -> Outputs:
        # Largest N replicated on a majority with term == current term.
        for n in range(self.last_log_index(), self.commit_index, -1):
            s = self.slot(n)
            if s.state is SlotState.TENTATIVE or self.term_at(n) != self.term:
                continue
            votes = 1 + sum(1 for p in self.peers() if self.match_index.get(p, 0) >= n)
            if votes >= self.quorum():
                self._advance_commit(n, now)
                break
        return []

    def _advance_commit(self, new_commit: int, now: float) -> None:
        new_commit = min(new_commit, self._durable_prefix())
        if new_commit <= self.commit_index:
            return
        self.commit_index = new_commit
        while self.last_applied < self.commit_index:
            self.last_applied += 1
            s = self.slot(self.last_applied)
            self._apply(self.last_applied, s.entry, now)

    def _apply(self, index: int, entry: Entry, now: float) -> None:
        cmd = entry.command
        if isinstance(cmd, str) and cmd.startswith(CONFIG_PREFIX):
            self._apply_config(cmd)
        if self.metrics is not None:
            self.metrics.committed(self.id, index, entry, now)
        if self.apply_fn is not None:
            self.apply_fn(index, entry)

    # ------------------------------------------------------------ membership

    def _apply_config(self, cmd: str) -> None:
        new_members = sorted(cmd[len(CONFIG_PREFIX):].split(","))
        self.members = new_members
        if self.role is Role.LEADER:
            for p in self.peers():
                self.next_index.setdefault(p, self.last_log_index() + 1)
                self.match_index.setdefault(p, 0)
            self.next_index = {p: self.next_index[p] for p in self.peers()}
            self.match_index = {p: self.match_index[p] for p in self.peers()}

    @staticmethod
    def config_command(members: List[NodeId]) -> str:
        return CONFIG_PREFIX + ",".join(sorted(members))

    # --------------------------------------------------------------- debug

    def committed_commands(self) -> List[Any]:
        return [self.log[i].entry.command for i in range(self.commit_index)]

    def log_summary(self) -> List[Tuple[int, str, str]]:
        return [
            (s.entry.term, str(s.entry.entry_id), s.state.value) for s in self.log
        ]

    def crash(self) -> None:
        self.alive = False

    def restart(self, now: float) -> None:
        """Crash-recovery: persistent state (term, voted_for, log) survives;
        volatile state resets."""
        self.alive = True
        self.role = Role.FOLLOWER
        self.leader_id = None
        self.votes_received = {}
        self.next_index = {}
        self.match_index = {}
        self.commit_index = 0
        self.last_applied = 0
        self._reset_election_timer(now)
