"""Classic Raft (Ongaro & Ousterhout 2014), event-driven and transport-free.

A node never touches a socket or a clock: the harness (``repro.core.sim`` in
CI, a gRPC shim in production) delivers messages via :meth:`on_message`,
drives time via :meth:`on_tick`, and sends whatever list of ``(dst, msg)``
pairs a handler returns. This is what makes hypothesis-driven schedule
exploration possible: every interleaving the simulator can produce is a real
execution of the node code.

The class is written to be subclassed by :class:`repro.core.fast_raft.
FastRaftNode`; the hooks it overrides are marked ``# FastRaft hook``.

Replication is batched and pipelined: client bursts coalesce into
multi-entry AppendEntries batches (``RaftConfig.max_batch_entries``,
optionally buffered for ``batch_window`` sim-ms), and a leader keeps up to
``max_inflight_batches`` un-acked batches in flight per follower — each
heartbeat re-opens the pipeline from ``next_index``, doubling as
retransmission. The committed prefix compacts into a
:class:`repro.core.types.Snapshot` every ``snapshot_threshold`` applied
entries; followers that fall behind the snapshot horizon are caught up via
InstallSnapshot instead of log replay.
"""
from __future__ import annotations

import copy
import dataclasses
import random
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.statemachine import DedupTable, LogListMachine, StateMachine
from repro.core.types import (
    AppendEntriesArgs,
    AppendEntriesReply,
    Entry,
    EntryId,
    ForwardOperation,
    InstallSnapshotArgs,
    InstallSnapshotChunk,
    InstallSnapshotChunkReply,
    InstallSnapshotReply,
    Message,
    NodeId,
    RequestVoteArgs,
    RequestVoteReply,
    Role,
    Slot,
    SlotState,
    Snapshot,
    majority,
    snapshot_from_bytes,
    snapshot_to_bytes,
)

Outputs = List[Tuple[NodeId, Message]]

CONFIG_PREFIX = "__config__:"  # membership-change commands


@dataclasses.dataclass
class RaftConfig:
    election_timeout_min: float = 150.0
    election_timeout_max: float = 300.0
    heartbeat_interval: float = 50.0
    # Fast Raft only (kept here so one config type serves both protocols):
    fast_track: bool = False
    fast_vote_timeout: float = 120.0  # slot falls back to classic after this
    max_fast_inflight: int = 64
    # Batched + pipelined replication:
    #   max_batch_entries   — entries per AppendEntries / FastPropose window.
    #   max_inflight_batches — un-acked AppendEntries batches a leader keeps
    #       in flight per follower between heartbeats (pipeline depth; the
    #       window re-opens from next_index at every heartbeat, which doubles
    #       as retransmission).
    #   batch_window — leader-side coalescing delay (sim-ms): client commands
    #       buffer up to this long (or max_batch_entries) before one
    #       append+broadcast. 0.0 = replicate immediately (seed behavior).
    max_batch_entries: int = 64
    max_inflight_batches: int = 4
    batch_window: float = 0.0
    # Snapshot / log compaction: once the applied prefix since the last
    # snapshot reaches this many entries, fold it into a Snapshot and drop it
    # from the log. 0 = never compact (seed behavior). Followers whose
    # next_index falls below the snapshot receive InstallSnapshot.
    snapshot_threshold: int = 0
    # Chunked snapshot transfer: when > 0, InstallSnapshot streams the
    # serialized snapshot in chunks of this many bytes (at most one chunk in
    # flight per follower, offset-based resume, retransmit on heartbeat) so
    # a lossy link resumes a partial transfer instead of restarting it.
    # 0 = single-message InstallSnapshot (seed behavior).
    snapshot_chunk_bytes: int = 0


@dataclasses.dataclass
class _SnapshotTransfer:
    """Leader-side progress of one chunked snapshot transfer to one
    follower. ``offset`` is the follower-acknowledged cursor: the next chunk
    always starts there, so a heartbeat retransmission after loss resends
    the unacked chunk rather than restarting the stream."""

    last_index: int
    last_term: int
    data: bytes
    offset: int = 0


class RaftNode:
    """One Raft participant. Deterministic given (config, seed, schedule)."""

    def __init__(
        self,
        node_id: NodeId,
        members: List[NodeId],
        config: Optional[RaftConfig] = None,
        seed: int = 0,
        apply_fn: Optional[Callable[[int, Entry], None]] = None,
        state_machine: Optional[StateMachine] = None,
    ):
        self.id = node_id
        self.members: List[NodeId] = list(members)
        self.config = config or RaftConfig()
        # crc32, NOT hash(): string hashing is randomized per process and
        # would silently break cross-process determinism of every sim.
        self.rng = random.Random(zlib.crc32(node_id.encode()) ^ (seed * 2654435761 % 2**32))
        self.apply_fn = apply_fn
        # The replicated state machine. Committed entries are applied to it
        # in index order; snapshots carry ITS reduced state, not entries.
        self.state_machine: StateMachine = state_machine or LogListMachine()
        # Compact exactly-once filter over applied EntryIds: keeps client
        # retry dedup exact after the prefix (and its ids) compacts away.
        self._dedup = DedupTable()

        # Persistent state.
        self.term = 0
        self.voted_for: Optional[NodeId] = None
        # log[p] holds absolute index snapshot_last_index + p + 1; the
        # committed prefix up to ``snapshot`` has been compacted away.
        self.log: List[Slot] = []
        self.snapshot: Optional[Snapshot] = None

        # Volatile state.
        self.role = Role.FOLLOWER
        self.commit_index = 0
        self.last_applied = 0
        self.leader_id: Optional[NodeId] = None

        # Leader state.
        self.next_index: Dict[NodeId, int] = {}
        self.match_index: Dict[NodeId, int] = {}
        # Replication pipeline: un-acked entry batches per follower and the
        # optimistic next send position (>= next_index). Both reset at every
        # heartbeat broadcast, which doubles as retransmission after loss.
        self._inflight: Dict[NodeId, int] = {}
        self._pipe_next: Dict[NodeId, int] = {}
        # Chunked snapshot transfers in progress (leader side), per follower.
        self._snap_xfer: Dict[NodeId, _SnapshotTransfer] = {}
        # Chunked snapshot being received (follower side):
        # {"last_index", "last_term", "data": bytearray}.
        self._incoming_snap: Optional[dict] = None

        # Leader-side client-command coalescing (config.batch_window > 0).
        self._batch_buffer: List[Tuple[Any, EntryId]] = []
        self._buffered_ids: set = set()
        self._batch_deadline = 0.0
        # Persistence hooks, wired by the harness (e.g. checkpoint.
        # SnapshotStore): snapshot_sink(node_id, snapshot) after each
        # compaction; hard_state_sink(node_id, term, voted_for, seq)
        # whenever Raft hard state changes — term/voted_for MUST be durable
        # before acting on them (double-vote safety) and seq must never
        # regress (EntryId dedup safety), so a host replacement restoring
        # only persisted state stays correct.
        self.snapshot_sink: Optional[Callable[[NodeId, Snapshot], None]] = None
        self.hard_state_sink: Optional[
            Callable[[NodeId, int, Optional[NodeId], int], None]
        ] = None

        # Candidate state.
        self.votes_received: Dict[NodeId, RequestVoteReply] = {}

        # Timers (absolute sim times).
        self.election_deadline = 0.0
        self.next_heartbeat = 0.0

        # Dedup / bookkeeping.
        self._entry_index: Dict[EntryId, int] = {}
        self._pending_client: List[Tuple[Any, EntryId]] = []  # no-leader queue
        self._seq = 0
        self.alive = True
        self.metrics = None  # injected by the harness (core.metrics.Recorder)

    # ---------------------------------------------------------------- util

    @property
    def m(self) -> int:
        return len(self.members)

    def quorum(self) -> int:
        return majority(self.m)

    @property
    def snapshot_last_index(self) -> int:
        return self.snapshot.last_index if self.snapshot is not None else 0

    def last_log_index(self) -> int:
        return self.snapshot_last_index + len(self.log)

    def term_at(self, index: int) -> int:
        if index == 0:
            return 0
        if self.snapshot is not None and index <= self.snapshot.last_index:
            # Interior terms compacted away with the entries (the snapshot
            # state is opaque). last_term is exact at the boundary; for
            # interior indexes it is an approximation that is only ever used
            # as a heartbeat prev_log_term while a snapshot transfer is in
            # flight — a mismatch there just makes the follower reply false,
            # and the snapshot installs either way.
            return self.snapshot.last_term
        return self.log[index - self.snapshot_last_index - 1].entry.term

    def slot(self, index: int) -> Optional[Slot]:
        """The live (uncompacted) slot at absolute ``index``; None if the
        index is beyond the log OR compacted into the snapshot."""
        p = index - self.snapshot_last_index
        if 1 <= p <= len(self.log):
            return self.log[p - 1]
        return None

    def peers(self) -> List[NodeId]:
        return [n for n in self.members if n != self.id]

    def next_seq(self) -> int:
        self._seq += 1
        self._persist_hard_state()
        return self._seq

    def _persist_hard_state(self) -> None:
        if self.hard_state_sink is not None:
            self.hard_state_sink(self.id, self.term, self.voted_for, self._seq)

    def _seen(self, entry_id: EntryId) -> bool:
        """Has this EntryId been observed as a live log entry or an applied
        (possibly compacted) one? The client-retry dedup predicate."""
        return entry_id in self._entry_index or self._dedup.contains(entry_id)

    def _count(self, kind: str, n: int = 1) -> None:
        if self.metrics is not None:
            self.metrics.count(kind, n)

    # ------------------------------------------------------ election state

    def _reset_election_timer(self, now: float) -> None:
        c = self.config
        self.election_deadline = now + self.rng.uniform(
            c.election_timeout_min, c.election_timeout_max
        )

    def _become_follower(self, term: int, now: float) -> None:
        if term > self.term:
            self.term = term
            self.voted_for = None
            self._persist_hard_state()
        self.role = Role.FOLLOWER
        self.votes_received = {}
        # Commands coalescing in the leader batch buffer were never appended;
        # put them back on the client queue so they re-route to the new leader.
        if self._batch_buffer:
            self._pending_client.extend(self._batch_buffer)
            self._batch_buffer = []
            self._buffered_ids.clear()
        self._inflight = {}
        self._pipe_next = {}
        self._snap_xfer = {}
        self._reset_election_timer(now)

    def _become_candidate(self, now: float) -> Outputs:
        self.term += 1
        self.role = Role.CANDIDATE
        self.voted_for = self.id
        self._persist_hard_state()
        self.leader_id = None
        self.votes_received = {}
        self._reset_election_timer(now)
        self._count("elections")
        lli, llt = self._election_log_position()
        args = RequestVoteArgs(
            term=self.term,
            src=self.id,
            candidate_id=self.id,
            last_log_index=lli,
            last_log_term=llt,
        )
        # Vote for self (record a synthetic reply so recovery sees our tail).
        self.votes_received[self.id] = RequestVoteReply(
            term=self.term,
            src=self.id,
            vote_granted=True,
            tentative_tail=self._tentative_tail(),
            last_log_index=self.last_log_index(),
        )
        out: Outputs = [(p, args) for p in self.peers()]
        return out + self._maybe_win_election(now)

    def _become_leader(self, now: float) -> Outputs:
        self.role = Role.LEADER
        self.leader_id = self.id
        self.next_index = {p: self.last_log_index() + 1 for p in self.peers()}
        self.match_index = {p: 0 for p in self.peers()}
        self._inflight = {}
        self._pipe_next = {}
        self._snap_xfer = {}
        self.next_heartbeat = now  # fire immediately
        self._count("leader_elected")
        if self.metrics is not None:
            self.metrics.leader_elected(self.id, self.term)
        out = self._on_leadership_acquired(now)  # FastRaft hook (recovery)
        out += self._flush_pending(now)
        return out + self._broadcast_append_entries(now)

    def _maybe_win_election(self, now: float) -> Outputs:
        grants = sum(1 for r in self.votes_received.values() if r.vote_granted)
        if self.role is Role.CANDIDATE and grants >= self.quorum():
            return self._become_leader(now)
        return []

    # ---- Hooks overridden by FastRaftNode -------------------------------

    def _election_log_position(self) -> Tuple[int, int]:
        """(last_log_index, last_log_term) used in up-to-dateness checks.

        FastRaft hook: tentative fast-track slots are *excluded* there —
        they are recovered by the new leader from vote replies instead.
        """
        return self.last_log_index(), self.term_at(self.last_log_index())

    def _tentative_tail(self) -> Optional[dict]:
        return None  # FastRaft hook

    def _on_leadership_acquired(self, now: float) -> Outputs:
        return []  # FastRaft hook: slot recovery

    def _on_slot_overwritten(self, index: int, old: Slot, new: Slot) -> None:
        pass  # FastRaft hook: re-propose displaced commands

    # --------------------------------------------------------------- ticks

    def start(self, now: float) -> None:
        self._reset_election_timer(now)

    def on_tick(self, now: float) -> Outputs:
        if not self.alive:
            return []
        out: Outputs = []
        if self.role is Role.LEADER:
            if self._batch_buffer and now >= self._batch_deadline:
                out += self._flush_batch(now)
            if now >= self.next_heartbeat:
                self.next_heartbeat = now + self.config.heartbeat_interval
                out += self._broadcast_append_entries(now)
        elif now >= self.election_deadline:
            out += self._become_candidate(now)
        out += self._tick_protocol(now)  # FastRaft hook (fast-slot timeouts)
        return out

    def _tick_protocol(self, now: float) -> Outputs:
        return []

    # ------------------------------------------------------------ messages

    def on_message(self, msg: Message, now: float) -> Outputs:
        if not self.alive:
            return []
        self._count("msgs_in")
        if msg.term > self.term:
            self._become_follower(msg.term, now)
        handler = getattr(self, f"_handle_{type(msg).__name__}", None)
        if handler is None:
            return []
        return handler(msg, now)

    # -- RequestVote

    def _handle_RequestVoteArgs(self, msg: RequestVoteArgs, now: float) -> Outputs:
        grant = False
        if msg.term >= self.term:
            lli, llt = self._election_log_position()
            up_to_date = (msg.last_log_term, msg.last_log_index) >= (llt, lli)
            if up_to_date and self.voted_for in (None, msg.candidate_id):
                grant = True
                self.voted_for = msg.candidate_id
                self._persist_hard_state()
                self._reset_election_timer(now)
        reply = RequestVoteReply(
            term=self.term,
            src=self.id,
            vote_granted=grant,
            tentative_tail=self._tentative_tail() if grant else None,
            last_log_index=self.last_log_index(),
        )
        return [(msg.src, reply)]

    def _handle_RequestVoteReply(self, msg: RequestVoteReply, now: float) -> Outputs:
        if self.role is not Role.CANDIDATE or msg.term < self.term:
            return []
        self.votes_received[msg.src] = msg
        return self._maybe_win_election(now)

    # -- AppendEntries

    def _broadcast_append_entries(self, now: float) -> Outputs:
        """(Re)send replication traffic to every follower.

        Each broadcast re-opens the per-follower pipeline from next_index —
        the known-replicated point — so a broadcast doubles as retransmission
        of batches lost since the last one. Followers with nothing to pull
        get a plain heartbeat.
        """
        out: Outputs = []
        for p in self.peers():
            self._inflight[p] = 0
            self._pipe_next[p] = self.next_index.get(p, self.last_log_index() + 1)
            msgs = self._replicate_to_peer(p)
            if not msgs:
                msgs = [(p, self._heartbeat_for(p))]
            out += msgs
        self._count("msgs_out", len(out))
        return out

    def _heartbeat_for(self, peer: NodeId) -> AppendEntriesArgs:
        prev = min(
            self.next_index.get(peer, self.last_log_index() + 1) - 1,
            self.last_log_index(),
        )
        return AppendEntriesArgs(
            term=self.term,
            src=self.id,
            leader_id=self.id,
            prev_log_index=prev,
            prev_log_term=self.term_at(prev),
            entries=(),
            leader_commit=self.commit_index,
        )

    def _replicate_to_peer(self, peer: NodeId) -> Outputs:
        """Entry-bearing traffic for one follower: consecutive AppendEntries
        batches of <= max_batch_entries, pipelined up to max_inflight_batches
        outstanding — or one InstallSnapshot when the follower's next entry
        was compacted away."""
        ni = self.next_index.get(peer, self.last_log_index() + 1)
        if self.snapshot is not None and ni <= self.snapshot.last_index:
            return self._send_snapshot(peer)
        out: Outputs = []
        batch = max(1, self.config.max_batch_entries)
        depth = max(1, self.config.max_inflight_batches)
        start = max(ni, self._pipe_next.get(peer, ni))
        while start <= self.last_log_index() and self._inflight.get(peer, 0) < depth:
            lo = start - self.snapshot_last_index - 1  # list position
            entries = tuple(s.clone() for s in self.log[lo : lo + batch])
            out.append(
                (
                    peer,
                    AppendEntriesArgs(
                        term=self.term,
                        src=self.id,
                        leader_id=self.id,
                        prev_log_index=start - 1,
                        prev_log_term=self.term_at(start - 1),
                        entries=entries,
                        leader_commit=self.commit_index,
                    ),
                )
            )
            self._inflight[peer] = self._inflight.get(peer, 0) + 1
            start += len(entries)
            self._pipe_next[peer] = start
        return out

    def _send_snapshot(self, peer: NodeId) -> Outputs:
        """Catch a follower up past the compaction horizon: one monolithic
        InstallSnapshot (snapshot_chunk_bytes == 0) or the next chunk of a
        streamed transfer. Either way at most one message is in flight; the
        heartbeat broadcast clears the inflight mark and re-sends, which
        doubles as retransmission after loss."""
        if self._inflight.get(peer, 0) > 0:
            return []  # one snapshot message in flight at a time
        self._inflight[peer] = 1
        chunk = self.config.snapshot_chunk_bytes
        if chunk <= 0:
            self._count("snapshots_sent")
            # Pre-warm the size cache on OUR snapshot so every clone sent
            # (one per retransmission) inherits it instead of re-serializing
            # the whole state for the link model's size estimate.
            self.snapshot.size_bytes()
            return [
                (
                    peer,
                    InstallSnapshotArgs(
                        term=self.term,
                        src=self.id,
                        leader_id=self.id,
                        snapshot=self.snapshot.clone(),
                        leader_commit=self.commit_index,
                    ),
                )
            ]
        xfer = self._snap_xfer.get(peer)
        if xfer is None or xfer.last_index != self.snapshot.last_index:
            # New transfer (or the leader compacted again mid-transfer, which
            # changes the snapshot identity and restarts the stream).
            xfer = _SnapshotTransfer(
                last_index=self.snapshot.last_index,
                last_term=self.snapshot.last_term,
                data=snapshot_to_bytes(self.snapshot),
            )
            self._snap_xfer[peer] = xfer
            self._count("snapshots_sent")
        data = xfer.data[xfer.offset : xfer.offset + chunk]
        done = xfer.offset + len(data) >= len(xfer.data)
        self._count("snapshot_chunks_sent")
        return [
            (
                peer,
                InstallSnapshotChunk(
                    term=self.term,
                    src=self.id,
                    leader_id=self.id,
                    last_index=xfer.last_index,
                    last_term=xfer.last_term,
                    offset=xfer.offset,
                    data=data,
                    total_bytes=len(xfer.data),
                    done=done,
                    leader_commit=self.commit_index,
                ),
            )
        ]

    def _handle_AppendEntriesArgs(self, msg: AppendEntriesArgs, now: float) -> Outputs:
        if msg.term < self.term:
            return [(msg.src, AppendEntriesReply(term=self.term, src=self.id))]
        # Valid leader for this term.
        first_leader_contact = self.leader_id != msg.leader_id
        self.leader_id = msg.leader_id
        if self.role is not Role.FOLLOWER:
            self._become_follower(msg.term, now)
        self._reset_election_timer(now)
        deferred: Outputs = self._flush_pending(now) if first_leader_contact else []

        # Consistency check. Tentative slots don't count as matching history:
        # only CLASSIC/FINALIZED slots anchor prev_log_term. A prev inside
        # our snapshot is committed history and matches by definition.
        if msg.prev_log_index > self.snapshot_last_index:
            s = self.slot(msg.prev_log_index)
            if s is None or (
                s.entry.term != msg.prev_log_term and s.state is not SlotState.TENTATIVE
            ) or (s.state is SlotState.TENTATIVE):
                # A tentative slot at prev is not authoritative history; ask
                # the leader to back up and ship it classically.
                return deferred + [
                    (
                        msg.src,
                        AppendEntriesReply(
                            term=self.term, src=self.id, success=False, match_index=0
                        ),
                    )
                ]
        # Append / overwrite.
        for k, incoming in enumerate(msg.entries):
            idx = msg.prev_log_index + 1 + k
            if idx <= self.snapshot_last_index:
                continue  # compacted == committed; nothing to reconcile
            cur = self.slot(idx)
            if cur is not None and cur.entry.term == incoming.entry.term and cur.entry.same_entry(incoming.entry):
                # Matching entry: possibly upgrade state (tentative->classic).
                if cur.state is SlotState.TENTATIVE:
                    cur.state = incoming.state
                continue
            if cur is not None:
                # Conflict: truncate from idx (Raft rule), after notifying.
                self._on_slot_overwritten(idx, cur, incoming)
                self._truncate_from(idx)
            self._append_slot(incoming.clone())
        if msg.leader_commit > self.commit_index:
            self._advance_commit(min(msg.leader_commit, self._durable_prefix()), now)
        reply = AppendEntriesReply(
            term=self.term,
            src=self.id,
            success=True,
            match_index=msg.prev_log_index + len(msg.entries),
        )
        return deferred + [(msg.src, reply)]

    def _handle_AppendEntriesReply(self, msg: AppendEntriesReply, now: float) -> Outputs:
        if self.role is not Role.LEADER or msg.term < self.term:
            return []
        if msg.success:
            self._inflight[msg.src] = max(0, self._inflight.get(msg.src, 0) - 1)
            self.match_index[msg.src] = max(self.match_index.get(msg.src, 0), msg.match_index)
            self.next_index[msg.src] = self.match_index[msg.src] + 1
            self._pipe_next[msg.src] = max(
                self._pipe_next.get(msg.src, 0), self.next_index[msg.src]
            )
            out = self._leader_advance_commit(now)
            # Keep the pipeline full: the freed inflight slot immediately
            # carries the next batch if the follower still lags.
            more = self._replicate_to_peer(msg.src)
            self._count("msgs_out", len(more))
            return out + more
        # Back up (simple decrement; fine at sim scale) and restart the
        # pipeline from the new next_index.
        self.next_index[msg.src] = max(1, self.next_index.get(msg.src, 1) - 8)
        self._inflight[msg.src] = 0
        self._pipe_next[msg.src] = self.next_index[msg.src]
        more = self._replicate_to_peer(msg.src)
        self._count("msgs_out", len(more))
        return more

    # -- client path

    def client_request(
        self, command: Any, now: float, entry_id: Optional[EntryId] = None
    ) -> Outputs:
        """Entry point for a client command submitted at this node."""
        if not self.alive:
            return []
        entry_id = entry_id or EntryId(self.id, self.next_seq())
        if self._seen(entry_id) or entry_id in self._buffered_ids:
            return []  # duplicate retry
        if self.metrics is not None:
            self.metrics.submitted(entry_id, now, mode=self._submit_mode())
        if self.role is Role.LEADER:
            return self._leader_append(command, entry_id, now)
        return self._non_leader_submit(command, entry_id, now)

    def client_request_batch(
        self, pairs: List[Tuple[Any, EntryId]], now: float
    ) -> Outputs:
        """Batched entry point: a burst of client (command, entry_id) pairs
        submitted together moves as ONE batch — one multi-entry append on a
        leader, one relay RPC from a classic follower, one multi-slot
        FastPropose window on a fast-track proposer."""
        if not self.alive or not pairs:
            return []
        fresh = [
            (c, e)
            for c, e in pairs
            if not self._seen(e) and e not in self._buffered_ids
        ]
        if not fresh:
            return []
        mode = self._submit_mode()
        if self.metrics is not None:
            for _, e in fresh:
                self.metrics.submitted(e, now, mode=mode)
        if self.role is Role.LEADER:
            return self._leader_append_many(fresh, now)
        return self._non_leader_submit_batch(fresh, now)

    def _submit_mode(self) -> str:
        return "classic"  # FastRaft hook

    def _non_leader_submit(self, command: Any, entry_id: EntryId, now: float) -> Outputs:
        # Classic track: forward to the last known leader. FastRaft overrides.
        if self.leader_id is not None and self.leader_id != self.id:
            fwd = ForwardOperation(
                term=self.term, src=self.id, command=command, entry_id=entry_id
            )
            self._count("forwards")
            return [(self.leader_id, fwd)]
        # No leader known yet: queue and flush once one is discovered.
        self._pending_client.append((command, entry_id))
        return []

    def _non_leader_submit_batch(
        self, pairs: List[Tuple[Any, EntryId]], now: float
    ) -> Outputs:
        # Classic track: one relay RPC carries the whole burst. FastRaft
        # overrides with a multi-slot FastPropose window.
        if self.leader_id is not None and self.leader_id != self.id:
            head_cmd, head_id = pairs[0]
            fwd = ForwardOperation(
                term=self.term,
                src=self.id,
                command=head_cmd,
                entry_id=head_id,
                batch=tuple(pairs[1:]),
            )
            self._count("forwards")
            return [(self.leader_id, fwd)]
        self._pending_client.extend(pairs)
        return []

    def _flush_pending(self, now: float) -> Outputs:
        if not self._pending_client:
            return []
        pending, self._pending_client = self._pending_client, []
        out: Outputs = []
        for command, entry_id in pending:
            if self._seen(entry_id):
                continue
            if self.role is Role.LEADER:
                out += self._leader_append(command, entry_id, now)
            else:
                out += self._non_leader_submit(command, entry_id, now)
        return out

    def _handle_ForwardOperation(self, msg: ForwardOperation, now: float) -> Outputs:
        if self.role is not Role.LEADER:
            if self.leader_id and self.leader_id != self.id:
                return [(self.leader_id, msg)]  # re-forward
            return []
        pairs = [(msg.command, msg.entry_id)] + list(msg.batch)
        return self._leader_append_many(pairs, now)

    def _leader_append(self, command: Any, entry_id: EntryId, now: float) -> Outputs:
        return self._leader_append_many([(command, entry_id)], now)

    def _leader_append_many(
        self, pairs: List[Tuple[Any, EntryId]], now: float
    ) -> Outputs:
        """Append a burst of commands. With batch_window > 0 they coalesce in
        the leader buffer (flushed by size or deadline); otherwise they are
        appended and replicated immediately in one broadcast."""
        pairs = [
            (c, e)
            for c, e in pairs
            if not self._seen(e) and e not in self._buffered_ids
        ]
        if not pairs:
            return []
        if self.config.batch_window > 0:
            if not self._batch_buffer:
                self._batch_deadline = now + self.config.batch_window
            for c, e in pairs:
                self._batch_buffer.append((c, e))
                self._buffered_ids.add(e)
            if len(self._batch_buffer) >= self.config.max_batch_entries:
                return self._flush_batch(now)
            return []
        return self._append_and_replicate(pairs, now)

    def _flush_batch(self, now: float) -> Outputs:
        pairs, self._batch_buffer = self._batch_buffer, []
        self._buffered_ids.clear()
        return self._append_and_replicate(pairs, now)

    def _append_and_replicate(
        self, pairs: List[Tuple[Any, EntryId]], now: float
    ) -> Outputs:
        appended = False
        for command, entry_id in pairs:
            if self._seen(entry_id):
                continue
            e = Entry(term=self.term, command=command, entry_id=entry_id, proposed_at=now)
            self._append_slot(Slot(e, SlotState.CLASSIC))
            self._count("proposals")
            appended = True
        if not appended:
            return []
        # Replicate immediately (don't wait for the heartbeat).
        return self._broadcast_append_entries(now)

    # ---------------------------------------------------------- log & commit

    def _append_slot(self, s: Slot) -> None:
        self.log.append(s)
        self._entry_index[s.entry.entry_id] = self.last_log_index()

    def _truncate_from(self, index: int) -> None:
        start = index - self.snapshot_last_index
        assert start >= 1, f"cannot truncate compacted prefix at {index}"
        for p in range(start - 1, len(self.log)):
            self._entry_index.pop(self.log[p].entry.entry_id, None)
        del self.log[start - 1 :]

    def _durable_prefix(self) -> int:
        """Largest index i such that slots 1..i are all non-tentative."""
        i = self.snapshot_last_index  # compacted prefix is committed
        for s in self.log:
            if s.state is SlotState.TENTATIVE:
                break
            i += 1
        return i

    def _leader_advance_commit(self, now: float) -> Outputs:
        # Largest N replicated on a majority with term == current term.
        for n in range(self.last_log_index(), self.commit_index, -1):
            s = self.slot(n)
            if s.state is SlotState.TENTATIVE or self.term_at(n) != self.term:
                continue
            votes = 1 + sum(1 for p in self.peers() if self.match_index.get(p, 0) >= n)
            if votes >= self.quorum():
                self._advance_commit(n, now)
                break
        return []

    def _advance_commit(self, new_commit: int, now: float) -> None:
        new_commit = min(new_commit, self._durable_prefix())
        if new_commit <= self.commit_index:
            return
        self.commit_index = new_commit
        while self.last_applied < self.commit_index:
            self.last_applied += 1
            s = self.slot(self.last_applied)
            self._apply(self.last_applied, s.entry, now)
        t = self.config.snapshot_threshold
        if t > 0 and self.last_applied - self.snapshot_last_index >= t:
            self.compact()

    # ---------------------------------------------------- snapshot/compaction

    def compact(self) -> None:
        """Fold the whole applied prefix into ``self.snapshot`` — the state
        machine's reduced state plus the dedup filter — and drop it from the
        log. Safe at any time: only applied == committed entries are
        compacted, and followers that still need them are caught up via
        InstallSnapshot."""
        upto = self.last_applied
        if upto <= self.snapshot_last_index:
            return
        keep = upto - self.snapshot_last_index
        last_term = self.term_at(upto)
        for s in self.log[:keep]:
            # Applied ids live on in the dedup filter; drop the log mapping
            # so node memory tracks the machine's reduced state, not history.
            self._entry_index.pop(s.entry.entry_id, None)
        self.snapshot = Snapshot(
            last_index=upto,
            last_term=last_term,
            state=self.state_machine.snapshot(),
            members=tuple(self.members),
            dedup=self._dedup.state(),
        )
        del self.log[:keep]
        self._count("compactions")
        if self.snapshot_sink is not None:
            self.snapshot_sink(self.id, self.snapshot)

    def restore_snapshot(self, snap: Snapshot) -> None:
        """Cold-start from a persisted snapshot (fresh host replacing a lost
        one): the snapshot becomes the whole committed state. The state
        machine jumps to the snapshot state — nothing is re-applied."""
        self.snapshot = snap.clone()
        self.log = []
        self._entry_index = {}
        self.state_machine.restore(copy.deepcopy(snap.state))
        self._dedup = DedupTable.from_state(snap.dedup)
        self.commit_index = snap.last_index
        self.last_applied = snap.last_index
        self.term = max(self.term, snap.last_term)
        self.members = sorted(snap.members)
        # Floor for seq reuse from the snapshot's dedup filter; the
        # authoritative value comes from restore_hard_state (seqs burned
        # after the last compaction are not in the snapshot).
        self._seq = max(self._seq, self._dedup.max_seq(self.id))

    def restore_hard_state(
        self, term: int, voted_for: Optional[NodeId], seq: int
    ) -> None:
        """Adopt persisted Raft hard state on a cold start. Without this a
        replaced node could double-vote in a term it already voted in, or
        mint EntryIds that collide with ones it burned before the crash."""
        if term >= self.term:
            self.term = term
            self.voted_for = voted_for
        self._seq = max(self._seq, seq)

    def _install_snapshot(self, snap: Snapshot, now: float) -> None:
        """Follower-side InstallSnapshot: adopt the leader's compacted prefix.

        If the snapshot is ahead of our applied state, the state machine
        JUMPS to the snapshot state (reduced state replaces replay — the
        whole point of state-machine snapshots); any log suffix beyond the
        snapshot that matches last_term is retained.
        """
        if snap.last_index <= self.snapshot_last_index:
            return
        # Retain a matching live suffix; drop everything else. (If we had
        # applied past snap.last_index, those entries are committed, so our
        # term at snap.last_index necessarily matches and the suffix stays.)
        suffix: List[Slot] = []
        if self.last_log_index() > snap.last_index and self.term_at(
            snap.last_index
        ) == snap.last_term:
            lo = snap.last_index - self.snapshot_last_index
            if lo >= 0:
                suffix = self.log[lo:]
        if snap.last_index > self.last_applied:
            self.state_machine.restore(copy.deepcopy(snap.state))
            self._dedup = DedupTable.from_state(snap.dedup)
            self.last_applied = snap.last_index
        self.commit_index = max(self.commit_index, snap.last_index)
        self.snapshot = snap.clone()
        self.log = suffix
        self._entry_index = {
            s.entry.entry_id: snap.last_index + p + 1
            for p, s in enumerate(self.log)
        }
        self.members = sorted(snap.members)
        self._count("snapshots_installed")

    def _handle_InstallSnapshotArgs(self, msg: InstallSnapshotArgs, now: float) -> Outputs:
        if msg.term < self.term or msg.snapshot is None:
            return [
                (msg.src, InstallSnapshotReply(term=self.term, src=self.id, match_index=0))
            ]
        self.leader_id = msg.leader_id
        if self.role is not Role.FOLLOWER:
            self._become_follower(msg.term, now)
        self._reset_election_timer(now)
        snap = msg.snapshot
        if snap.last_index > self.commit_index:
            self._install_snapshot(snap, now)
        if msg.leader_commit > self.commit_index:
            self._advance_commit(min(msg.leader_commit, self._durable_prefix()), now)
        # Ack with what we durably hold so the leader resumes AppendEntries
        # pipelining right above it.
        match = max(snap.last_index, self.commit_index)
        return [
            (msg.src, InstallSnapshotReply(term=self.term, src=self.id, match_index=match))
        ]

    def _handle_InstallSnapshotReply(self, msg: InstallSnapshotReply, now: float) -> Outputs:
        if self.role is not Role.LEADER or msg.term < self.term:
            return []
        self._inflight[msg.src] = 0
        if msg.match_index <= 0:
            return []
        return self._snapshot_delivered(msg.src, msg.match_index, now)

    def _snapshot_delivered(self, peer: NodeId, match_index: int, now: float) -> Outputs:
        """Leader bookkeeping once a follower holds the snapshot: resume
        normal AppendEntries pipelining right above it.

        The reply's match_index OVERWRITES (not maxes) our record: a host
        replaced from its checkpoint volume legitimately regresses below the
        match its lost incarnation reached, and keeping the stale (higher)
        match would pin next_index above entries the replacement does not
        have — an AppendEntries-reject / InstallSnapshot livelock whenever
        our own snapshot horizon sits below the stale match. The converse
        hazard (a jitter-delayed old reply briefly regressing a healthy
        follower's match) self-heals in one round: the follower's next
        AppendEntries/chunk reply reports its true position — chunk
        requests at or below its commit short-circuit with
        match_index=commit_index — so at most one redundant message is
        sent, which is the right trade against a permanent livelock."""
        self._snap_xfer.pop(peer, None)
        self.match_index[peer] = match_index
        self.next_index[peer] = self.match_index[peer] + 1
        self._pipe_next[peer] = self.next_index[peer]
        out = self._leader_advance_commit(now)
        more = self._replicate_to_peer(peer)
        self._count("msgs_out", len(more))
        return out + more

    # ------------------------------------------------- chunked transfer

    def _handle_InstallSnapshotChunk(self, msg: InstallSnapshotChunk, now: float) -> Outputs:
        if msg.term < self.term:
            return [
                (
                    msg.src,
                    InstallSnapshotChunkReply(
                        term=self.term, src=self.id, last_index=msg.last_index
                    ),
                )
            ]
        self.leader_id = msg.leader_id
        if self.role is not Role.FOLLOWER:
            self._become_follower(msg.term, now)
        self._reset_election_timer(now)
        if msg.last_index <= self.commit_index:
            # Already caught up past this snapshot (e.g. a duplicate final
            # chunk after install): tell the leader where to resume.
            return [
                (
                    msg.src,
                    InstallSnapshotChunkReply(
                        term=self.term,
                        src=self.id,
                        last_index=msg.last_index,
                        match_index=self.commit_index,
                    ),
                )
            ]
        buf = self._incoming_snap
        if buf is None or buf["last_index"] != msg.last_index:
            if buf is not None:
                # A different snapshot supersedes the partial transfer (the
                # leader compacted again, or a new leader took over with a
                # different horizon). Plain loss never lands here: retries
                # carry the same identity and resume at our cursor.
                self._count("snapshot_transfer_restarts")
            buf = {
                "last_index": msg.last_index,
                "last_term": msg.last_term,
                "data": bytearray(),
            }
            self._incoming_snap = buf
        cursor = len(buf["data"])
        if msg.offset == cursor and msg.data:
            buf["data"] += msg.data
            cursor = len(buf["data"])
        elif msg.offset < cursor:
            self._count("snapshot_chunk_dups")  # retransmit of acked bytes
        # msg.offset > cursor: a gap (we lost our buffer, e.g. restart
        # mid-transfer); replying with our cursor rewinds the leader.
        if msg.done and cursor >= msg.total_bytes:
            snap = snapshot_from_bytes(bytes(buf["data"]))
            self._incoming_snap = None
            if snap.last_index > self.commit_index:
                self._install_snapshot(snap, now)
            if msg.leader_commit > self.commit_index:
                self._advance_commit(
                    min(msg.leader_commit, self._durable_prefix()), now
                )
            return [
                (
                    msg.src,
                    InstallSnapshotChunkReply(
                        term=self.term,
                        src=self.id,
                        last_index=msg.last_index,
                        next_offset=cursor,
                        match_index=max(snap.last_index, self.commit_index),
                    ),
                )
            ]
        return [
            (
                msg.src,
                InstallSnapshotChunkReply(
                    term=self.term,
                    src=self.id,
                    last_index=msg.last_index,
                    next_offset=cursor,
                ),
            )
        ]

    def _handle_InstallSnapshotChunkReply(
        self, msg: InstallSnapshotChunkReply, now: float
    ) -> Outputs:
        if self.role is not Role.LEADER or msg.term < self.term:
            return []
        self._inflight[msg.src] = 0
        if msg.match_index > 0:
            return self._snapshot_delivered(msg.src, msg.match_index, now)
        xfer = self._snap_xfer.get(msg.src)
        if xfer is None or xfer.last_index != msg.last_index:
            # Stale reply for a superseded transfer; the next
            # _replicate_to_peer (below or at the heartbeat) restarts it.
            more = self._replicate_to_peer(msg.src)
            self._count("msgs_out", len(more))
            return more
        if msg.next_offset == xfer.offset:
            # Duplicate ack of the position we are already at (a heartbeat
            # retransmission produced a second reply, or our chunk is still
            # in flight). Reacting would fork a parallel chunk stream —
            # the heartbeat covers the genuinely-lost-chunk case.
            return []
        # The follower's cursor is authoritative: normally it advances past
        # the chunk we sent; after a follower restart it legitimately
        # rewinds to 0. Either way the transfer RESUMES there.
        xfer.offset = max(0, min(msg.next_offset, len(xfer.data)))
        more = self._replicate_to_peer(msg.src)
        self._count("msgs_out", len(more))
        return more

    def _apply(self, index: int, entry: Entry, now: float) -> None:
        cmd = entry.command
        if isinstance(cmd, str) and cmd.startswith(CONFIG_PREFIX):
            self._apply_config(cmd)
        self._dedup.add(entry.entry_id)
        self.state_machine.apply(index, entry)
        if self.metrics is not None:
            self.metrics.committed(self.id, index, entry, now)
        if self.apply_fn is not None:
            self.apply_fn(index, entry)

    # ------------------------------------------------------------ membership

    def _apply_config(self, cmd: str) -> None:
        new_members = sorted(cmd[len(CONFIG_PREFIX):].split(","))
        self.members = new_members
        if self.role is Role.LEADER:
            for p in self.peers():
                self.next_index.setdefault(p, self.last_log_index() + 1)
                self.match_index.setdefault(p, 0)
            self.next_index = {p: self.next_index[p] for p in self.peers()}
            self.match_index = {p: self.match_index[p] for p in self.peers()}

    @staticmethod
    def config_command(members: List[NodeId]) -> str:
        return CONFIG_PREFIX + ",".join(sorted(members))

    # --------------------------------------------------------------- debug

    def committed_entries(self) -> List[Entry]:
        """All committed entries this node can enumerate, in index order.

        With the default LogListMachine the machine retains the full applied
        history, so this is the complete committed sequence exactly as in
        the seed. Reduced-state machines (KV) cannot enumerate the compacted
        prefix; only the applied-through-live-log tail is returned (use the
        machine's own state for cross-node divergence checks)."""
        out = self.state_machine.applied_entries()
        if out is None:
            out = []
            base = self.last_applied - self.snapshot_last_index
            for p in range(max(0, base)):
                out.append(self.log[p].entry)
            return out
        # The machine's history covers 1..last_applied; last_applied tracks
        # commit_index everywhere in this codebase (commit applies eagerly).
        return out

    def committed_commands(self) -> List[Any]:
        return [e.command for e in self.committed_entries()]

    def committed_by_index(self) -> Dict[int, Entry]:
        """Enumerable committed entries keyed by ABSOLUTE log index.

        The single source of truth for cross-node agreement checks: a
        reduced-state machine's history is a tail starting above its own
        compaction horizon, so comparisons must align on absolute index
        (the enumerable range always ends at last_applied)."""
        hist = self.committed_entries()
        start = self.last_applied - len(hist) + 1
        return {start + i: e for i, e in enumerate(hist)}

    def has_applied(self, entry_id: EntryId) -> bool:
        """Exact membership oracle over this node's applied (= committed)
        entries, valid across compaction for ANY state machine — the dedup
        filter carries it even when entries can no longer be enumerated."""
        return self._dedup.contains(entry_id)

    def log_summary(self) -> List[Tuple[int, str, str]]:
        return [
            (s.entry.term, str(s.entry.entry_id), s.state.value) for s in self.log
        ]

    def crash(self) -> None:
        self.alive = False

    def restart(self, now: float) -> None:
        """Crash-recovery: persistent state (term, voted_for, log, snapshot)
        survives; volatile state resets. The state machine rolls back to the
        last snapshot (or empty) and the suffix re-applies as commit
        re-advances — exactly the snapshot-plus-replay recovery a durable
        deployment performs."""
        self.alive = True
        self.role = Role.FOLLOWER
        self.leader_id = None
        self.votes_received = {}
        self.next_index = {}
        self.match_index = {}
        self._inflight = {}
        self._pipe_next = {}
        self._snap_xfer = {}
        self._incoming_snap = None
        self._batch_buffer = []
        self._buffered_ids = set()
        if self.snapshot is not None:
            self.state_machine.restore(copy.deepcopy(self.snapshot.state))
            self._dedup = DedupTable.from_state(self.snapshot.dedup)
        else:
            self.state_machine.restore(None)
            self._dedup = DedupTable()
        self.commit_index = self.snapshot_last_index
        self.last_applied = self.snapshot_last_index
        self._reset_election_timer(now)
