"""Commit-latency / message-round accounting for consensus experiments.

The paper measures (a) average commit latency under varying random packet
loss (Figure 1) and (b) — from the original Fast Raft paper — the average
number of message rounds to commit. We record per-entry lifecycle events and
derive both: in a loss-free constant-latency network, rounds-to-commit is
exactly ``latency / one_way_delay``.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Dict, List, Optional, Tuple

from repro.core.types import Entry, EntryId, NodeId


@dataclasses.dataclass
class EntryTrace:
    entry_id: EntryId
    submitted_at: float = -1.0
    mode: str = "?"            # "fast" | "classic" at submission time
    fallbacks: int = 0
    first_commit_at: float = -1.0
    committed_index: int = -1

    @property
    def committed(self) -> bool:
        return self.first_commit_at >= 0

    @property
    def latency(self) -> Optional[float]:
        if self.committed and self.submitted_at >= 0:
            return self.first_commit_at - self.submitted_at
        return None


class Recorder:
    def __init__(self) -> None:
        self.traces: Dict[EntryId, EntryTrace] = {}
        self.counters: Dict[str, int] = {}
        # node -> list[(index, entry_id)] in apply order, for invariants.
        self.applied: Dict[NodeId, List] = {}
        # Safety invariants enforced online:
        self.committed_at: Dict[int, EntryId] = {}   # commit safety
        self.leaders: Dict[int, set] = {}            # election safety
        # Commit watchers: sets of EntryIds that still await their FIRST
        # commit; committed() discards ids as they land, so a waiter's stop
        # predicate is an O(1) emptiness check instead of a scan over its
        # whole entry list every check interval (Cluster.run_until_committed
        # registers one per call). Purely observational: watchers never
        # schedule events or perturb the simulation schedule.
        self.commit_watchers: List[set] = []
        # Wire accounting: (src, dst, msg class) -> [sent, delivered,
        # dropped] byte totals. Purely observational (wire_size draws no
        # randomness), so recording never perturbs the schedule.
        self.link_bytes: Dict[Tuple[NodeId, NodeId, str], List[int]] = {}

    def watch_commits(self, pending: set) -> None:
        """Register ``pending`` (a set of EntryIds) to be drained as those
        entries first commit. Ids already committed must be removed by the
        caller before registering. Call unwatch_commits() when done."""
        self.commit_watchers.append(pending)

    def unwatch_commits(self, pending: set) -> None:
        try:
            self.commit_watchers.remove(pending)
        except ValueError:
            pass

    # -- lifecycle ---------------------------------------------------------

    def submitted(self, entry_id: EntryId, now: float, mode: str) -> None:
        t = self.traces.setdefault(entry_id, EntryTrace(entry_id))
        if t.submitted_at < 0:
            t.submitted_at = now
            t.mode = mode

    def fell_back(self, entry_id: EntryId, now: float) -> None:
        t = self.traces.setdefault(entry_id, EntryTrace(entry_id))
        t.fallbacks += 1

    def committed(self, node_id: NodeId, index: int, entry: Entry, now: float) -> None:
        # COMMIT SAFETY (State Machine Safety): once any node applies entry e
        # at index i, no node may ever apply a different entry at i.
        prev = self.committed_at.get(index)
        if prev is not None and prev != entry.entry_id:
            raise AssertionError(
                f"COMMIT SAFETY VIOLATION at index {index}: "
                f"{prev} already applied, {entry.entry_id} now applied by {node_id}"
            )
        self.committed_at[index] = entry.entry_id
        t = self.traces.setdefault(entry.entry_id, EntryTrace(entry.entry_id))
        if t.first_commit_at < 0:
            t.first_commit_at = now
            t.committed_index = index
            if self.commit_watchers:
                for w in self.commit_watchers:
                    w.discard(entry.entry_id)
        self.applied.setdefault(node_id, []).append((index, entry.entry_id))

    def leader_elected(self, node_id: NodeId, term: int) -> None:
        # ELECTION SAFETY: at most one leader per term.
        s = self.leaders.setdefault(term, set())
        s.add(node_id)
        if len(s) > 1:
            raise AssertionError(f"ELECTION SAFETY VIOLATION in term {term}: {sorted(s)}")

    def count(self, kind: str, n: int = 1) -> None:
        self.counters[kind] = self.counters.get(kind, 0) + n

    # -- wire accounting ---------------------------------------------------

    def bytes_sent(self, src: NodeId, dst: NodeId, cls: str, n: int) -> None:
        row = self.link_bytes.get((src, dst, cls))
        if row is None:
            row = self.link_bytes[(src, dst, cls)] = [0, 0, 0]
        row[0] += n

    def bytes_delivered(self, src: NodeId, dst: NodeId, cls: str, n: int) -> None:
        row = self.link_bytes.get((src, dst, cls))
        if row is None:
            row = self.link_bytes[(src, dst, cls)] = [0, 0, 0]
        row[1] += n

    def bytes_dropped(self, src: NodeId, dst: NodeId, cls: str, n: int) -> None:
        row = self.link_bytes.get((src, dst, cls))
        if row is None:
            row = self.link_bytes[(src, dst, cls)] = [0, 0, 0]
        row[2] += n

    def total_bytes(self, which: str = "sent") -> int:
        """Total bytes across every link and message class.

        ``which`` is one of ``sent`` / ``delivered`` / ``dropped``.
        """
        i = ("sent", "delivered", "dropped").index(which)
        return sum(row[i] for row in self.link_bytes.values())

    def bytes_by_class(self, which: str = "sent") -> Dict[str, int]:
        """Byte totals per message class, summed over links."""
        i = ("sent", "delivered", "dropped").index(which)
        out: Dict[str, int] = {}
        for (_, _, cls), row in self.link_bytes.items():
            out[cls] = out.get(cls, 0) + row[i]
        return out

    def bytes_by_link(self, which: str = "sent") -> Dict[Tuple[NodeId, NodeId], int]:
        """Byte totals per directed (src, dst) link, summed over classes."""
        i = ("sent", "delivered", "dropped").index(which)
        out: Dict[Tuple[NodeId, NodeId], int] = {}
        for (src, dst, _), row in self.link_bytes.items():
            out[(src, dst)] = out.get((src, dst), 0) + row[i]
        return out

    def bytes_per_commit(self, which: str = "sent") -> Optional[float]:
        """Wire bytes divided by distinct committed entries — the headline
        bandwidth-efficiency metric for benchmarks. None before the first
        commit."""
        commits = len(self.committed_at)
        if commits == 0:
            return None
        return self.total_bytes(which) / commits

    # -- queries -----------------------------------------------------------

    def latencies(self, mode: Optional[str] = None) -> List[float]:
        return [
            t.latency
            for t in self.traces.values()
            if t.latency is not None and (mode is None or t.mode == mode)
        ]

    def commit_rate(self) -> float:
        subs = [t for t in self.traces.values() if t.submitted_at >= 0]
        if not subs:
            return 1.0
        return sum(1 for t in subs if t.committed) / len(subs)

    def mean_latency(self, mode: Optional[str] = None) -> Optional[float]:
        ls = self.latencies(mode)
        return statistics.fmean(ls) if ls else None

    def p99_latency(self) -> Optional[float]:
        ls = sorted(self.latencies())
        if not ls:
            return None
        return ls[min(len(ls) - 1, int(0.99 * len(ls)))]

    def fallback_fraction(self) -> float:
        fast = [t for t in self.traces.values() if t.mode == "fast"]
        if not fast:
            return 0.0
        return sum(1 for t in fast if t.fallbacks > 0) / len(fast)

    def summary(self) -> Dict[str, float]:
        return {
            "n_committed": float(len(self.latencies())),
            "commit_rate": self.commit_rate(),
            "mean_latency": self.mean_latency() or float("nan"),
            "p99_latency": self.p99_latency() or float("nan"),
            "fallback_fraction": self.fallback_fraction(),
            **{k: float(v) for k, v in self.counters.items()},
        }
