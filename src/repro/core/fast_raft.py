"""Fast Raft (Castiglia, Goldberg & Patterson 2020) on top of classic Raft.

Fast track (paper section 2.2):

  round 1  proposer  -> ALL    FastPropose(index=i, entry=e)
  round 2  acceptors -> leader FastVote(i, e)        (tentative insert at i)
  round 3  leader    -> ALL    FastFinalize(i, e)    once votes >= ceil(3M/4)

versus the classic track for a non-leader proposer (forward -> AppendEntries
-> acks -> commit-bearing heartbeat = 4 rounds). The fast track commits in 3
rounds from any proposer and removes the leader as the serialization point
for replication fan-out.

Design decisions (and the safety arguments behind them):

* The authoritative log (``self.log``) stays contiguous and append-only as in
  classic Raft. Fast-track slots live in a sparse overlay ``self.fast_slots``
  until FINALIZED *and* contiguous, at which point they merge into the log.
  Every classic-Raft invariant holds by construction; the paper's
  "over-writable log" is confined to the overlay.
* An acceptor votes for the FIRST proposal it sees per (term, index) —
  first-come-first-served, as in Fast Paxos — and the tentative entry is part
  of persistent state (the durable vote).
* Fast commit = ceil(3M/4) votes *and* slot contiguity at the leader. A
  slot that reaches quorum before its gap fills (vote jitter) is HELD
  finalized in the overlay and merges the moment the gap fills; if the gap
  never fills, a liveness timer re-routes the held entry through the classic
  track (safe: a non-contiguous slot was never observable as committed).
* Recovery (new leader): vote-reply tails carry each voter's overlay. In a
  sample of R granted votes, an entry that MAY have fast-committed appears
  >= fq + R - M times (quorum intersection), and no two entries can both
  reach that bound for one slot (2*(fq + R - M) > R for all M >= 2, R >=
  majority). Such entries are re-adopted AT THEIR ORIGINAL INDEX, overwriting
  uncommitted classic entries if necessary — a committed classic entry at the
  same index is impossible because majority(M) + fq(M) > M means the two
  holder sets would have to overlap in a node that accepted both, which the
  per-slot first-come-first-served rule forbids. Sub-threshold tail entries
  provably did not commit and are optionally re-appended for liveness.
* EntryId-level dedup makes every fallback idempotent: a command commits at
  most once no matter how many tracks and retries it traveled.
* Batched fast track: a client burst rides ONE multi-slot FastPropose
  window (entries for consecutive slots), acceptors vote per-slot FCFS and
  reply with ONE batched FastVote, and the leader's resulting
  finalizations leave as batched FastFinalize windows — so an N-command
  burst costs the same 2 message rounds as a single command. Safety is
  unchanged: a window is semantically exactly N single-slot proposals.

Known liveness (not safety) gap, matching the paper's own observations about
lossy networks: if the leader's own slot was claimed by a conflicting
proposal, it lacks the losing command's payload (FastVotes carry ids, not
payloads) and cannot fall the loser back itself; the proposer's inflight
timeout re-routes the command through the classic track instead.

Linearizable reads under the fast track (the read-visibility rule): a
fast-committed write only becomes client-visible (acked) through
``_merge_finalized`` -> ``_advance_commit`` on the LEADER, which bumps
``commit_index`` and applies the entry synchronously BEFORE the
FastFinalize broadcast leaves the handler. Finalized-but-held slots
(non-contiguous, awaiting their gap) are NOT committed and were never
acked, so they are invisible to reads by construction. The base read path
(ReadIndex + leases, ``repro.core.raft``) therefore stays exact here:
``read_index = commit_index`` covers every fast-acked write by the time
any later read can arrive, and ``_advance_commit``'s pending-read drain
releases queued reads the instant a fast-track merge advances the
read-visible index.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.core.raft import Outputs, RaftNode, is_config_command, skeleton_entry
from repro.core.types import (
    AppendEntriesArgs,
    Entry,
    EntryId,
    FastFinalize,
    FastPropose,
    FastVote,
    ForwardOperation,
    NodeId,
    Role,
    Slot,
    SlotState,
    fast_quorum,
)


@dataclasses.dataclass(slots=True)
class _InflightProposal:
    index: int
    command: Any
    entry_id: EntryId
    started_at: float
    fell_back: bool = False


@dataclasses.dataclass(slots=True)
class _SlotTally:
    """Leader-side vote accounting for one fast-track slot."""

    votes: Dict[EntryId, Set[NodeId]] = dataclasses.field(default_factory=dict)
    entries: Dict[EntryId, Entry] = dataclasses.field(default_factory=dict)
    first_vote_at: float = 0.0
    resolved: bool = False


class FastRaftNode(RaftNode):
    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.config.fast_track = True
        # Sparse overlay: index -> Slot (TENTATIVE or FINALIZED-awaiting-merge).
        self.fast_slots: Dict[int, Slot] = {}
        # Proposer state.
        self.inflight: Dict[EntryId, _InflightProposal] = {}
        self._next_fast_hint = 0
        # Leader tallies: index -> _SlotTally.
        self.tallies: Dict[int, _SlotTally] = {}
        # Leader: finalized-but-non-contiguous slots awaiting their gap.
        self._finalized_held: Dict[int, float] = {}
        # When set (window-vote handling), finalized slots accumulate here
        # and are broadcast as batched FastFinalize windows afterwards.
        self._finalize_accum: Optional[List[Tuple[int, Entry]]] = None
        # Ack piggybacking (config.ack_piggyback): single-slot FastVotes
        # cast in one delivery tick, buffered per leader as (index,
        # entry_id) pairs and flushed as ONE FastVote (head vote +
        # multi_votes) by _flush_acks. _vote_buf_term is the term the
        # buffered votes were cast in — the flushed message is stamped
        # with it, so a term bump mid-tick leaves the votes exactly as
        # stale as an in-flight unbuffered message would be.
        self._vote_buf: Dict[NodeId, List[Tuple[int, EntryId]]] = {}
        self._vote_buf_term = 0
        # Liveness nicety: re-propose sub-threshold entries seen during
        # recovery (safe — dedup by entry_id).
        self.readopt_uncommitted = True

    # ------------------------------------------------------------ proposing

    def _submit_mode(self) -> str:
        fast = (
            self.role is not Role.LEADER
            and len(self.inflight) < self.config.max_fast_inflight
            and self.leader_id is not None
        )
        return "fast" if fast else "classic"

    def _non_leader_submit(self, command: Any, entry_id: EntryId, now: float) -> Outputs:
        if (
            len(self.inflight) >= self.config.max_fast_inflight
            or self.leader_id is None
            or is_config_command(command)  # config entries are leader-appended only
        ):
            return super()._non_leader_submit(command, entry_id, now)
        return self._fast_propose_window([(command, entry_id)], now)

    def _non_leader_submit_batch(self, pairs, now: float) -> Outputs:
        if (
            len(self.inflight) + len(pairs) > self.config.max_fast_inflight
            or self.leader_id is None
            or any(is_config_command(c) for c, _ in pairs)
        ):
            return super()._non_leader_submit_batch(pairs, now)
        out: Outputs = []
        w = max(1, self.config.max_batch_entries)
        for i in range(0, len(pairs), w):
            out += self._fast_propose_window(pairs[i : i + w], now)
        return out

    def _fast_propose_window(self, pairs, now: float) -> Outputs:
        """One fast-track round 1 for consecutive slots: a single FastPropose
        (with a window for >1 entries) to all peers plus our own batched
        vote to the leader."""
        base = self._choose_fast_index(len(pairs))
        entries = []
        for off, (command, entry_id) in enumerate(pairs):
            index = base + off
            entry = Entry(term=self.term, command=command, entry_id=entry_id,
                          proposed_at=now)
            self.inflight[entry_id] = _InflightProposal(index, command, entry_id, now)
            # Tentatively accept our own proposal (we are one of the M acceptors).
            self.fast_slots[index] = Slot(entry.clone(), SlotState.TENTATIVE)
            entries.append(entry)
        self._count("fast_proposals", len(entries))

        if len(entries) == 1:
            propose = FastPropose(term=self.term, src=self.id, index=base,
                                  entry=entries[0])
        else:
            propose = FastPropose(term=self.term, src=self.id, index=base,
                                  window=tuple(entries))
        out: Outputs = [(p, propose) for p in self.peers()]
        if self.role is Role.LEADER:
            out += self._apply_window_votes(
                base, [e.entry_id for e in entries], self.id, now
            )
        elif len(entries) == 1:
            out.append((self.leader_id,
                        FastVote(term=self.term, src=self.id, index=base,
                                 entry_id=entries[0].entry_id, voter=self.id)))
        else:
            out.append((self.leader_id,
                        FastVote(term=self.term, src=self.id, index=base, voter=self.id,
                                 window_votes=tuple(e.entry_id for e in entries))))
        self._count("msgs_out", len(out))
        return out

    def _choose_fast_index(self, span: int = 1) -> int:
        """Reserve ``span`` consecutive slots above everything we know of."""
        hi = max(
            self.last_log_index(),
            max(self.fast_slots.keys(), default=0),
            self._next_fast_hint,
        )
        self._next_fast_hint = hi + span
        return hi + 1

    def _append_and_replicate(self, pairs, now: float) -> Outputs:
        # Held finalized slots take their indexes before classic traffic;
        # classic appends then shadow any remaining overlay reservations at
        # or below their index (displaced proposals re-route via timeout).
        self._merge_finalized(now)
        out = super()._append_and_replicate(pairs, now)
        for index in list(self.fast_slots.keys()):
            if index <= self.last_log_index():
                self.fast_slots.pop(index)
                self._finalized_held.pop(index, None)
        return out

    # ------------------------------------------------------------- acceptors

    def _handle_FastPropose(self, msg: FastPropose, now: float) -> Outputs:
        if msg.term < self.term:
            return []
        window = msg.window if msg.window else (
            (msg.entry,) if msg.entry is not None else ()
        )
        if not window:
            return []
        # Per-slot first-come-first-served acceptance, exactly as if the
        # window had arrived as len(window) single proposals; the reply is
        # ONE (possibly batched) FastVote.
        accepted: List[Optional[EntryId]] = []
        for off, entry in enumerate(window):
            accepted.append(self._accept_fast_slot(msg.index + off, entry))
        if not any(eid is not None for eid in accepted):
            return []
        if len(accepted) == 1:
            return self._emit_fast_vote(msg.index, accepted[0], now)
        return self._emit_fast_window_vote(msg.index, accepted, now)

    def _accept_fast_slot(self, index: int, entry: Entry) -> Optional[EntryId]:
        """FCFS acceptance for one slot; returns the entry_id we vote for
        (None = refuse)."""
        if index <= self.snapshot_last_index:
            return None  # compacted: slot is committed history
        if is_config_command(entry.command):
            # Membership changes never ride the fast track: the entry that
            # REDEFINES quorums must not commit through a quorum rule that
            # is itself in flux. They are leader-appended classic entries.
            self._count("fast_rejects")
            return None
        authoritative = self.slot(index)
        if authoritative is not None:
            # Classic track already owns this index. Vote only if it's the
            # same entry (harmless); otherwise the proposal is dead here.
            if not authoritative.entry.same_entry(entry):
                self._count("fast_rejects")
                return None
        else:
            held = self.fast_slots.get(index)
            if held is None:
                # Witness acceptors hold payload-free skeletons even in the
                # fast-slot overlay; FCFS conflict detection only compares
                # EntryIds (same_entry), so votes are unaffected.
                e = skeleton_entry(entry) if self.is_witness() else entry.clone()
                self.fast_slots[index] = Slot(e, SlotState.TENTATIVE)
                self._next_fast_hint = max(self._next_fast_hint, index)
            elif not held.entry.same_entry(entry):
                self._count("fast_conflicts")
                return None  # first-come-first-served: keep existing vote
        return entry.entry_id

    def _emit_fast_vote(self, index: int, entry_id: Optional[EntryId], now: float) -> Outputs:
        if entry_id is None:
            return []
        if self.role is Role.LEADER:
            return self._record_fast_vote(index, entry_id, self.id, now)
        if self.leader_id is None:
            return []
        if self.config.ack_piggyback:
            # Fold same-tick single-slot votes into one FastVote per
            # leader per delivery tick (flushed by _flush_acks).
            if self._ack_buf_time < 0 or not self._vote_buf:
                self._vote_buf_term = self.term
            self._vote_buf.setdefault(self.leader_id, []).append(
                (index, entry_id)
            )
            self._ack_buf_time = now
            return []
        return [
            (
                self.leader_id,
                FastVote(term=self.term, src=self.id, index=index,
                         entry_id=entry_id, voter=self.id),
            )
        ]

    def _emit_fast_window_vote(
        self, base: int, accepted: List[Optional[EntryId]], now: float
    ) -> Outputs:
        if self.role is Role.LEADER:
            return self._apply_window_votes(base, accepted, self.id, now)
        if self.leader_id is None:
            return []
        return [
            (
                self.leader_id,
                FastVote(term=self.term, src=self.id, index=base, voter=self.id,
                         window_votes=tuple(accepted)),
            )
        ]

    # ---------------------------------------------------------- leader side

    def _handle_FastVote(self, msg: FastVote, now: float) -> Outputs:
        if self.role is not Role.LEADER or msg.term < self.term:
            return []
        if msg.window_votes:
            return self._apply_window_votes(
                msg.index, list(msg.window_votes), msg.voter, now
            )
        if msg.multi_votes:
            # Piggybacked vote: the head (index, entry_id) plus folded
            # same-tick votes. Record them all inside one finalize-accum
            # scope so slots they complete leave as batched FastFinalize
            # windows (same coalescing as window votes).
            outer = self._finalize_accum is None
            if outer:
                self._finalize_accum = []
            out: Outputs = []
            try:
                votes = [(msg.index, msg.entry_id)] + list(msg.multi_votes)
                for index, eid in votes:
                    if eid is not None:
                        out += self._record_fast_vote(index, eid, msg.voter, now)
            finally:
                if outer:
                    acc, self._finalize_accum = self._finalize_accum, None
                    out += self._broadcast_finalize_windows(acc)
            return out
        if msg.entry_id is None:
            return []
        return self._record_fast_vote(msg.index, msg.entry_id, msg.voter, now)

    def _apply_window_votes(
        self, base: int, votes: List[Optional[EntryId]], voter: NodeId, now: float
    ) -> Outputs:
        """Record a batched vote; coalesce any resulting finalizations into
        batched FastFinalize windows instead of one broadcast per slot."""
        outer = self._finalize_accum is None
        if outer:
            self._finalize_accum = []
        out: Outputs = []
        try:
            for off, eid in enumerate(votes):
                if eid is not None:
                    out += self._record_fast_vote(base + off, eid, voter, now)
        finally:
            if outer:
                acc, self._finalize_accum = self._finalize_accum, None
                out += self._broadcast_finalize_windows(acc)
        return out

    def _broadcast_finalize_windows(self, acc: List[Tuple[int, Entry]]) -> Outputs:
        if not acc:
            return []
        acc.sort(key=lambda kv: kv[0])
        runs: List[List[Tuple[int, Entry]]] = [[acc[0]]]
        for index, entry in acc[1:]:
            if index == runs[-1][-1][0] + 1:
                runs[-1].append((index, entry))
            else:
                runs.append([(index, entry)])
        out: Outputs = []
        for run in runs:
            base = run[0][0]
            if len(run) == 1:
                msg = FastFinalize(term=self.term, src=self.id, index=base,
                                   entry=run[0][1], leader_commit=self.commit_index)
            else:
                msg = FastFinalize(term=self.term, src=self.id, index=base,
                                   window=tuple(e for _, e in run),
                                   leader_commit=self.commit_index)
            out += [(p, msg) for p in self.peers()]
        self._count("msgs_out", len(out))
        return out

    def _record_fast_vote(
        self, index: int, entry_id: EntryId, voter: NodeId, now: float
    ) -> Outputs:
        if self._seen(entry_id):
            return []  # already authoritative (fast-merged or classicized)
        tally = self.tallies.setdefault(index, _SlotTally(first_vote_at=now))
        if tally.resolved:
            return []
        tally.votes.setdefault(entry_id, set()).add(voter)
        s = self.fast_slots.get(index)
        if s is not None and s.entry.entry_id == entry_id:
            tally.entries.setdefault(entry_id, s.entry)

        supporters = tally.votes[entry_id]
        # Fast commit requires ceil(3V/4) of EVERY active voter set (both
        # halves during a joint config change); learner votes never count —
        # ClusterConfig.fast_ok intersects with the voter sets.
        if self.cluster_config.fast_ok(supporters) and entry_id in tally.entries:
            return self._finalize_fast_slot(index, tally.entries[entry_id], now)
        # Definitive conflict: no candidate can still reach a fast quorum
        # in every active voter set (per-slot FCFS votes never change).
        cast = set().union(*tally.votes.values())
        if len(tally.votes) > 1 and not any(
            self.cluster_config.fast_possible(v, cast)
            for v in tally.votes.values()
        ):
            return self._fallback_slot(index, now)
        return []

    def _finalize_fast_slot(self, index: int, entry: Entry, now: float) -> Outputs:
        tally = self.tallies.get(index)
        if tally is not None:
            tally.resolved = True
        if self.slot(index) is not None or self._seen(entry.entry_id):
            return []  # classic track already owns this index / entry
        # Quorum reached. If not yet contiguous (vote jitter can complete
        # slot k+1 before slot k), HOLD the finalized slot in the overlay;
        # it merges the moment the gap fills. A liveness timer re-routes
        # held slots through the classic track if the gap never fills
        # (safe: a non-contiguous slot was never observable as committed).
        self.fast_slots[index] = Slot(entry.clone(), SlotState.FINALIZED)
        self._count("fast_commits")
        if index != self.last_log_index() + 1:
            self._finalized_held[index] = now
            self._count("fast_holds")
        self._merge_finalized(now)
        if self._finalize_accum is not None:
            # Window-vote context: defer the broadcast so consecutive slots
            # finalized by one batched vote leave as one FastFinalize window.
            self._finalize_accum.append((index, entry))
            return []
        out: Outputs = [
            (
                p,
                FastFinalize(term=self.term, src=self.id, index=index,
                             entry=entry, leader_commit=self.commit_index),
            )
            for p in self.peers()
        ]
        self._count("msgs_out", len(out))
        return out

    def _fallback_slot(self, index: int, now: float) -> Outputs:
        """Conflict or timeout: push the slot's candidates onto the classic
        track, best-supported first. Idempotent thanks to entry_id dedup."""
        tally = self.tallies.get(index)
        if tally is None or tally.resolved:
            return []
        tally.resolved = True
        self._count("fast_fallbacks")
        ranked = sorted(
            tally.votes.keys(),
            key=lambda eid: (-len(tally.votes[eid]), str(eid)),
        )
        out: Outputs = []
        for eid in ranked:
            entry = tally.entries.get(eid)
            if entry is None:
                continue  # payload unknown; proposer's timeout re-routes it
            if self.metrics is not None:
                self.metrics.fell_back(eid, now)
            out += super()._leader_append(entry.command, eid, now)
        return out

    # ------------------------------------------------------------ finalize

    def _handle_FastFinalize(self, msg: FastFinalize, now: float) -> Outputs:
        if msg.term < self.term:
            return []
        # Finalize comes from the live leader: counts as leader contact for
        # lease-mode vote stickiness (it does NOT reset the election timer —
        # heartbeats own liveness detection, exactly as in the seed).
        self._note_leader_contact(now)
        window = msg.window if msg.window else (
            (msg.entry,) if msg.entry is not None else ()
        )
        for off, entry in enumerate(window):
            index = msg.index + off
            if index <= self.snapshot_last_index:
                continue  # already compacted == committed
            if self.slot(index) is None and not self._seen(entry.entry_id):
                # Leader's finalize overrides any conflicting tentative entry.
                self.fast_slots[index] = Slot(entry.clone(), SlotState.FINALIZED)
        self._merge_finalized(now)
        if msg.leader_commit > self.commit_index:
            self._advance_commit(msg.leader_commit, now)
        return []

    def _merge_finalized(self, now: float) -> None:
        """Fold contiguous FINALIZED overlay slots into the authoritative log
        and (leader only) commit them — a contiguous ceil(3M/4) fast quorum
        IS commit."""
        merged_any = False
        while True:
            nxt = self.last_log_index() + 1
            s = self.fast_slots.get(nxt)
            if s is None or s.state is not SlotState.FINALIZED:
                break
            del self.fast_slots[nxt]
            self._finalized_held.pop(nxt, None)
            if self._seen(s.entry.entry_id):
                continue  # already classicized elsewhere in the log
            self._append_slot(s)
            merged_any = True
        if merged_any and self.role is Role.LEADER:
            self._advance_commit(self._highest_contiguous_finalized(), now)

    def _highest_contiguous_finalized(self) -> int:
        i = self.commit_index
        while i < self.last_log_index():
            if self.slot(i + 1).state is SlotState.FINALIZED:
                i += 1
            else:
                break
        return i

    # --------------------------------------------------------------- ticks

    def _flush_acks(self) -> None:
        # Buffered FastVotes leave first (they were cast before any
        # AppendEntries ack buffered later the same tick could matter),
        # stamped with the term they were cast in; then the base class
        # flushes AppendEntries acks and clears the shared buffer clock.
        if self._vote_buf:
            for dst, votes in self._vote_buf.items():
                head_index, head_eid = votes[0]
                self._outbox.append(
                    (
                        dst,
                        FastVote(
                            term=self._vote_buf_term,
                            src=self.id,
                            index=head_index,
                            entry_id=head_eid,
                            voter=self.id,
                            multi_votes=tuple(votes[1:]),
                        ),
                    )
                )
                if len(votes) > 1:
                    self._count("fast_votes_folded", len(votes) - 1)
            self._vote_buf = {}
        super()._flush_acks()

    def _protocol_idle(self) -> bool:
        # _tick_protocol below is a no-op exactly when there are no leader
        # tallies, no held finalizations, and no proposer inflight state.
        return (
            not self.inflight
            and not self.tallies
            and not self._finalized_held
        )

    def _tick_protocol(self, now: float) -> Outputs:
        out: Outputs = []
        timeout = self.config.fast_vote_timeout
        if self.role is Role.LEADER:
            for index, tally in list(self.tallies.items()):
                if not tally.resolved and now - tally.first_vote_at > timeout:
                    out += self._fallback_slot(index, now)
            # Liveness for held finalized slots whose gap never fills:
            # re-route them through the classic track in index order.
            stuck = sorted(i for i, t in self._finalized_held.items()
                           if now - t > timeout)
            for index in stuck:
                s = self.fast_slots.pop(index, None)
                self._finalized_held.pop(index, None)
                if s is not None and not self._seen(s.entry.entry_id):
                    self._count("fast_held_reroutes")
                    out += super()._leader_append(s.entry.command,
                                                  s.entry.entry_id, now)
        # Proposer retry: inflight proposals that never committed fall back
        # through the classic forward path.
        for eid, prop in list(self.inflight.items()):
            if self._seen(eid):
                del self.inflight[eid]
                continue
            if not prop.fell_back and now - prop.started_at > timeout:
                prop.fell_back = True
                if self.metrics is not None:
                    self.metrics.fell_back(eid, now)
                if self.leader_id is not None and self.leader_id != self.id:
                    out.append(
                        (
                            self.leader_id,
                            ForwardOperation(term=self.term, src=self.id,
                                             command=prop.command, entry_id=eid),
                        )
                    )
                elif self.role is Role.LEADER:
                    out += super()._leader_append(prop.command, eid, now)
            elif prop.fell_back and now - prop.started_at > 6 * timeout:
                del self.inflight[eid]  # give up; client-level retry
        return out

    # ----------------------------------------------- election & recovery

    def _tentative_tail(self) -> Optional[dict]:
        return {
            i: (s.entry.clone(), s.state.value) for i, s in self.fast_slots.items()
        }

    def _on_leadership_acquired(self, now: float) -> Outputs:
        """Recover possibly-fast-committed entries from the election quorum.

        Must-adopt entries are re-adopted at their ORIGINAL slot index,
        overwriting uncommitted classic entries if present (a committed
        conflicting classic entry at the same index is impossible — see
        module docstring). The must threshold is config-aware: an entry
        that fast-committed holds >= fq(V) of every active voter set V, so
        within the granted sample S_V (of V's voters) it appears at least
        fq(V) + |S_V| - |V| times; an entry below that bound in ANY active
        set provably did not fast-commit. During a joint config this is
        evaluated against both halves — conservative in the safe direction
        (over-adopting a non-committed entry just re-proposes it
        classically; EntryId dedup keeps that idempotent). Gaps below a
        must-adopt index that cannot be filled prove the entry never
        committed, so it is appended at the next free index instead.
        """
        granted: Dict[NodeId, dict] = {
            n: (r.tentative_tail or {})
            for n, r in self.votes_received.items()
            if r.vote_granted
        }

        holders: Dict[int, Dict[EntryId, set]] = {}
        entries: Dict[EntryId, Entry] = {}
        for src, tail in granted.items():
            for index, (entry, _state) in tail.items():
                holders.setdefault(index, {}).setdefault(entry.entry_id, set()).add(src)
                entries.setdefault(entry.entry_id, entry)

        def may_have_fast_committed(holder_set: set) -> bool:
            for vs in self.cluster_config.voter_sets():
                s = set(vs)
                sample = sum(1 for n in granted if n in s)
                thr = max(1, fast_quorum(len(s)) + sample - len(s))
                if len(holder_set & s) < thr:
                    return False
            return True

        must: List[Tuple[int, EntryId]] = []
        maybe: List[EntryId] = []
        for index in sorted(holders):
            ranked = sorted(
                holders[index].items(), key=lambda kv: (-len(kv[1]), str(kv[0]))
            )
            top_eid, top_holders = ranked[0]
            if may_have_fast_committed(top_holders):
                must.append((index, top_eid))
                ranked = ranked[1:]
            if self.readopt_uncommitted:
                maybe.extend(eid for eid, _ in ranked)

        displaced: List[Entry] = []
        for index, eid in must:
            e = entries[eid]
            if self._seen(eid):
                continue
            if index <= self.snapshot_last_index:
                # The slot is compacted committed history holding a different
                # entry — a conflicting fast commit there is impossible, so
                # this candidate provably never committed. Re-append it at a
                # fresh index for liveness.
                displaced.append(e)
                continue
            if index <= self.last_log_index():
                cur = self.slot(index)
                if cur.entry.same_entry(e):
                    continue
                # Overwrite an uncommitted classic entry at the original slot.
                assert index > self.commit_index, "would overwrite a committed slot"
                displaced.extend(
                    s.entry for s in self.log[index - 1 :]
                    if s.state is SlotState.CLASSIC
                )
                self._truncate_from(index)
            # Append at original index when contiguous; otherwise the gap
            # proves non-commitment and next-free-index placement is safe.
            e2 = Entry(term=self.term, command=e.command, entry_id=eid,
                       proposed_at=e.proposed_at)
            self._append_slot(Slot(e2, SlotState.CLASSIC))
            self._count("recovered_fast_entries")

        out: Outputs = []
        for e in displaced:
            if not self._seen(e.entry_id):
                out += super()._leader_append(e.command, e.entry_id, now)
        if self.readopt_uncommitted:
            for eid in maybe:
                if not self._seen(eid):
                    e = entries[eid]
                    out += super()._leader_append(e.command, eid, now)
        # The new leader's log is now authoritative; clear the overlay and
        # stale tallies from previous terms.
        self.fast_slots.clear()
        self.tallies.clear()
        self._finalized_held.clear()
        self._count("recoveries")
        return out

    def _on_leadership_lost(self, now: float) -> None:
        """Step-down (higher term, CheckQuorum, removal from config): drop
        every leader-volatile piece of fast-track state. Tallies and held
        finalized slots are THIS leadership's vote accounting — a later
        re-election must rebuild them from vote replies (_recover), not
        trust counts from before the step-down. fast_slots stay: a
        tentative slot is this node's durable FCFS vote as an ACCEPTOR,
        which survives role changes by design."""
        self.tallies.clear()
        self._finalized_held.clear()
        self._finalize_accum = None

    # ------------------------------------------------- linearizable reads

    def _read_index(self) -> int:
        """Read-visibility rule under the fast track (module docstring):
        every fast-acked write is covered by commit_index before its
        FastFinalize broadcast leaves, because _merge_finalized commits and
        applies synchronously inside the vote handler. Held finalized slots
        above the contiguous prefix were never acked, so excluding them is
        exactly right — the base rule needs no widening."""
        return self.commit_index

    # ------------------------------------------- classic-track interactions

    def _handle_AppendEntriesArgs(self, msg: AppendEntriesArgs, now: float) -> Outputs:
        out = super()._handle_AppendEntriesArgs(msg, now)
        # Reconcile the overlay with newly-arrived authoritative entries:
        # overlay slots at indexes the log now owns are dead (the classic
        # track won); displaced inflight proposals re-route via timeout.
        for index in list(self.fast_slots.keys()):
            if index <= self.last_log_index():
                del self.fast_slots[index]
                self._finalized_held.pop(index, None)
        self._merge_finalized(now)
        return out

    def _install_snapshot(self, snap, now: float) -> None:
        super()._install_snapshot(snap, now)
        # Overlay reservations at compacted indexes are dead: those slots
        # are committed history now. Displaced proposals re-route via the
        # inflight timeout (dedup by entry_id keeps this idempotent).
        for index in list(self.fast_slots.keys()):
            if index <= self.snapshot_last_index:
                del self.fast_slots[index]
                self._finalized_held.pop(index, None)
                self.tallies.pop(index, None)
        self._merge_finalized(now)

    def restart(self, now: float) -> None:
        # fast_slots (and the durable votes they imply) persist across
        # crashes; leader tallies and proposer inflight state are volatile.
        super().restart(now)
        self.tallies = {}
        self.inflight = {}
        self._finalized_held = {}
        self._finalize_accum = None
        self._vote_buf = {}
