"""Pluggable replicated state machines for the consensus core.

The seed's "state machine" was literally the committed command list, so
snapshots carried every entry ever applied and compaction saved nothing on
the wire. This module makes the applied state a first-class, swappable
object (see DESIGN.md):

- :class:`StateMachine` — the protocol a machine implements:
  ``apply(index, entry)``, ``snapshot() -> state``, ``restore(state)``,
  ``size_bytes()``. Snapshot state must be JSON-serializable (it is what
  :class:`repro.checkpoint.manager.SnapshotStore` persists and what chunked
  InstallSnapshot streams over the wire).
- :class:`LogListMachine` — the default; reproduces the seed semantics
  bit-for-bit: state is the applied entry list, ``committed_entries()`` /
  ``committed_commands()`` keep returning the full history, and snapshots
  remain O(history).
- :class:`KVMachine` — a real key-value workload (SET / GET / DEL / CAS
  with per-key versioning) whose snapshot is the live key map: O(live
  keys), not O(history) — the reduced-state snapshot the paper's evaluation
  as a replication substrate assumes.
- :class:`DedupTable` — compact exactly-once filter over applied EntryIds.
  The log keeps per-entry ids only while entries are live; once the prefix
  compacts into an opaque snapshot, client-retry dedup needs a membership
  oracle that does not grow with history. Per-origin ``(max_seq, holes)``
  is exact (a hole is a seq below the watermark that never applied, e.g. a
  command that fast-committed out of order) and O(clients + holes).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Set

from repro.core.types import Entry, EntryId, entry_from_wire, entry_to_wire

# Rough per-entry bookkeeping overhead (term, id, framing) used by
# size_bytes() accounting; only relative sizes matter to the simulator.
_ENTRY_OVERHEAD = 24
_KEY_OVERHEAD = 16


class StateMachine:
    """Protocol for the replicated state machine a RaftNode drives.

    Contract (see DESIGN.md for the full argument):

    - ``apply(index, entry)`` is called exactly once per committed index in
      index order during normal operation. After a crash-restart the node
      rolls the machine back to its last snapshot (``restore``) and
      re-applies the suffix, so a machine never needs its own durability.
    - ``snapshot()`` returns a JSON-serializable value capturing the state
      as of the last applied entry. It must not alias mutable internals:
      later ``apply`` calls must not change an already-taken snapshot.
    - ``restore(state)`` replaces the machine's state with a previously
      taken snapshot; ``restore(None)`` resets to the empty initial state.
    - ``size_bytes()`` is the approximate serialized size of the CURRENT
      state — what a snapshot of it would cost on the wire.
    """

    name = "base"

    def apply(self, index: int, entry: Entry) -> Any:
        raise NotImplementedError

    def snapshot(self) -> Any:
        raise NotImplementedError

    def restore(self, state: Any) -> None:
        raise NotImplementedError

    def size_bytes(self) -> int:
        raise NotImplementedError

    def query(self, query: Any) -> Any:
        """Read-only entry point for the linearizable read path.

        MUST NOT mutate state and is never dedup-recorded — unlike
        ``apply``, a query is not a log entry: it has no index, is not
        replicated, and may be evaluated any number of times (origin-side
        read retries re-evaluate at the then-current applied state).
        Machines that don't support reads return None.
        """
        return None

    def applied_entries(self) -> Optional[List[Entry]]:
        """Full applied entry history, when the machine retains it.

        The LogListMachine does (that IS its state); reduced-state machines
        return None, and ``RaftNode.committed_entries`` then only exposes
        the uncompacted tail.
        """
        return None

    # -- delta snapshots (RaftConfig.delta_snapshots) ----------------------

    def snapshot_delta(self, base_state: Any, target_state: Any) -> Optional[Any]:
        """JSON-serializable delta transforming ``base_state`` (an earlier
        ``snapshot()`` result) into ``target_state`` (a later one), or None
        when the machine cannot beat a full transfer. The default — kept by
        LogListMachine, whose state IS the history — is None, which makes
        the leader fall back to streaming the full snapshot."""
        return None

    def apply_delta(self, base_state: Any, delta: Any) -> Any:
        """Reconstruct the target snapshot state from ``base_state`` plus a
        ``snapshot_delta``-produced delta. Must not mutate ``base_state``
        (it is the receiver's live snapshot)."""
        raise NotImplementedError


class LogListMachine(StateMachine):
    """Seed-compatible machine: the state is the applied entry sequence.

    Keeps ``committed_entries()`` exact across compaction (the snapshot
    carries every applied entry), which is what the history-based test
    checkers rely on. Snapshots are O(history) by design — this machine
    exists to reproduce the seed's semantics, not to save bytes.
    """

    name = "loglist"

    def __init__(self) -> None:
        self._entries: List[Entry] = []
        self._bytes = 0

    def apply(self, index: int, entry: Entry) -> Any:
        self._entries.append(entry.clone())
        self._bytes += _ENTRY_OVERHEAD + len(str(entry.command))
        return None

    def snapshot(self) -> Any:
        return [entry_to_wire(e) for e in self._entries]

    def restore(self, state: Any) -> None:
        self._entries = (
            [] if state is None else [entry_from_wire(d) for d in state]
        )
        self._bytes = sum(
            _ENTRY_OVERHEAD + len(str(e.command)) for e in self._entries
        )

    def size_bytes(self) -> int:
        return self._bytes

    def query(self, query: Any) -> Any:
        if query == "LEN":
            return len(self._entries)
        if query == "LAST":
            return self._entries[-1].command if self._entries else None
        return None

    def applied_entries(self) -> Optional[List[Entry]]:
        return list(self._entries)


class KVMachine(StateMachine):
    """Key-value machine: SET / GET / DEL / CAS with per-key versioning.

    Commands are whitespace-separated strings::

        SET <key> <value...>        write; bumps the key's version
        GET <key>                   read (returns the value, state unchanged)
        DEL <key>                   remove the key
        CAS <key> <expected> <new...>   write iff current value == expected

    Anything else (membership ``__config__:`` commands, hierarchy shadow
    entries, checkpoint records, plain strings) is a no-op — infrastructure
    commands flow through the same log and must not wedge the machine.

    The snapshot is the live key map ``{key: [value, version]}``: O(live
    keys) regardless of how many updates the history contains.
    """

    name = "kv"

    def __init__(self) -> None:
        self._kv: Dict[str, List] = {}  # key -> [value, version]
        self._bytes = 0

    # -- command interpreter ------------------------------------------------

    def apply(self, index: int, entry: Entry) -> Any:
        cmd = entry.command
        if not isinstance(cmd, str):
            return None
        parts = cmd.split(" ")
        op = parts[0]
        if op == "SET" and len(parts) >= 3:
            return self._write(parts[1], " ".join(parts[2:]))
        if op == "GET" and len(parts) == 2:
            cur = self._kv.get(parts[1])
            return cur[0] if cur is not None else None
        if op == "DEL" and len(parts) == 2:
            cur = self._kv.pop(parts[1], None)
            if cur is not None:
                self._bytes -= _KEY_OVERHEAD + len(parts[1]) + len(str(cur[0]))
            return cur is not None
        if op == "CAS" and len(parts) >= 4:
            key, expected = parts[1], parts[2]
            cur = self._kv.get(key)
            if cur is not None and cur[0] == expected:
                self._write(key, " ".join(parts[3:]))
                return True
            return False
        return None

    def _write(self, key: str, value: str) -> int:
        cur = self._kv.get(key)
        if cur is None:
            self._kv[key] = [value, 1]
            self._bytes += _KEY_OVERHEAD + len(key) + len(value)
            return 1
        self._bytes += len(value) - len(str(cur[0]))
        cur[0] = value
        cur[1] += 1
        return cur[1]

    # -- snapshot protocol --------------------------------------------------

    def snapshot(self) -> Any:
        return {k: list(v) for k, v in self._kv.items()}

    def restore(self, state: Any) -> None:
        self._kv = {} if state is None else {k: list(v) for k, v in state.items()}
        self._bytes = sum(
            _KEY_OVERHEAD + len(k) + len(str(v[0])) for k, v in self._kv.items()
        )

    def size_bytes(self) -> int:
        return self._bytes

    # -- delta snapshots ----------------------------------------------------

    def snapshot_delta(self, base_state: Any, target_state: Any) -> Optional[Any]:
        """O(live keys) delta: per-key versions make change detection a
        single integer compare per key (a same-value CAS still bumps the
        version, so every write is caught). Shape:
        ``{"set": {key: [value, version]}, "del": [keys]}``."""
        if not isinstance(base_state, dict) or not isinstance(target_state, dict):
            return None
        set_ops: Dict[str, List] = {}
        for k, v in target_state.items():
            b = base_state.get(k)
            if b is None or b[1] != v[1] or b[0] != v[0]:
                set_ops[k] = list(v)
        deleted = sorted(k for k in base_state if k not in target_state)
        return {"set": set_ops, "del": deleted}

    def apply_delta(self, base_state: Any, delta: Any) -> Any:
        state = (
            {}
            if base_state is None
            else {k: list(v) for k, v in base_state.items()}
        )
        for k in delta.get("del", ()):
            state.pop(k, None)
        for k, v in delta.get("set", {}).items():
            state[k] = list(v)
        return state

    # -- read-only query path (linearizable reads) -------------------------

    def query(self, query: Any) -> Any:
        """GET/VERSION/KEYS without going through the log. Same command
        grammar as ``apply`` where it overlaps (``GET <key>``) so a workload
        can switch a GET between the log path and the read path without
        rewriting commands. Never mutates ``self._kv``."""
        if not isinstance(query, str):
            return None
        parts = query.split(" ")
        if parts[0] == "GET" and len(parts) == 2:
            cur = self._kv.get(parts[1])
            return cur[0] if cur is not None else None
        if parts[0] == "VERSION" and len(parts) == 2:
            cur = self._kv.get(parts[1])
            return cur[1] if cur is not None else 0
        if parts[0] == "KEYS" and len(parts) == 1:
            return sorted(self._kv)
        return None

    # -- queries (tests / benchmarks) --------------------------------------

    def get(self, key: str) -> Optional[str]:
        cur = self._kv.get(key)
        return cur[0] if cur is not None else None

    def version(self, key: str) -> int:
        cur = self._kv.get(key)
        return cur[1] if cur is not None else 0

    def keys(self) -> List[str]:
        return sorted(self._kv)


class DedupTable:
    """Exactly-once membership oracle over applied EntryIds, O(clients).

    Per origin we keep the highest applied seq (``max``) plus the set of
    ``holes``: seqs at or below the watermark that have NOT applied (fast
    track and leader recovery can commit a client's seqs out of order).
    ``contains`` is exact: seq <= max and not a hole.
    """

    def __init__(self) -> None:
        self._max: Dict[str, int] = {}
        self._holes: Dict[str, Set[int]] = {}

    def add(self, entry_id: EntryId) -> None:
        origin, seq = entry_id.origin, entry_id.seq
        hi = self._max.get(origin, 0)
        if seq > hi:
            if seq > hi + 1:
                self._holes.setdefault(origin, set()).update(range(hi + 1, seq))
            self._max[origin] = seq
        else:
            holes = self._holes.get(origin)
            if holes is not None:
                holes.discard(seq)
                if not holes:
                    del self._holes[origin]

    def contains(self, entry_id: EntryId) -> bool:
        origin, seq = entry_id.origin, entry_id.seq
        if seq > self._max.get(origin, 0):
            return False
        return seq not in self._holes.get(origin, ())

    def max_seq(self, origin: str) -> int:
        return self._max.get(origin, 0)

    # -- snapshot wire format ----------------------------------------------

    def state(self) -> Dict[str, Any]:
        return {
            "max": dict(self._max),
            "holes": {o: sorted(s) for o, s in self._holes.items() if s},
        }

    @classmethod
    def from_state(cls, state: Any) -> "DedupTable":
        t = cls()
        if state:
            t._max = {o: int(v) for o, v in state.get("max", {}).items()}
            t._holes = {
                o: set(v) for o, v in state.get("holes", {}).items() if v
            }
        return t
