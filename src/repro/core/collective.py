"""In-graph Fast Raft: the TPU-native mapping of the paper's two tracks.

Inside a compiled SPMD step there is no point-to-point RPC; the unit of a
"message round" is a collective. We map:

  fast track    -> ONE ``lax.psum`` of votes over the replica axes;
                   commit iff n_yes >= ceil(3M/4)              (1 round)
  classic track -> ``lax.all_gather`` of votes (leader observes) followed by
                   a leader-decides broadcast ``lax.psum``     (2 rounds)
  piggybacking  -> the vote word is reduced IN THE SAME ``psum`` call as the
                   gradients, so consensus costs ZERO extra collective
                   rounds (beyond-paper optimization; see EXPERIMENTS.md
                   §Perf for the HLO evidence)

Used by the training runtime as the per-step commit barrier: each
data-parallel replica votes "my microbatch gradient is finite and in
bounds"; the optimizer update applies only on a fast-quorum commit,
otherwise the step is skipped (the in-graph analogue of a tentative log slot
being rolled back) and the pathological replica's contribution is excluded.

All functions here must be called inside ``shard_map`` (they use named
axes). ``axis_names`` lists the replica axes, e.g. ("pod", "data").
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat


def _axis_size(axis_names: Sequence[str]) -> int:
    m = 1
    for a in axis_names:
        m *= compat.axis_size(a)
    return m


def fast_quorum_size(m: int) -> int:
    return math.ceil(3 * m / 4)


def majority_size(m: int) -> int:
    return m // 2 + 1


# ---------------------------------------------------------------------------
# Track primitives
# ---------------------------------------------------------------------------


def fast_track_commit(
    vote: jax.Array, axis_names: Sequence[str]
) -> Tuple[jax.Array, jax.Array]:
    """One collective round: psum the votes, commit on a ceil(3M/4) quorum.

    Args:
      vote: scalar in {0., 1.} — this replica's vote.
    Returns:
      (n_yes, committed): replicated scalars.
    """
    m = _axis_size(axis_names)
    n_yes = lax.psum(vote, axis_names)
    committed = n_yes >= jnp.asarray(fast_quorum_size(m), dtype=n_yes.dtype)
    return n_yes, committed


def classic_track_commit(
    vote: jax.Array, axis_names: Sequence[str]
) -> Tuple[jax.Array, jax.Array]:
    """Two collective rounds, structurally mirroring leader-mediated Raft:
    round 1 gathers every vote to the leader; round 2 broadcasts the
    leader's verdict. (Each round is a real collective in the lowered HLO —
    this is the baseline the fast track is measured against.)
    """
    m = _axis_size(axis_names)
    # Round 1: gather votes (the leader — replica 0 — observes the tally).
    votes = vote.reshape(1)
    for a in reversed(axis_names):
        votes = lax.all_gather(votes, a, tiled=True)
    n_yes = jnp.sum(votes)
    decision = (n_yes >= jnp.asarray(majority_size(m), votes.dtype)).astype(votes.dtype)
    # Round 2: only the leader's verdict counts; broadcast it.
    is_leader = jnp.asarray(1.0, votes.dtype)
    for a in axis_names:
        is_leader = is_leader * (lax.axis_index(a) == 0).astype(votes.dtype)
    committed = lax.psum(decision * is_leader, axis_names) > 0
    return n_yes, committed


def voted_psum(
    tree: Any, vote: jax.Array, axis_names: Sequence[str]
) -> Tuple[Any, jax.Array, jax.Array]:
    """Gradient all-reduce with the Fast Raft vote piggybacked.

    The vote scalar rides in the SAME psum call as the gradient leaves, so
    XLA emits one fused all-reduce group — consensus adds zero collective
    rounds. Returns (summed_tree, n_yes, committed).
    """
    m = _axis_size(axis_names)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    # Pack every leaf AND the vote into one flat f32 buffer so the lowered
    # HLO contains exactly one all-reduce op by construction — tuple psum
    # lowers to one all-reduce per operand and not every backend's combiner
    # pass re-merges them.
    flat = jnp.concatenate(
        [l.astype(jnp.float32).ravel() for l in leaves] + [vote.astype(jnp.float32).reshape(1)]
    )
    summed = lax.psum(flat, axis_names)
    summed_leaves = []
    off = 0
    for l in leaves:
        n = l.size
        summed_leaves.append(summed[off : off + n].reshape(l.shape).astype(l.dtype))
        off += n
    n_yes = summed[off]
    committed = n_yes >= jnp.asarray(fast_quorum_size(m), dtype=n_yes.dtype)
    return jax.tree_util.tree_unflatten(treedef, summed_leaves), n_yes, committed


def masked_update(committed: jax.Array, new_tree: Any, old_tree: Any) -> Any:
    """Apply `new` only when the quorum committed — the in-graph analogue of
    rolling back a tentative slot."""
    def sel(n, o):
        return jnp.where(committed, n, o)

    return jax.tree_util.tree_map(sel, new_tree, old_tree)


# ---------------------------------------------------------------------------
# Step-level consensus barrier used by the Trainer
# ---------------------------------------------------------------------------


def gradient_vote(grads: Any, max_norm: float = 1e4) -> jax.Array:
    """This replica's vote: gradients are finite and in bounds."""
    leaves = jax.tree_util.tree_leaves(grads)
    finite = jnp.asarray(True)
    sq = jnp.asarray(0.0, jnp.float32)
    for g in leaves:
        finite = jnp.logical_and(finite, jnp.all(jnp.isfinite(g)))
        sq = sq + jnp.sum(jnp.square(g.astype(jnp.float32)))
    ok = jnp.logical_and(finite, jnp.sqrt(sq) < max_norm)
    return ok.astype(jnp.float32)


def consensus_gradient_sync(
    grads: Any,
    axis_names: Sequence[str],
    track: str = "fast",
    max_norm: float = 1e4,
) -> Tuple[Any, jax.Array, jax.Array]:
    """All-reduce gradients under a Fast Raft commit barrier.

    track:
      "fast"    — vote piggybacked on the gradient psum (1 fused round).
      "classic" — separate gather + broadcast vote rounds, then the gradient
                  psum (3 collective rounds total; the Raft baseline).

    Pathological replicas are excluded from the mean: each leaf is
    pre-multiplied by the local vote, and the sum is normalized by n_yes —
    so a diverging replica cannot poison a committed step.
    Returns (mean_grads, n_yes, committed).
    """
    vote = gradient_vote(grads, max_norm)
    # nan_to_num before gating: NaN * 0 would still be NaN, and a replica
    # votes 0 exactly when it holds non-finite values.
    gated = jax.tree_util.tree_map(
        lambda g: (jnp.nan_to_num(g.astype(jnp.float32)) * vote).astype(g.dtype),
        grads,
    )
    if track == "fast":
        summed, n_yes, committed = voted_psum(gated, vote, axis_names)
    elif track == "classic":
        n_yes, committed = classic_track_commit(vote, axis_names)
        summed = lax.psum(gated, axis_names)
    else:
        raise ValueError(f"unknown track {track!r}")
    denom = jnp.maximum(n_yes, 1.0)
    mean = jax.tree_util.tree_map(lambda g: (g / denom.astype(g.dtype)), summed)
    return mean, n_yes, committed
