"""Core protocol types shared by Raft and Fast Raft.

Terminology follows the Raft paper (Ongaro & Ousterhout, 2014) and the Fast
Raft description (Castiglia, Goldberg & Patterson, 2020; SebaRaj & Melnychuk,
2025 implementation paper):

- A log *slot* holds at most one entry per (term, index). Under Fast Raft a
  slot may be *tentative* (fast-track proposal awaiting a supermajority) and
  is over-writable until finalized; classic Raft slots are append-only from
  the leader's point of view.
- The *fast quorum* is ceil(3M/4); the *classic quorum* is the majority
  floor(M/2)+1. Any two fast quorums intersect in >= a majority, and any fast
  quorum intersects any majority in >= recovery_threshold nodes, which is
  what makes leader-side recovery of fast-committed entries sound (see
  ``recovery_threshold``).
"""
from __future__ import annotations

import copy
import dataclasses
import enum
import json
import math
from typing import Any, Dict, Iterable, Optional, Set, Tuple

NodeId = str


def majority(m: int) -> int:
    """Classic Raft quorum size for a cluster of m nodes."""
    return m // 2 + 1


def fast_quorum(m: int) -> int:
    """Fast-track quorum size: ceil(3M/4) (paper section 2.2)."""
    return math.ceil(3 * m / 4)


def recovery_threshold(m: int) -> int:
    """Minimum multiplicity in a majority sample that identifies a possibly
    fast-committed entry.

    If an entry x fast-committed, >= fast_quorum(m) nodes hold it, so any
    majority Q of size majority(m) contains at least
    ``fast_quorum(m) + majority(m) - m`` holders. Two distinct entries can
    never both reach this count within one majority because
    2 * recovery_threshold(m) > majority(m) for all m >= 3.
    """
    return fast_quorum(m) + majority(m) - m


@dataclasses.dataclass(frozen=True, slots=True)
class ClusterConfig:
    """First-class, log-replicated cluster configuration.

    Every quorum decision in the system — leader elections, commit
    advancement, ReadIndex/lease confirmation rounds, and the fast track's
    ceil(3V/4) acceptor quorum — flows through this object rather than raw
    ``len(members)`` math, which is what makes membership changes safe:

    - ``voters`` is the (target) voting set C_new. ``learners`` are
      non-voting members: they receive full replication traffic (log
      batches, snapshots) so they can catch up, but never count toward any
      quorum and never campaign.
    - During a **joint consensus** change (Raft dissertation chapter 4),
      ``old_voters`` holds C_old and every quorum must be reached in BOTH
      voter sets independently. A config with ``old_voters is None`` is a
      simple (final) config. The joint config is itself a log entry; once
      it commits, the leader appends the final C_new config, and only when
      THAT commits is the transition done.
    - ``witnesses`` marks a subset of the voters as **quorum-only
      members** (BlackWater-style): they vote in elections, ack
      replication rounds, and count toward every quorum predicate, but
      store only log *positions* (term/index/entry-id skeletons, no
      command payloads), run no state machine, never campaign, and never
      serve reads. The marker survives joint transitions — a witness in
      C_old stays a witness in C_old,new and C_new unless removed. Safety
      rests on the acked-log floor (DESIGN.md §12): a witness is
      permanently in the "restored node that lost its log" state that §10
      already makes safe.

    A config takes effect the moment it is appended to a node's log (not
    when it commits) and rolls back if the entry is truncated — the
    dissertation's rule, required so C_new's quorum constraints bind
    before the change is durable anywhere.

    Instances are frozen and canonical (sorted, deduplicated): construct
    through :meth:`of` / :meth:`from_wire`.
    """

    voters: Tuple[NodeId, ...]
    learners: Tuple[NodeId, ...] = ()
    old_voters: Optional[Tuple[NodeId, ...]] = None
    witnesses: Tuple[NodeId, ...] = ()
    # Lazily computed members cache; must be a declared field now that the
    # class is slotted (object.__setattr__ needs a slot to land in).
    _members_cache: Optional[Tuple[NodeId, ...]] = dataclasses.field(
        default=None, init=False, compare=False, repr=False
    )

    @staticmethod
    def of(
        voters: Iterable[NodeId],
        learners: Iterable[NodeId] = (),
        old_voters: Optional[Iterable[NodeId]] = None,
        witnesses: Iterable[NodeId] = (),
    ) -> "ClusterConfig":
        v = tuple(sorted(set(voters)))
        ov = None if old_voters is None else tuple(sorted(set(old_voters)))
        # The marker only means something for ids that vote in some
        # active set; canonicalize so equality is structural.
        voting = set(v) | (set(ov) if ov is not None else set())
        return ClusterConfig(
            voters=v,
            learners=tuple(sorted(set(learners) - set(v))),
            old_voters=ov,
            witnesses=tuple(sorted(set(witnesses) & voting)),
        )

    @property
    def joint(self) -> bool:
        return self.old_voters is not None

    def voter_sets(self) -> Tuple[Tuple[NodeId, ...], ...]:
        """The independent voter sets a quorum must be reached in: one for
        a simple config, both C_old and C_new during joint consensus."""
        if self.old_voters is None:
            return (self.voters,)
        return (self.voters, self.old_voters)

    @property
    def members(self) -> Tuple[NodeId, ...]:
        """Everyone who receives replication traffic: voters of every
        active config plus learners. Cached — this backs the hot
        RaftNode.members/peers()/m paths evaluated on every message
        round, and the instance is frozen."""
        cached = getattr(self, "_members_cache", None)
        if cached is None:
            all_ids: Set[NodeId] = set(self.voters) | set(self.learners)
            if self.old_voters is not None:
                all_ids |= set(self.old_voters)
            cached = tuple(sorted(all_ids))
            object.__setattr__(self, "_members_cache", cached)
        return cached

    def is_voter(self, nid: NodeId) -> bool:
        return any(nid in vs for vs in self.voter_sets())

    def is_learner(self, nid: NodeId) -> bool:
        return nid in self.learners and not self.is_voter(nid)

    def is_witness(self, nid: NodeId) -> bool:
        """Quorum-only voter: counts toward every quorum but stores no
        command payloads, never campaigns, never serves reads."""
        return nid in self.witnesses and self.is_voter(nid)

    def election_won(self, granted: Set[NodeId]) -> bool:
        """True iff ``granted`` contains a majority of EVERY active voter
        set (both halves of a joint config must elect)."""
        return all(
            len(granted & set(vs)) >= majority(len(vs)) for vs in self.voter_sets()
        )

    # Commit quorum is the same predicate; the alias keeps call sites
    # self-documenting.
    commit_ok = election_won

    def fast_ok(self, voted: Set[NodeId]) -> bool:
        """Fast-track finalization quorum: ceil(3V/4) of every active
        voter set must have voted for the same entry."""
        return all(
            len(voted & set(vs)) >= fast_quorum(len(vs)) for vs in self.voter_sets()
        )

    def fast_possible(self, supporters: Set[NodeId], cast: Set[NodeId]) -> bool:
        """Could ``supporters`` still grow to a fast quorum in every voter
        set, given that ``cast`` have already voted (per-slot FCFS: a cast
        vote is never changed)?"""
        for vs in self.voter_sets():
            s = set(vs)
            if len(supporters & s) + len(s - cast) < fast_quorum(len(s)):
                return False
        return True

    def final(self) -> "ClusterConfig":
        """The simple config that ends this joint transition."""
        return ClusterConfig.of(self.voters, self.learners, witnesses=self.witnesses)

    def to_wire(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "voters": list(self.voters),
            "learners": list(self.learners),
        }
        if self.old_voters is not None:
            d["old_voters"] = list(self.old_voters)
        if self.witnesses:
            d["witnesses"] = list(self.witnesses)
        return d

    @staticmethod
    def from_wire(d: Dict[str, Any]) -> "ClusterConfig":
        return ClusterConfig.of(
            d.get("voters", ()),
            d.get("learners", ()),
            d.get("old_voters"),
            d.get("witnesses", ()),
        )


class Role(enum.Enum):
    FOLLOWER = "follower"
    CANDIDATE = "candidate"
    LEADER = "leader"


class SlotState(enum.Enum):
    """State of a log slot."""

    CLASSIC = "classic"      # appended via leader AppendEntries (Raft authority)
    TENTATIVE = "tentative"  # fast-track proposal, over-writable
    FINALIZED = "finalized"  # fast-track proposal that reached ceil(3M/4)


@dataclasses.dataclass(frozen=True, slots=True)
class EntryId:
    """Globally unique identity of a proposed command (origin + sequence).

    Used to key fast-track votes and to deduplicate client retries.
    Hashed on every dedup-table probe, vote tally, and entry-index lookup,
    so the hash is computed once at construction instead of per probe.
    """

    origin: NodeId
    seq: int
    _hash: int = dataclasses.field(
        default=0, init=False, repr=False, compare=False
    )

    def __post_init__(self) -> None:
        object.__setattr__(self, "_hash", hash((self.origin, self.seq)))

    def __hash__(self) -> int:
        return self._hash

    def __str__(self) -> str:  # compact for logs
        return f"{self.origin}#{self.seq}"


@dataclasses.dataclass(slots=True)
class Entry:
    term: int
    command: Any
    entry_id: EntryId
    # Bookkeeping (not part of protocol identity):
    proposed_at: float = 0.0

    def same_entry(self, other: "Entry") -> bool:
        return self.entry_id == other.entry_id

    def clone(self) -> "Entry":
        return Entry(self.term, self.command, self.entry_id, self.proposed_at)


@dataclasses.dataclass(slots=True)
class Slot:
    entry: Entry
    state: SlotState

    def clone(self) -> "Slot":
        return Slot(self.entry.clone(), self.state)


def entry_to_wire(e: Entry) -> Dict[str, Any]:
    """JSON-serializable form of an Entry (LogList snapshot state and the
    SnapshotStore both use this shape)."""
    return {
        "term": e.term,
        "command": e.command,
        "origin": e.entry_id.origin,
        "seq": e.entry_id.seq,
        "proposed_at": e.proposed_at,
    }


def entry_from_wire(d: Dict[str, Any]) -> Entry:
    return Entry(
        term=d["term"],
        command=d["command"],
        entry_id=EntryId(d["origin"], d["seq"]),
        proposed_at=d.get("proposed_at", 0.0),
    )


@dataclasses.dataclass(slots=True)
class Snapshot:
    """A compacted committed prefix of the log (indexes 1..last_index).

    ``state`` is the OPAQUE reduced state produced by the node's
    :class:`repro.core.statemachine.StateMachine` — the consensus layer
    never interprets it, it only ships and persists it. ``dedup`` is the
    compact client-retry filter (:class:`repro.core.statemachine.
    DedupTable` state) that keeps EntryId dedup exact across compaction now
    that entries no longer ride in the snapshot. ``config`` is the full
    :class:`ClusterConfig` as of ``last_index`` (wire format v2) so a
    follower restored from scratch learns voters/learners/joint state too;
    ``members`` stays as the flat member list for v1 readers and debug
    tooling. Both ``state`` and ``dedup`` must be JSON-serializable
    (:func:`snapshot_to_bytes` is the wire/persistence format).
    """

    last_index: int
    last_term: int
    state: Any = None
    members: Tuple[NodeId, ...] = ()
    dedup: Any = None
    config: Optional[ClusterConfig] = None
    # Provenance of a delta-installed snapshot (RaftConfig.delta_snapshots):
    # the last_index of the base snapshot the shipped delta was applied to.
    # Purely informational once the state is materialized — the snapshot is
    # complete either way — but persisted by the checkpoint store so a
    # restored host's provenance survives. -1 = built from full state.
    delta_base: int = -1
    # Cached wire size (see size_bytes); a declared field because the class
    # is slotted. Excluded from comparison/repr — it's derived state.
    _wire_bytes: Optional[int] = dataclasses.field(
        default=None, init=False, compare=False, repr=False
    )

    def cluster_config(self) -> ClusterConfig:
        """The config this snapshot pins, with the v1 legacy-load path:
        old snapshots carry only the flat member list, which decodes as an
        all-voter simple config (exactly what v1 semantics were)."""
        if self.config is not None:
            return self.config
        return ClusterConfig.of(self.members)

    @property
    def entries(self) -> Tuple[Entry, ...]:
        """Compatibility view: decode ``state`` as an applied entry list
        when it has the LogListMachine shape (the default machine), else
        an empty tuple (reduced-state machines don't carry entries)."""
        if not isinstance(self.state, (list, tuple)):
            return ()
        out = []
        for d in self.state:
            if not (isinstance(d, dict) and "command" in d and "origin" in d):
                return ()
            out.append(entry_from_wire(d))
        return tuple(out)

    def size_bytes(self) -> int:
        # Cached: state is immutable once the snapshot is taken (the
        # StateMachine contract), and the monolithic InstallSnapshot path
        # would otherwise re-serialize the whole state on every heartbeat
        # retransmission just to estimate the message size.
        size = getattr(self, "_wire_bytes", None)
        if size is None:
            size = len(snapshot_to_bytes(self))
            self._wire_bytes = size
        return size

    def clone(self) -> "Snapshot":
        snap = Snapshot(
            self.last_index,
            self.last_term,
            copy.deepcopy(self.state),
            tuple(self.members),
            copy.deepcopy(self.dedup),
            self.config,  # frozen, safe to share
            self.delta_base,
        )
        size = getattr(self, "_wire_bytes", None)
        if size is not None:
            snap._wire_bytes = size
        return snap


def snapshot_to_bytes(snap: Snapshot) -> bytes:
    """Canonical serialized form of a snapshot — the unit the chunked
    InstallSnapshot protocol streams and the SnapshotStore persists.
    ``sort_keys`` makes the byte stream identical across leaders holding
    the same (deterministic) applied state, so a transfer can survive a
    leader change without splicing mismatched bytes.

    Wire format v2: adds ``config`` (the full ClusterConfig — voters,
    learners, joint old_voters) next to the legacy flat ``members`` list.
    v1 payloads (no ``config``/``version`` keys) still load: the member
    list decodes as an all-voter simple config."""
    payload = {
        "last_index": snap.last_index,
        "last_term": snap.last_term,
        "members": list(snap.members),
        "state": snap.state,
        "dedup": snap.dedup,
        "version": 2,
    }
    if snap.config is not None:
        payload["config"] = snap.config.to_wire()
    if snap.delta_base >= 0:
        # Delta provenance, persisted/streamed only when set so the byte
        # stream of ordinary snapshots is unchanged.
        payload["delta_base"] = snap.delta_base
    return json.dumps(payload, sort_keys=True).encode("utf-8")


def snapshot_from_bytes(data: bytes) -> Snapshot:
    payload = json.loads(data.decode("utf-8"))
    cfg = payload.get("config")
    return Snapshot(
        last_index=payload["last_index"],
        last_term=payload["last_term"],
        state=payload["state"],
        members=tuple(payload["members"]),
        dedup=payload.get("dedup"),
        config=None if cfg is None else ClusterConfig.from_wire(cfg),
        delta_base=payload.get("delta_base", -1),
    )


def snapshot_delta_to_bytes(snap: Snapshot, delta: Any, delta_base: int) -> bytes:
    """Delta-snapshot wire form (RaftConfig.delta_snapshots): the full
    snapshot metadata — identity, members/config, dedup filter, all small —
    but only the state machine DELTA against the follower-advertised base
    snapshot ``delta_base`` instead of the full state. Streamed through the
    same chunk/CRC/resume machinery as the full form; the receiver
    reconstructs the complete state via ``StateMachine.apply_delta``."""
    payload = {
        "kind": "delta",
        "last_index": snap.last_index,
        "last_term": snap.last_term,
        "members": list(snap.members),
        "delta": delta,
        "dedup": snap.dedup,
        "delta_base": delta_base,
        "version": 2,
    }
    if snap.config is not None:
        payload["config"] = snap.config.to_wire()
    return json.dumps(payload, sort_keys=True).encode("utf-8")


def snapshot_delta_from_bytes(data: bytes) -> Dict[str, Any]:
    """Decode a delta-snapshot stream. Raises ValueError when the payload
    is not a delta doc (so a mixed-up buffer fails loudly into the normal
    decode-failure fallback, never silently installs garbage)."""
    payload = json.loads(data.decode("utf-8"))
    if payload.get("kind") != "delta":
        raise ValueError("not a delta snapshot payload")
    return payload


# --------------------------------------------------------------------------
# RPC messages. Every message carries ``term`` for the standard Raft term
# rules. Dataclasses keep the simulator transport trivially serializable.
# --------------------------------------------------------------------------


@dataclasses.dataclass(slots=True)
class Message:
    term: int
    src: NodeId = ""


@dataclasses.dataclass(slots=True)
class RequestVoteArgs(Message):
    candidate_id: NodeId = ""
    last_log_index: int = 0
    last_log_term: int = 0


@dataclasses.dataclass(slots=True)
class PreVoteArgs(Message):
    """PreVote probe (Raft dissertation section 9.6 / etcd PreVote).

    ``term`` is the PROSPECTIVE term (candidate's term + 1) the sender
    would campaign in — receivers never adopt it, which is the whole
    point: a partitioned or removed node can probe forever without
    inflating anyone's term. A voter answers based on log up-to-dateness
    and leader-contact recency only; granting a pre-vote neither records a
    ``voted_for`` nor resets the voter's election timer."""

    candidate_id: NodeId = ""
    last_log_index: int = 0
    last_log_term: int = 0


@dataclasses.dataclass(slots=True)
class PreVoteReply(Message):
    """``term`` is the voter's REAL current term (standard term rules apply
    to the reply: a higher one cancels the probe). ``prospective_term``
    echoes the probe's term so a candidate only counts grants for its
    current campaign."""

    vote_granted: bool = False
    prospective_term: int = 0


@dataclasses.dataclass(slots=True)
class RequestVoteReply(Message):
    vote_granted: bool = False
    # Fast Raft recovery: voters ship a summary of their tentative tail so a
    # new leader can recover fast-committed entries (see
    # FastRaftNode._recover_tentative). {index: (entry, state_name)}
    tentative_tail: Optional[dict] = None
    last_log_index: int = 0


@dataclasses.dataclass(slots=True)
class AppendEntriesArgs(Message):
    leader_id: NodeId = ""
    prev_log_index: int = 0
    prev_log_term: int = 0
    entries: Tuple[Slot, ...] = ()
    leader_commit: int = 0
    # Heartbeat-round tag for leader-lease accounting: every broadcast
    # increments the leader's round counter and stamps its messages with it;
    # the reply echoes the tag, so a quorum of echoes for round r proves the
    # leader was still recognized no earlier than r's send time — the lease
    # basis. 0 = untagged (pre-lease peers / replies to stale leaders).
    hb_id: int = 0
    # Certified read watermark riding every heartbeat/replication round —
    # the replica-read protocol. (read_wm, read_wm_ts) is the leader's
    # newest QUORUM-CONFIRMED claim: "every write committed anywhere
    # strictly before sim time read_wm_ts has index <= read_wm". The claim
    # is minted in _note_round_ack — read_wm is the leader's commit_index
    # captured when round q was SENT (under the current-term read barrier),
    # and the quorum echo of q proves no rival leadership existed before
    # q's send time — so a follower/learner can serve reads at index
    # read_wm with NO leader round-trip. read_wm < 0 = no certified
    # watermark yet (fresh leader pre-barrier, or pre-watermark peer).
    read_wm: int = -1
    read_wm_ts: float = -1.0e18


@dataclasses.dataclass(slots=True)
class AppendEntriesReply(Message):
    success: bool = False
    match_index: int = 0
    hb_id: int = 0
    # Delta-snapshot negotiation (RaftConfig.delta_snapshots): the
    # follower's current snapshot.last_index, advertised on every reply so
    # the leader knows which retained base a delta stream can build on.
    # -1 = not advertised (knob off / no snapshot yet).
    snap_index: int = -1
    # Ack piggybacking (RaftConfig.ack_piggyback): how many same-tick acks
    # were folded into this reply. The leader releases this many pipeline
    # slots instead of one. Always 1 when the knob is off.
    n_acks: int = 1


@dataclasses.dataclass(slots=True)
class InstallSnapshotArgs(Message):
    """Leader -> lagging follower whose needed entries were compacted away."""

    leader_id: NodeId = ""
    snapshot: Optional[Snapshot] = None
    leader_commit: int = 0


@dataclasses.dataclass(slots=True)
class InstallSnapshotReply(Message):
    # match_index == snapshot.last_index on success; the leader resumes
    # normal AppendEntries pipelining from there.
    match_index: int = 0


@dataclasses.dataclass(slots=True)
class InstallSnapshotChunk(Message):
    """One chunk of a serialized snapshot (``RaftConfig.snapshot_chunk_bytes``
    > 0). The snapshot identity is (last_index, last_term): a chunk for a
    different identity than the receiver's in-progress transfer restarts the
    transfer (the leader compacted again); same identity + ``offset`` equal
    to the receiver's write cursor extends it. At most one chunk is in
    flight per follower; each heartbeat retransmits the unacked chunk.

    ``data_crc`` is the crc32 of ``data``: the receiver verifies it and
    treats a mismatch exactly like loss (no ack; the cursor-based
    retransmission resends the chunk), so a corrupted payload can never be
    spliced into an assembling snapshot."""

    leader_id: NodeId = ""
    last_index: int = 0
    last_term: int = 0
    offset: int = 0
    data: bytes = b""
    data_crc: int = 0
    total_bytes: int = 0
    done: bool = False
    leader_commit: int = 0
    # Delta transfer (RaftConfig.delta_snapshots): the snapshot.last_index
    # of the base this stream is a delta AGAINST. The receiver must still
    # hold exactly that snapshot to apply the delta; otherwise it replies
    # need_full=True and the leader restarts with the full stream.
    # -1 = the stream is a full serialized snapshot.
    delta_base: int = -1


@dataclasses.dataclass(slots=True)
class InstallSnapshotChunkReply(Message):
    """``next_offset`` is the follower's authoritative write cursor — the
    resume point. The leader adopts it verbatim (a follower that crashed
    mid-transfer legitimately rewinds to 0). ``match_index`` > 0 once the
    snapshot is fully installed; the leader then resumes AppendEntries
    pipelining above it, exactly like the monolithic reply."""

    last_index: int = 0
    next_offset: int = 0
    match_index: int = 0
    # Delta negotiation failure: the follower no longer holds the base the
    # delta stream was computed against (restarted from an older
    # checkpoint, installed a different snapshot since advertising). The
    # leader drops the delta transfer and resends the full stream.
    need_full: bool = False


@dataclasses.dataclass(slots=True)
class ForwardOperation(Message):
    """Classic track from a non-leader: relay the command to the leader.

    ``batch`` carries additional (command, entry_id) pairs coalesced behind
    the head command, so one relay RPC moves a whole client burst.
    """

    command: Any = None
    entry_id: Optional[EntryId] = None
    batch: Tuple = ()  # Tuple[Tuple[Any, EntryId], ...]


@dataclasses.dataclass(slots=True)
class FastPropose(Message):
    """Fast track round 1: proposer -> ALL nodes.

    Single-slot form: (index, entry). Batched form: ``window`` holds entries
    for the consecutive slots index, index+1, ... — one RPC proposes a whole
    multi-slot window and acceptors vote per-slot (first-come-first-served
    per slot, exactly as if the window had been sent as N proposals).
    """

    index: int = 0
    entry: Optional[Entry] = None
    window: Tuple[Entry, ...] = ()


@dataclasses.dataclass(slots=True)
class FastVote(Message):
    """Fast track round 2: acceptor -> leader, voting for (index, entry_id).

    ``window_votes`` batches votes for the slots of a FastPropose window:
    entry_ids for consecutive slots starting at ``index`` (None where the
    acceptor refused that slot).
    """

    index: int = 0
    entry_id: Optional[EntryId] = None
    voter: NodeId = ""
    window_votes: Tuple[Optional[EntryId], ...] = ()
    # Ack piggybacking (RaftConfig.ack_piggyback): additional single-slot
    # votes cast in the same delivery tick, folded behind the head vote as
    # (index, entry_id) pairs — one message per acceptor per tick instead
    # of one per FastPropose.
    multi_votes: Tuple = ()  # Tuple[Tuple[int, EntryId], ...]


@dataclasses.dataclass(slots=True)
class FastFinalize(Message):
    """Fast track round 3: leader -> ALL, the slot reached ceil(3M/4).

    ``window`` batches finalizations for consecutive slots starting at
    ``index`` (entries for index, index+1, ...), produced when a window vote
    resolves several slots in one step.
    """

    index: int = 0
    entry: Optional[Entry] = None
    leader_commit: int = 0
    window: Tuple[Entry, ...] = ()


@dataclasses.dataclass(slots=True)
class ReadIndexProbe(Message):
    """Leader -> ALL: one leadership-confirmation round for pending
    linearizable reads (the ReadIndex protocol). ``probe_id`` comes from the
    same monotone round counter as AppendEntries ``hb_id``, so probe acks
    and heartbeat acks share one quorum/lease accounting path. A follower
    that acks a probe also resets its election timer — the promise the
    leader-lease safety argument rests on (no new leader sooner than
    election_timeout_min after the ack). Probes carry the certified read
    watermark too (same semantics as ``AppendEntriesArgs.read_wm``) so a
    read-heavy leader publishes watermarks at probe cadence, not just at
    heartbeat cadence."""

    leader_id: NodeId = ""
    probe_id: int = 0
    read_wm: int = -1
    read_wm_ts: float = -1.0e18


@dataclasses.dataclass(slots=True)
class ReadIndexProbeReply(Message):
    probe_id: int = 0
    ok: bool = False


@dataclasses.dataclass(slots=True)
class ReadQuery(Message):
    """Non-leader -> leader: relay a linearizable read. ``read_id`` is the
    client-side identity (origin + seq, EntryId-shaped but NEVER entered in
    the dedup table — reads must not be recorded as applied commands);
    replies and origin-side retries are deduplicated on it."""

    read_id: Optional[EntryId] = None
    query: Any = None


@dataclasses.dataclass(slots=True)
class ReadReply(Message):
    """Leader -> read origin. ``served_index`` is the leader's last_applied
    at serve time (>= the captured read index) — what the read-oracle
    checker validates freshness against. ``ok=False`` means "retry via
    leader_hint" (the serving node lost leadership).

    ``batch`` carries additional ``(read_id, value)`` pairs served to the
    same origin in the same confirmation round (read coalescing groups all
    reads released together into ONE reply per origin; ``served_index``
    is shared — every batched read was served from the same applied
    state)."""

    read_id: Optional[EntryId] = None
    ok: bool = False
    value: Any = None
    served_index: int = 0
    leader_hint: Optional[NodeId] = None
    batch: Tuple = ()  # Tuple[Tuple[EntryId, Any], ...]


@dataclasses.dataclass(slots=True)
class ClientReply(Message):
    ok: bool = False
    entry_id: Optional[EntryId] = None
    index: int = 0
    leader_hint: Optional[NodeId] = None


# Hierarchical tier (pod leaders) wraps inner messages with routing metadata.
@dataclasses.dataclass(slots=True)
class TierEnvelope(Message):
    """Envelope for global-tier traffic routed between pod leaders.

    ``member`` is the stable *pod identity* in the global group; the physical
    host currently serving that member is resolved by the hierarchy router —
    this is exactly the dynamic-membership trick of the paper: logical
    membership is stable while physical hosts churn.
    """

    member: NodeId = ""
    payload: Any = None
