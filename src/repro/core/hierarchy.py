"""Hierarchical consensus: per-pod groups + a global tier of pod leaders.

This is the model of the underlying Fast Raft paper (Castiglia, Goldberg &
Patterson): the network is organized into *clusters* — here, TPU pods — each
running consensus locally over fast links (ICI-adjacent hosts, ~0.5 ms);
cluster leaders form an upper tier over slow links (inter-pod DCN, ~10 ms)
for global agreement. Membership in the global tier is *logical*: member
identity is the pod id, while the physical host serving it is whichever host
currently leads the pod — so pod-leader churn is invisible to the global
group's membership, which is exactly how the paper handles dynamic networks.

Availability coupling: while a pod has no local leader (election in
progress, partition, crash storm), its global member is unreachable — global
messages to it are dropped, and the global tier rides through via its own
quorums. The global member's persistent state is modeled as surviving leader
migration; in a deployment it is replicated through the pod's local log
(every state mutation of the global member is a local log entry), which the
local consensus layer makes durable — see DESIGN.md.

Down-propagation: when the global tier commits an entry, each pod's member
injects a shadow entry into the pod's local log so every host learns the
global decision through local (cheap) consensus.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.fast_raft import FastRaftNode
from repro.core.metrics import Recorder
from repro.core.raft import RaftConfig, RaftNode
from repro.core.sim import (
    EV_GDELIVER,
    EV_GTICK,
    Adversary,
    Cluster,
    FailureProfile,
    LinkModel,
    MembershipError,
    Simulation,
    wire_size,
)
from repro.core.statemachine import LogListMachine, StateMachine
from repro.core.types import Entry, EntryId, Message, NodeId

GLOBAL_SHADOW_PREFIX = "__global__:"


def coflaky_risk(
    placement: Dict[str, Sequence[NodeId]], groups: Dict[NodeId, str]
) -> Dict[str, float]:
    """Per-pod worst-case correlated-failure exposure: the largest
    fraction of a pod's hosts that share one failure group (rack, AZ,
    spot pool — FailureProfile.group). A value >= the pod's majority
    fraction means ONE group outage silently costs the pod its quorum —
    the exact co-flakiness the placement policy exists to avoid.
    Pure function of the placement, so tests and planners can score
    layouts without simulating."""
    risk: Dict[str, float] = {}
    for pod, hosts in placement.items():
        counts: Dict[str, int] = {}
        for h in hosts:
            g = groups.get(h, "")
            if g:
                counts[g] = counts.get(g, 0) + 1
        risk[pod] = max(counts.values(), default=0) / max(1, len(hosts))
    return risk


def plan_coflaky_moves(
    placement: Dict[str, Sequence[NodeId]],
    groups: Dict[NodeId, str],
    max_moves: int = 64,
) -> List[Tuple[NodeId, str, str]]:
    """Greedy de-correlation plan, SWAP-based: while some pod has a
    failure group holding a MAJORITY of its hosts (so one group outage
    kills the pod's quorum), exchange one host of that group with a
    differently-grouped host from the pod where the group's presence is
    smallest. Swapping (rather than one-way moves) keeps every pod at
    its size — a pod that is 100% one rack can never be fixed by
    shrinking it, only by mixing other racks in. Each host moves at most
    once and every accepted swap strictly reduces the offending group's
    count in the source pod, so the loop terminates; when no safe
    counterparty exists the plan stops best-effort (with three rack-A
    hosts spread over two 3-host pods, SOME pod must keep two of them).
    Returns ``(host, src_pod, dst_pod)`` tuples — two per swap — for
    :meth:`HierarchicalCluster.move_node`; pure, so the plan is
    unit-testable without a simulation."""
    place = {p: list(hs) for p, hs in placement.items()}
    moved: set = set()
    moves: List[Tuple[NodeId, str, str]] = []

    def group_counts(hosts: List[NodeId]) -> Dict[str, int]:
        c: Dict[str, int] = {}
        for h in hosts:
            g = groups.get(h, "")
            if g:
                c[g] = c.get(g, 0) + 1
        return c

    while len(moves) + 2 <= max_moves:
        # Worst offender: the (pod, group) whose loss leaves the fewest
        # survivors relative to the pod's majority.
        worst = None  # (share, pod, group)
        for pod in sorted(place):
            hosts = place[pod]
            majority = len(hosts) // 2 + 1
            for g, c in sorted(group_counts(hosts).items()):
                if c >= majority and (worst is None or c / len(hosts) > worst[0]):
                    worst = (c / len(hosts), pod, g)
        if worst is None:
            return moves
        _, src, g = worst
        outgoing = [
            h for h in sorted(place[src]) if groups.get(h, "") == g and h not in moved
        ]
        if not outgoing:
            return moves  # every offender already moved once; give up
        host_out = outgoing[0]
        # Counterparty pod: smallest presence of g, and receiving the host
        # must not hand the destination its own g-majority (sizes are
        # unchanged by a swap, so the majority threshold is today's).
        swap = None  # (host_in, dst)
        for pod in sorted(place, key=lambda p: (group_counts(place[p]).get(g, 0), p)):
            if pod == src:
                continue
            if group_counts(place[pod]).get(g, 0) + 1 >= len(place[pod]) // 2 + 1:
                continue
            # Counter-host: any unmoved host NOT in group g, preferring
            # groups the source pod has least of.
            src_counts = group_counts(place[src])
            incoming = sorted(
                (h for h in place[pod]
                 if groups.get(h, "") != g and h not in moved),
                key=lambda h: (src_counts.get(groups.get(h, ""), 0), h),
            )
            if incoming:
                swap = (incoming[0], pod)
                break
        if swap is None:
            return moves  # nowhere safe to swap with
        host_in, dst = swap
        place[src].remove(host_out)
        place[dst].append(host_out)
        place[dst].remove(host_in)
        place[src].append(host_in)
        moved.add(host_out)
        moved.add(host_in)
        moves.append((host_out, src, dst))
        moves.append((host_in, dst, src))
    return moves


class GlobalDeliveryMachine(LogListMachine):
    """State machine of a global-tier member: the applied global history,
    surfacing every globally-committed entry to the hierarchy for
    down-propagation into the member's pod.

    Delivery hooks BOTH paths a global member can learn a commit through:
    ``apply`` (normal replication) and ``restore`` (an InstallSnapshot jump
    past compacted history — now that the global tier compacts and streams
    chunked snapshots, a lagging member may never apply the interior
    entries individually). Restore re-announces the full history; the
    pod-level (index, entry_id) dedup in the hierarchy makes re-delivery
    idempotent, so over-announcing is safe where under-announcing would
    silently lose global commands in the skipped range."""

    name = "global-delivery"

    def __init__(self, on_entry: Callable[[int, Entry], None]):
        super().__init__()
        self._on_entry = on_entry

    def apply(self, index: int, entry: Entry) -> Any:
        r = super().apply(index, entry)
        self._on_entry(index, entry)
        return r

    def restore(self, state: Any) -> None:
        super().restore(state)
        for i, e in enumerate(self._entries):
            self._on_entry(i + 1, e)


@dataclasses.dataclass
class PodMove:
    """Tracking record for one live pod rebalancing (move_node).

    ``ops`` holds the underlying MembershipOps this move issued (removal
    on the source pod, learner+promotion on the destination) — failure is
    judged on THESE ops only, never on unrelated churn in either pod."""

    nid: NodeId
    src_pod: str
    dst_pod: str
    deadline: float
    stage: str = "removing"  # removing -> joining -> done | failed
    error: str = ""
    ops: List = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.stage == "done"

    @property
    def failed(self) -> bool:
        return self.stage == "failed"


class ShadowDeliveryMachine(StateMachine):
    """Wraps a pod host's state machine and surfaces globally-committed
    shadow entries to the hierarchy as they apply locally.

    Delivery rides the replicated apply path (not a harness callback): every
    host's machine observes the shadow entry when the pod's local consensus
    applies it, and the hierarchy dedups per pod on (index, entry_id) —
    first local apply wins. A host that catches up via a snapshot jump skips
    individual applies, which is safe: the snapshotting host already applied
    (and delivered) those entries, so the pod-level dedup has them."""

    name = "shadow"

    def __init__(self, inner: StateMachine, on_shadow: Callable[[int, Entry], None]):
        self.inner = inner
        self.on_shadow = on_shadow

    def apply(self, index: int, entry: Entry) -> Any:
        cmd = entry.command
        if isinstance(cmd, str) and cmd.startswith(GLOBAL_SHADOW_PREFIX):
            self.on_shadow(index, entry)
        return self.inner.apply(index, entry)

    def snapshot(self) -> Any:
        return self.inner.snapshot()

    def restore(self, state: Any) -> None:
        self.inner.restore(state)

    def size_bytes(self) -> int:
        return self.inner.size_bytes()

    def query(self, query: Any) -> Any:
        # Read-only pass-through: shadow delivery only intercepts applies.
        return self.inner.query(query)

    def applied_entries(self):
        return self.inner.applied_entries()


class HierarchicalCluster:
    def __init__(
        self,
        n_pods: int = 2,
        hosts_per_pod: int = 3,
        protocol: str = "fastraft",
        seed: int = 0,
        local_loss: float = 0.0,
        local_latency: float = 0.5,
        global_loss: float = 0.0,
        global_latency: float = 10.0,
        jitter: float = 0.0,
        msg_overhead: float = 0.0,
        global_bytes_per_ms: float = 0.0,
        global_mtu_bytes: float = 0.0,
        tick_interval: float = 10.0,
        config: Optional[RaftConfig] = None,
        global_config: Optional[RaftConfig] = None,
        state_machine_factory: Optional[Callable[[NodeId], StateMachine]] = None,
        engine: str = "slotted",
        link_rng: str = "shared",
        link_rng_backend: str = "auto",
        relay_batch_window: float = 0.0,
        record_bytes: bool = False,
    ):
        self.sim = Simulation(seed)
        self.protocol = protocol
        self.engine = engine
        self.pod_ids = [f"pod{i}" for i in range(n_pods)]
        # The slow inter-pod links can be size-aware exactly like pod-local
        # ones (CD-Raft's economy argument is ABOUT these links); both
        # knobs default to 0.0 = the seed's pure-latency global network.
        self.global_link = LinkModel(global_loss, global_latency, jitter,
                                     bytes_per_ms=global_bytes_per_ms,
                                     mtu_bytes=global_mtu_bytes)
        self._global_link_busy: Dict[Tuple[str, str], float] = {}
        self.global_metrics = Recorder()
        self.record_bytes = record_bytes
        self.tick_interval = tick_interval
        # Down-propagation batching: >0 buffers globally-committed entries
        # per pod and injects them as ONE ordered client batch per window
        # (0.0 = seed behavior, one local entry injected per global commit).
        self.relay_batch_window = relay_batch_window
        self._relay_buf: Dict[str, List[Tuple[Any, EntryId]]] = {}
        self._relay_flush_scheduled: Dict[str, bool] = {}
        # Per-pod base machine factory (None = LogListMachine); each host's
        # machine is wrapped in a ShadowDeliveryMachine so globally-committed
        # entries disseminate through the replicated apply path.
        self._base_sm_factory = state_machine_factory

        # Delivered global commands per pod (via local shadow entries).
        self.delivered: Dict[str, List[Any]] = {}
        self._delivered_keys: Dict[str, set] = {}
        # Per-pod round-robin cursor for replica-read fan-out.
        self._replica_rr: Dict[str, int] = {}

        # Local tiers: one Cluster per pod, sharing the one simulation.
        self.pods: Dict[str, Cluster] = {}
        for pi, pod in enumerate(self.pod_ids):
            self.delivered[pod] = []
            self._delivered_keys[pod] = set()
            self.pods[pod] = Cluster(
                n=hosts_per_pod,
                protocol=protocol,
                seed=seed * 7919 + pi,
                loss=local_loss,
                base_latency=local_latency,
                jitter=jitter,
                msg_overhead=msg_overhead,
                config=config,
                tick_interval=tick_interval,
                node_prefix=f"{pod}h",
                sim=self.sim,
                state_machine_factory=self._pod_sm_factory(pod),
                engine=engine,
                link_rng=link_rng,
                link_rng_backend=link_rng_backend,
                record_bytes=record_bytes,
            )

        # Global tier: one logical member per pod. The default config
        # compacts its log and streams catch-up snapshots in pipelined
        # chunks: cross-domain (inter-pod) messages must stay SMALL
        # (CD-Raft's economy argument) — a lagging pod rejoining after a
        # partition must not pull one giant monolithic state transfer over
        # the slow global links.
        cls = FastRaftNode if protocol == "fastraft" else RaftNode
        gcfg = global_config or RaftConfig(
            election_timeout_min=400.0,
            election_timeout_max=800.0,
            heartbeat_interval=150.0,
            fast_vote_timeout=300.0,
            snapshot_threshold=32,
            snapshot_chunk_bytes=4096,
            snapshot_chunk_window=4,
        )
        self.global_nodes: Dict[str, RaftNode] = {}
        for pi, pod in enumerate(self.pod_ids):
            n = cls(pod, self.pod_ids, config=RaftConfig(**vars(gcfg)),
                    seed=seed * 104729 + pi,
                    state_machine=GlobalDeliveryMachine(self._make_global_apply(pod)))
            n.metrics = self.global_metrics
            # Global-tier members are built directly (not via Cluster._make_node),
            # so the engine flag must reach them here too.
            n._legacy_mode = engine == "legacy"
            self.global_nodes[pod] = n
        for pod, n in self.global_nodes.items():
            n.start(self.sim.now)
            self._schedule_global_tick(pod)
        # Live pod rebalancing records (move_node).
        self._moves: List[PodMove] = []
        self._move_poll_scheduled = False
        # Optional fault injector for the GLOBAL tier's links (per-pod
        # injectors go through set_pod_adversary — pods are Clusters).
        self.global_adversary: Optional[Adversary] = None

    # ----------------------------------------------------------- adversaries

    def set_pod_adversary(self, pod: str, adversary: Optional[Adversary]) -> None:
        """Install (or clear, with None) a message-level fault injector on
        ONE pod's local links — the per-pod blast radius the hierarchy is
        supposed to contain: a pod under adversarial fire may lose local
        availability, but the global tier rides through on its quorums."""
        self.pods[pod].adversary = adversary

    def set_global_adversary(self, adversary: Optional[Adversary]) -> None:
        """Install (or clear) a fault injector on the global tier's links."""
        self.global_adversary = adversary

    # ------------------------------------------------- failure profiles

    def set_failure_profiles(
        self, profiles: Dict[NodeId, FailureProfile]
    ) -> None:
        """Install per-host failure profiles across the hierarchy (host
        ids are pod-qualified, e.g. ``pod0h1``); each pod cluster receives
        its own subset and runs the same deterministic per-node schedule
        machinery as a flat :class:`~repro.core.sim.Cluster`."""
        for local in self.pods.values():
            sub = {n: fp for n, fp in profiles.items() if n in local.nodes}
            if sub:
                local.set_failure_profiles(sub)

    def clear_failure_profiles(self) -> None:
        for local in self.pods.values():
            local.clear_failure_profiles()

    def failure_groups(self) -> Dict[NodeId, str]:
        """host -> correlated-failure group, from the installed profiles."""
        groups: Dict[NodeId, str] = {}
        for local in self.pods.values():
            for nid, fp in local.failure_profiles.items():
                if fp.group:
                    groups[nid] = fp.group
        return groups

    def placement(self) -> Dict[str, List[NodeId]]:
        return {pod: sorted(self.pods[pod].nodes) for pod in self.pod_ids}

    def rebalance_coflaky(self, timeout: float = 240_000.0) -> List[PodMove]:
        """Execute the greedy de-correlation plan (:func:`plan_coflaky_moves`)
        over the CURRENT placement and installed failure profiles, as live
        :meth:`move_node` rebalancings. Returns the issued moves; drive
        them with :meth:`run_until_moved`. No-op (empty list) when no pod
        concentrates a quorum inside one failure group."""
        plan = plan_coflaky_moves(self.placement(), self.failure_groups())
        return [
            self.move_node(nid, src, dst, timeout=timeout)
            for nid, src, dst in plan
        ]

    # --------------------------------------------------------- global plumbing

    def pod_available(self, pod: str) -> bool:
        """A pod's global member is reachable iff the pod has a live leader."""
        return self.pods[pod].leader() is not None

    def _schedule_global_tick(self, pod: str) -> None:
        if self.engine == "legacy":
            def tick():
                n = self.global_nodes[pod]
                if n.alive and self.pod_available(pod):
                    self._global_dispatch(pod, n.on_tick(self.sim.now))
                self._schedule_global_tick(pod)

            self.sim.schedule(self.tick_interval, tick)
            return
        self.sim.schedule_record(self.tick_interval, EV_GTICK, self, pod)

    def _fire_global_tick(self, pod: str) -> None:
        """Slotted-engine global tick (EV_GTICK). Unlike pod-level timers,
        the global member's tick reschedules UNCONDITIONALLY — a member
        whose pod lost its leader (unavailable) keeps its timer alive and
        resumes participating the instant the pod re-elects, with no
        restart hook needed. Firing is gated on liveness AND pod
        availability, exactly like the legacy closure."""
        n = self.global_nodes[pod]
        if n.alive and self.pod_available(pod):
            self._global_dispatch(pod, n.on_tick(self.sim.now))
        sim = self.sim
        heapq.heappush(
            sim._events,
            (sim.now + self.tick_interval, next(sim._seq), EV_GTICK, self, pod),
        )

    def _global_dispatch(self, src: str, outputs: Sequence[Tuple[NodeId, Message]]) -> None:
        for dst, msg in outputs:
            self._global_send(src, dst, msg)

    def _global_send(self, src: str, dst: str, msg: Message) -> None:
        if dst not in self.global_nodes:
            return
        adv = self.global_adversary
        if adv is not None and adv.active(self.sim.now):
            copies = adv.apply(msg, self.global_metrics)
        else:
            copies = [msg]
        for m in copies:
            self._global_transmit(src, dst, m)

    def _global_bytes_accounted(self) -> bool:
        link = self.global_link
        return self.record_bytes or link.bytes_per_ms > 0 or link.mtu_bytes > 0

    def _global_transmit(self, src: str, dst: str, msg: Message) -> None:
        link = self.global_link
        account = self._global_bytes_accounted()
        size = wire_size(msg) if account else 0
        if account:
            self.global_metrics.bytes_sent(src, dst, type(msg).__name__, size)
        if link.loss > 0 and self.sim.rng.random() < min(
            1.0, link.drop_probability(size)
        ):
            self.global_metrics.count("dropped")
            if account:
                self.global_metrics.bytes_dropped(src, dst, type(msg).__name__, size)
            return
        delay = link.sample_latency(self.sim.rng)
        overhead = link.serialization_cost(size)
        if overhead > 0:
            # Same per-directed-link queueing as Cluster._transmit: a fat
            # message occupies the slow inter-pod link proportionally to
            # its size. Skipped entirely at 0 (seed-identical schedules).
            start = max(self.sim.now, self._global_link_busy.get((src, dst), 0.0))
            self._global_link_busy[(src, dst)] = start + overhead
            delay += (start + overhead) - self.sim.now
        if self.engine == "legacy":
            def deliver():
                n = self.global_nodes.get(dst)
                if n is not None and n.alive and self.pod_available(dst):
                    if self._global_bytes_accounted():
                        self.global_metrics.bytes_delivered(
                            src, dst, type(msg).__name__, wire_size(msg)
                        )
                    self._global_dispatch(dst, n.on_message(msg, self.sim.now))

            self.sim.schedule(delay, deliver)
            return
        sim = self.sim
        heapq.heappush(
            sim._events,
            (sim.now + delay, next(sim._seq), EV_GDELIVER, self, src, dst, msg),
        )

    def _global_deliver(self, src: str, dst: str, msg: Message) -> None:
        """Slotted-engine global delivery (EV_GDELIVER): liveness and pod
        availability are evaluated at DELIVERY time, same as the legacy
        closure — a pod that loses its leader mid-flight drops the message."""
        n = self.global_nodes.get(dst)
        if n is not None and n.alive and self.pod_available(dst):
            if self._global_bytes_accounted():
                self.global_metrics.bytes_delivered(
                    src, dst, type(msg).__name__, wire_size(msg)
                )
            self._global_dispatch(dst, n.on_message(msg, self.sim.now))

    # ------------------------------------------------------ down-propagation

    def _make_global_apply(self, pod: str) -> Callable[[int, Entry], None]:
        def on_apply(index: int, entry: Entry) -> None:
            # Globally committed: disseminate into this pod's local log.
            cmd = f"{GLOBAL_SHADOW_PREFIX}{index}:{entry.command}"
            eid = EntryId(f"{pod}-global", index)
            if self.relay_batch_window > 0:
                # Relay batching: buffer the announcement and flush every
                # buffered commit as ONE ordered client batch per window.
                # FIFO is preserved (the buffer is in global apply order and
                # a batch appends in list order); (index, entry_id) dedup at
                # the pod keeps retried/re-announced entries idempotent.
                self._relay_buf.setdefault(pod, []).append((cmd, eid))
                if not self._relay_flush_scheduled.get(pod):
                    self._relay_flush_scheduled[pod] = True
                    self.sim.schedule(
                        self.relay_batch_window, lambda: self._relay_flush(pod)
                    )
                return
            local = self.pods[pod]
            lead = local.leader()
            if lead is not None:
                node = local.nodes[lead]
                local.dispatch(
                    lead, node.client_request(cmd, self.sim.now, entry_id=eid)
                )

        return on_apply

    def _relay_flush(self, pod: str) -> None:
        """Flush one pod's buffered global-commit announcements as a single
        multi-entry client batch. With no live pod leader the flush retries
        a window later (strictly better delivery than the unbatched path,
        which drops announcements made during leaderless spells)."""
        buf = self._relay_buf.get(pod)
        if not buf:
            self._relay_flush_scheduled[pod] = False
            return
        local = self.pods[pod]
        lead = local.leader()
        if lead is None:
            self.sim.schedule(self.relay_batch_window,
                              lambda: self._relay_flush(pod))
            return
        self._relay_buf[pod] = []
        self._relay_flush_scheduled[pod] = False
        node = local.nodes[lead]
        local.dispatch(lead, node.client_request_batch(buf, self.sim.now))
        self.global_metrics.count("relay_batches")
        self.global_metrics.count("relay_batched_entries", len(buf))

    def _pod_sm_factory(self, pod: str) -> Callable[[NodeId], StateMachine]:
        """Factory wrapping each host's machine with shadow-entry delivery.
        First local apply wins per (index, entry_id) across the pod."""

        def on_shadow(index: int, entry: Entry, _pod=pod) -> None:
            key = (index, str(entry.entry_id))
            if key in self._delivered_keys[_pod]:
                return
            self._delivered_keys[_pod].add(key)
            cmd = entry.command
            self.delivered[_pod].append(cmd[len(GLOBAL_SHADOW_PREFIX):])

        def factory(nid: NodeId) -> StateMachine:
            inner = (
                self._base_sm_factory(nid)
                if self._base_sm_factory is not None
                else LogListMachine()
            )
            return ShadowDeliveryMachine(inner, on_shadow)

        return factory

    # ------------------------------------------------------------- workload

    def bootstrap(self, max_time: float = 20_000.0) -> None:
        """Run until every pod has a local leader and the global tier elected."""

        def ready() -> bool:
            return all(self.pods[p].leader() is not None for p in self.pod_ids) and (
                self.global_leader() is not None
            )

        self.sim.run_until(self.sim.now + max_time, stop=ready)
        assert ready(), "hierarchy failed to bootstrap"

    def global_leader(self) -> Optional[str]:
        leaders = [
            pod
            for pod, n in self.global_nodes.items()
            if n.alive and n.role.value == "leader" and self.pod_available(pod)
        ]
        if not leaders:
            return None
        return max(leaders, key=lambda p: self.global_nodes[p].term)

    def read_pod(
        self,
        pod: str,
        query: Any,
        via_host: Optional[NodeId] = None,
        mode: str = "leader",
        max_staleness_ms: float = 0.0,
        retry_ms: Optional[float] = None,
    ) -> EntryId:
        """Read served entirely INSIDE one pod: the query rides the pod's
        local read path over fast intra-pod links and never touches the
        global tier — the CD-Raft cross-domain-read economy (cross-domain
        messages stay reserved for global commits). Local-tier
        linearizability is exactly what the paper's hierarchy offers: the
        pod's log IS the authority for pod-local state, including
        down-propagated global shadow entries the pod has committed.

        ``mode="leader"`` terminates at the pod leader (ReadIndex/lease);
        ``mode="replica"`` serves at a follower or learner from the pod
        leader's certified watermark — with no ``via_host`` the read fans
        out across the pod's non-leader replicas (learners first: they are
        exactly the cheap read capacity ``add_pod_host``-style growth
        buys, holding full state but costing no quorum). ``via_host``
        naming a host the pod no longer has raises
        :class:`~repro.core.sim.MembershipError`; a crashed host fails the
        read fast unless ``retry_ms`` enables client-side failover.
        Returns the pod cluster's read id; the result lands in
        ``self.pods[pod].reads``."""
        local = self.pods[pod]
        if via_host is None and mode == "replica":
            via_host = self._pick_replica_host(pod)
        return local.read(
            query, via=via_host, mode=mode,
            max_staleness_ms=max_staleness_ms, retry_ms=retry_ms,
        )

    def _pick_replica_host(self, pod: str) -> Optional[NodeId]:
        """Round-robin read fan-out target inside a pod: live learners
        first (read capacity with zero quorum cost), then live followers,
        then whatever is left (the leader also serves replica reads)."""
        local = self.pods[pod]
        counter = self._replica_rr.get(pod, 0)
        self._replica_rr[pod] = counter + 1
        learners, followers, rest = [], [], []
        for nid in sorted(local.nodes):
            node = local.nodes[nid]
            if not node.alive:
                continue
            if node.cluster_config.is_witness(nid):
                continue  # quorum-only member: no state machine to read
            if node.cluster_config.is_learner(nid):
                learners.append(nid)
            elif node.role.value != "leader":
                followers.append(nid)
            else:
                rest.append(nid)
        pool = learners or followers or rest
        if not pool:
            return None  # every host down; Cluster.read fails it fast
        return pool[counter % len(pool)]

    def run_until_pod_reads(
        self, pod: str, read_ids, max_time: float = 30_000.0
    ) -> bool:
        return self.pods[pod].run_until_reads(read_ids, max_time)

    def propose_global(self, command: Any, via_pod: Optional[str] = None) -> EntryId:
        via_pod = via_pod or self.pod_ids[0]
        n = self.global_nodes[via_pod]
        eid = EntryId(via_pod, n.next_seq())
        self._global_dispatch(via_pod, n.client_request(command, self.sim.now, entry_id=eid))
        return eid

    def run(self, duration: float, stop=None) -> None:
        self.sim.run_until(self.sim.now + duration, stop)

    def run_until_globally_committed(
        self, entry_ids: Sequence[EntryId], max_time: float = 30_000.0
    ) -> bool:
        if self.engine == "legacy":
            def done() -> bool:
                return all(
                    self.global_metrics.traces.get(e) is not None
                    and self.global_metrics.traces[e].committed
                    for e in entry_ids
                )

            self.sim.run_until(self.sim.now + max_time, stop=done)
            return done()
        # Event-driven: the global Recorder drains the pending set as each
        # entry first commits, so the periodic stop check is O(1). No early
        # return when pending starts empty — the scan-based engine still ran
        # up to check_every events before its first stop check, and skipping
        # them would fork the schedule.
        pending = {
            e
            for e in entry_ids
            if not (
                (t := self.global_metrics.traces.get(e)) is not None and t.committed
            )
        }
        self.global_metrics.watch_commits(pending)
        try:
            self.sim.run_until(self.sim.now + max_time, stop=lambda: not pending)
        finally:
            self.global_metrics.unwatch_commits(pending)
        return not pending

    def run_until_delivered(self, n_cmds: int, max_time: float = 60_000.0) -> bool:
        def done() -> bool:
            return all(len(self.delivered[p]) >= n_cmds for p in self.pod_ids)

        self.sim.run_until(self.sim.now + max_time, stop=done)
        return done()

    # ------------------------------------------------------ pod rebalancing

    def move_node(
        self, nid: NodeId, from_pod: str, to_pod: str, timeout: float = 240_000.0
    ) -> PodMove:
        """Live pod rebalancing: move host ``nid`` from one pod to the
        other WITHOUT any global-tier traffic — both sides are ordinary
        pod-local membership changes (CD-Raft's cross-domain economy: the
        global tier never hears about host placement, only pod identities).

        Three phases, each riding the same config machinery as flat
        clusters: (1) joint-consensus removal from the source pod, (2)
        join the destination pod as a LEARNER and catch up on its state
        via the pipelined chunked snapshot path, (3) joint-consensus
        promotion to voter. The move survives pod-leader churn on either
        side (membership ops retry) and fails explicitly at ``timeout``.
        """
        assert from_pod in self.pods and to_pod in self.pods
        assert nid in self.pods[from_pod].nodes, f"{nid} not in {from_pod}"
        assert nid not in self.pods[to_pod].nodes, f"{nid} already in {to_pod}"
        rm = self.pods[from_pod].remove_node(nid, pop=True, timeout=timeout)
        move = PodMove(nid, from_pod, to_pod, deadline=self.sim.now + timeout,
                       ops=[rm])
        self._moves.append(move)
        if not self._move_poll_scheduled:
            self._move_poll_scheduled = True
            self._schedule_move_poll()
        return move

    def _schedule_move_poll(self) -> None:
        def poll():
            for move in self._moves:
                self._advance_move(move)
            self._moves = [m for m in self._moves if not (m.done or m.failed)]
            if self._moves:
                self.sim.schedule(self.tick_interval, poll)
            else:
                self._move_poll_scheduled = False

        self.sim.schedule(self.tick_interval, poll)

    def _advance_move(self, move: PodMove) -> None:
        src, dst = self.pods[move.src_pod], self.pods[move.dst_pod]
        if self.sim.now >= move.deadline:
            move.stage, move.error = "failed", f"pod move timed out in {move.stage}"
            return
        # Failure is judged on THIS move's own ops only — and consumed, so
        # unrelated (or long-finished) churn in either pod can neither fail
        # the move nor leak a stale error into later moves.
        failed_ops = [o for o in move.ops if o.failed]
        if failed_ops:
            move.stage = "failed"
            move.error = "; ".join(f"{o.kind}({o.nid}): {o.error}" for o in failed_ops)
            for pod in (src, dst):
                pod.membership_failures = [
                    o for o in pod.membership_failures if o not in failed_ops
                ]
            return
        if move.stage == "removing" and move.nid not in src.nodes:
            # Removal committed and the host left the source pod: join the
            # destination as a learner (fresh state machine from the
            # destination's factory — it learns dst state via snapshot,
            # carrying nothing over), then promote once caught up.
            move.ops.append(
                dst.add_learner(move.nid, timeout=move.deadline - self.sim.now)
            )
            move.ops.append(
                dst.promote(move.nid, timeout=move.deadline - self.sim.now)
            )
            move.stage = "joining"
        elif move.stage == "joining":
            cfg = dst._committed_config()
            if not cfg.joint and move.nid in cfg.voters:
                move.stage = "done"

    def run_until_moved(self, max_time: float = 240_000.0) -> bool:
        """Run until every in-flight pod move completed; raises
        :class:`repro.core.sim.MembershipError` on explicit failure."""

        def done() -> bool:
            return not self._moves

        orig = list(self._moves)
        self.sim.run_until(self.sim.now + max_time, stop=done)
        failed = [m for m in orig if m.failed]
        if failed:
            raise MembershipError(
                "; ".join(f"move({m.nid} {m.src_pod}->{m.dst_pod}): {m.error}"
                          for m in failed)
            )
        return not self._moves

    # ----------------------------------------------------------------- chaos

    def crash_pod_leader(self, pod: str) -> Optional[str]:
        lead = self.pods[pod].leader()
        if lead is not None:
            self.pods[pod].crash(lead)
        return lead

    def isolate_pod_host(self, pod: str, host: NodeId) -> None:
        """Chaos hook: partition one host away from the rest of its pod
        (e.g. so the pod leader compacts past it and catch-up must go
        through InstallSnapshot once healed)."""
        others = [h for h in self.pods[pod].nodes if h != host]
        self.pods[pod].partition([host], others)

    def heal_pod_hosts(self, pod: str) -> None:
        self.pods[pod].heal()

    def compact_pod(self, pod: str) -> None:
        """Chaos hook: force every live host in the pod to compact its
        applied prefix right now (snapshot-during-partition scenarios)."""
        for node in self.pods[pod].nodes.values():
            if node.alive:
                node.compact()

    def partition_pod(self, pod: str) -> None:
        """Cut the pod's global member off (simulates inter-pod link failure)
        by marking its global node dead to the network via 100% loss."""
        self.global_nodes[pod].alive = False

    def heal_pod(self, pod: str) -> None:
        self.global_nodes[pod].alive = True
        self.global_nodes[pod].restart(self.sim.now)

    def check_consistency(self) -> None:
        for pod in self.pod_ids:
            self.pods[pod].check_log_consistency()
        # Global delivered sequences must be prefix-compatible across pods.
        seqs = list(self.delivered.values())
        for i in range(len(seqs)):
            for j in range(i + 1, len(seqs)):
                a, b = seqs[i], seqs[j]
                k = min(len(a), len(b))
                assert a[:k] == b[:k], f"global delivery divergence: {a[:k]} vs {b[:k]}"
