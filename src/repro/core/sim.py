"""Deterministic discrete-event network simulator for consensus clusters.

Reproduces the paper's experimental methodology — EKS pods with Linux ``tc``
random packet loss / delay, crash failures by killing pods — as a seeded
simulation so every schedule is replayable in CI and explorable by
hypothesis.

Model:
- Each directed link (src, dst) drops a message with probability ``loss``
  and otherwise delivers after ``base_latency + U(0, jitter)``.
- Partitions block links across group boundaries entirely (tc blackhole).
- Crash failures stop a node from receiving/sending; restart preserves its
  persistent state (term, voted_for, log, tentative overlay).
- Nodes are ticked every ``tick_interval`` sim-ms; all protocol timeouts are
  evaluated against sim time only.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import random
import zlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Type

from repro.core.metrics import Recorder
from repro.core.raft import RaftConfig, RaftNode
from repro.core.fast_raft import FastRaftNode
from repro.core.statemachine import StateMachine
from repro.core.types import (
    AppendEntriesArgs,
    ClusterConfig,
    EntryId,
    FastFinalize,
    FastPropose,
    FastVote,
    ForwardOperation,
    InstallSnapshotArgs,
    InstallSnapshotChunk,
    Message,
    NodeId,
    ReadQuery,
    ReadReply,
)


class MembershipError(RuntimeError):
    """A membership operation failed explicitly (timed out waiting for a
    leader, for learner catch-up, or for its config change to commit)."""


@dataclasses.dataclass
class MembershipOp:
    """One queued membership operation. Ops are serialized per cluster (the
    at-most-one-config-change rule makes concurrent ops pointless) and are
    retried automatically: a proposal lost to leader churn is re-proposed
    against the new leader until the op's ``deadline`` — after which the op
    FAILS explicitly (surfaced by :meth:`Cluster.run_until_membership`)
    instead of silently doing nothing.

    kind: "learner"  — add ``nid`` as a non-voting learner
          "promote"  — promote caught-up learner ``nid`` to voter (joint)
          "remove"   — remove ``nid`` from voters+learners (joint)
          "swap"     — atomically replace voter ``nid`` with caught-up
                       learner ``new`` (one joint change)
    """

    kind: str
    nid: NodeId
    new: NodeId = ""
    deadline: float = 0.0
    pop: bool = False  # drop the removed node object from the cluster dict
    state: str = "queued"  # queued -> done | failed
    error: str = ""

    @property
    def done(self) -> bool:
        return self.state == "done"

    @property
    def failed(self) -> bool:
        return self.state == "failed"

class Adversary:
    """Seeded message-level fault injector, consulted by :meth:`Cluster.send`
    for every message while active (``now < until``).

    Effects, each drawn independently from the adversary's OWN RNG stream
    (never ``sim.rng`` — installing an adversary must not perturb the
    deterministic schedule of the traffic it leaves alone):

    - ``drop_p``       — the message vanishes (on top of link loss);
    - ``dup_p``        — the message is delivered twice, with independent
                         latency draws (classic network duplication);
    - ``corrupt_p``    — payload corruption. Only DETECTABLE corruption is
                         modeled (the protocol is crash-fault, not
                         Byzantine): an ``InstallSnapshotChunk`` has a byte
                         flipped in a COPY (its ``data_crc`` no longer
                         matches, so the receiver discards it like loss);
                         every other message type is dropped outright, as a
                         frame that failed its transport checksum.

    Fuzzer ops install/replace an adversary on a :class:`Cluster` (or a
    single pod of a hierarchy) for a bounded window; ``None`` disables it.
    """

    def __init__(
        self,
        seed: int = 0,
        drop_p: float = 0.0,
        dup_p: float = 0.0,
        corrupt_p: float = 0.0,
        until: float = math.inf,
    ):
        self.rng = random.Random(zlib.crc32(b"adversary") ^ (seed * 2654435761 % 2**32))
        self.drop_p = drop_p
        self.dup_p = dup_p
        self.corrupt_p = corrupt_p
        self.until = until

    def active(self, now: float) -> bool:
        return now < self.until

    def apply(self, msg: Message, metrics: Recorder) -> List[Message]:
        """The message copies to actually transmit (possibly empty)."""
        if self.drop_p > 0 and self.rng.random() < self.drop_p:
            metrics.count("adv_dropped")
            return []
        if self.corrupt_p > 0 and self.rng.random() < self.corrupt_p:
            if isinstance(msg, InstallSnapshotChunk) and msg.data:
                # Never mutate in place: broadcast handlers share one
                # message object across peers.
                flipped = bytearray(msg.data)
                flipped[self.rng.randrange(len(flipped))] ^= 0xFF
                msg = dataclasses.replace(msg, data=bytes(flipped))
                metrics.count("adv_corrupted")
            else:
                metrics.count("adv_corrupt_dropped")
                return []
        if self.dup_p > 0 and self.rng.random() < self.dup_p:
            metrics.count("adv_duplicated")
            return [msg, msg]
        return [msg]


@dataclasses.dataclass
class FailureProfile:
    """Per-node unreliability model for heterogeneous ("flaky") fleets.

    Crash/recover behavior is a renewal process: a node stays up for
    Exp(mean=``mtbf_ms``) then down for Exp(mean=``mttr_ms``), repeating
    while the profile is installed (``mtbf_ms == 0`` never crashes).
    ``apply_lag_ms`` models a slow CPU: commit acknowledgement is
    unaffected (replication is a network fact) but the node's state
    machine trails its commit point by the lag (RaftConfig.apply_lag_ms).
    The four multipliers compose per DIRECTED link — src's outbound times
    dst's inbound — so asymmetric paths (fine uplink, terrible downlink)
    are expressible; they scale the base LinkModel, so a lossless network
    stays lossless (multiplier semantics, not additive).

    ``group`` names a correlated-failure domain (rack, AZ, spot pool):
    :meth:`Cluster.crash_group` fells a whole group at once, and the
    hierarchy placement policy (repro.core.hierarchy.rebalance_coflaky)
    avoids concentrating any quorum inside one group.

    Determinism contract: each node's crash/recover schedule is drawn
    from a DEDICATED per-node RNG stream keyed by (cluster seed, node
    id) — never ``sim.rng`` — so the failure schedule is identical
    across protocol variants run on the same seed. That is what makes
    "weighted vs unweighted elections under the same failure schedule"
    a controlled comparison (benchmarks/unreliable_scaleout.py).
    """

    mtbf_ms: float = 0.0       # mean up-time between crashes (0 = stable)
    mttr_ms: float = 1000.0    # mean down-time per crash
    apply_lag_ms: float = 0.0  # state-machine lag behind commit
    loss_mult: float = 1.0     # outbound loss multiplier
    latency_mult: float = 1.0  # outbound latency multiplier
    in_loss_mult: float = 1.0  # inbound loss multiplier
    in_latency_mult: float = 1.0  # inbound latency multiplier
    group: str = ""            # correlated-failure domain


# Rough fixed per-message framing cost (headers, term/id fields) for the
# size-aware network model; only relative sizes matter.
_MSG_BASE_BYTES = 64
_ENTRY_BASE_BYTES = 24


def _entry_bytes(entry) -> int:
    return _ENTRY_BASE_BYTES + len(str(entry.command))


def wire_size(msg: Message) -> int:
    """Approximate serialized size of a message in bytes.

    Drives the size-aware pieces of :class:`LinkModel` (``bytes_per_ms``
    transmission time and ``mtu_bytes`` per-packet loss). Entry-bearing
    messages scale with their payload; a monolithic InstallSnapshot pays for
    the whole serialized snapshot, a chunk only for its slice."""
    if isinstance(msg, AppendEntriesArgs):
        return _MSG_BASE_BYTES + sum(_entry_bytes(s.entry) for s in msg.entries)
    if isinstance(msg, InstallSnapshotChunk):
        return _MSG_BASE_BYTES + len(msg.data)
    if isinstance(msg, InstallSnapshotArgs):
        size = msg.snapshot.size_bytes() if msg.snapshot is not None else 0
        return _MSG_BASE_BYTES + size
    if isinstance(msg, (FastPropose, FastFinalize)):
        entries = list(msg.window) or ([msg.entry] if msg.entry else [])
        return _MSG_BASE_BYTES + sum(_entry_bytes(e) for e in entries)
    if isinstance(msg, FastVote):
        # A vote is (index, entry_id) — id-sized, no payload. The head vote
        # rides the base; piggybacked multi_votes (ack_piggyback) pay per
        # folded vote so folding N votes is still far cheaper than N
        # messages (N * _MSG_BASE_BYTES) but never free. Zero when the
        # knob is off — the pre-piggyback byte stream is unchanged.
        return _MSG_BASE_BYTES + 16 * len(msg.multi_votes)
    if isinstance(msg, ForwardOperation):
        n = _entry_bytes_cmd(msg.command) + sum(
            _entry_bytes_cmd(c) for c, _ in msg.batch
        )
        return _MSG_BASE_BYTES + n
    if isinstance(msg, ReadQuery):
        return _MSG_BASE_BYTES + len(str(msg.query))
    if isinstance(msg, ReadReply):
        return (
            _MSG_BASE_BYTES
            + len(str(msg.value))
            + sum(8 + len(str(v)) for _, v in msg.batch)
        )
    return _MSG_BASE_BYTES


def _entry_bytes_cmd(command) -> int:
    return _ENTRY_BASE_BYTES + len(str(command))


# Slotted event kinds. Events are plain tuples on one global heap:
#   (time, seq, kind, *payload)
# ordered by (time, seq) exactly as the closure-era heap was — seq is unique,
# so comparison never reaches the heterogeneous payload. Typed records
# replace the per-event closure allocation that used to dominate the hot
# path: a message hop is (t, seq, EV_DELIVER, cluster, src, dst, msg) and a
# node tick is (t, seq, EV_TICK, cluster, nid), both dispatched by run_until
# without creating (or calling through) a Python closure. EV_CLOSURE keeps
# Simulation.schedule() working for arbitrary callbacks (membership polls,
# read failover loops, tests); kinds are ordered by observed frequency.
# To add a new event type: allocate a constant here, push the tuple with
# its payload, and add a dispatch arm in Simulation.run_until (see
# DESIGN.md section 11).
EV_CLOSURE = 0   # (fn,)                 -> fn()
EV_DELIVER = 1   # (cluster, src, dst, msg) -> cluster._deliver(...)
EV_TICK = 2      # (cluster, nid)        -> cluster._fire_tick(nid)
EV_GDELIVER = 3  # (hier, src, dst, msg) -> hier._global_deliver(...)
EV_GTICK = 4     # (hier, pod)           -> hier._fire_global_tick(pod)


class Simulation:
    """Seeded event loop: (time, seq) ordering makes runs fully deterministic.

    ``events`` counts retired events across the run — the numerator of the
    simulated-events/sec throughput number benchmarks/sim_speed.py tracks.
    """

    def __init__(self, seed: int = 0):
        self.now = 0.0
        self.rng = random.Random(seed)
        self._events: List[Tuple] = []
        self._seq = itertools.count()
        self.events = 0

    def schedule(self, delay: float, fn: Callable[[], None]) -> None:
        heapq.heappush(
            self._events, (self.now + delay, next(self._seq), EV_CLOSURE, fn)
        )

    def schedule_record(self, delay: float, kind: int, *payload) -> None:
        """Schedule a typed (closure-free) event record."""
        heapq.heappush(
            self._events, (self.now + delay, next(self._seq), kind) + payload
        )

    def run_until(
        self, t_max: float, stop: Optional[Callable[[], bool]] = None, check_every: int = 32
    ) -> None:
        n = 0
        events = self._events
        pop = heapq.heappop
        while events and events[0][0] <= t_max:
            ev = pop(events)
            t = ev[0]
            if t > self.now:
                self.now = t
            kind = ev[2]
            if kind == EV_DELIVER:
                ev[3]._deliver(ev[4], ev[5], ev[6])
            elif kind == EV_TICK:
                ev[3]._fire_tick(ev[4])
            elif kind == EV_CLOSURE:
                ev[3]()
            elif kind == EV_GDELIVER:
                ev[3]._global_deliver(ev[4], ev[5], ev[6])
            else:  # EV_GTICK
                ev[3]._fire_global_tick(ev[4])
            n += 1
            if stop is not None and n % check_every == 0 and stop():
                self.events += n
                return
        self.events += n
        self.now = max(self.now, t_max) if not self._events else self.now


class LinkModel:
    """Directed-link model: drop probability, propagation delay, and an
    optional SIZE-AWARE serialization/loss model.

    ``msg_overhead`` models the fixed per-RPC cost (syscall, marshalling,
    NIC serialization): each message occupies the link for that long before
    the next one may start, so N unbatched RPCs queue behind each other
    while one N-entry batch pays the cost once.

    ``bytes_per_ms`` adds transmission time proportional to
    :func:`wire_size` (link bandwidth): big messages — a monolithic
    InstallSnapshot above all — occupy the link longer than small ones.

    ``mtu_bytes`` makes LOSS size-aware: a message of S bytes is ceil(S/mtu)
    packets, and it is delivered only if every packet survives, i.e. it
    drops with probability 1-(1-loss)^packets. This is the regime where
    chunked snapshot transfer beats monolithic: one huge message virtually
    never survives a lossy link, while chunks sized near the MTU do.

    All three default to 0.0, which reproduces the seed's pure-latency,
    per-message-loss network exactly."""

    def __init__(self, loss: float = 0.0, base_latency: float = 5.0, jitter: float = 0.0,
                 msg_overhead: float = 0.0, bytes_per_ms: float = 0.0,
                 mtu_bytes: float = 0.0):
        self.loss = loss
        self.base_latency = base_latency
        self.jitter = jitter
        self.msg_overhead = msg_overhead
        self.bytes_per_ms = bytes_per_ms
        self.mtu_bytes = mtu_bytes

    def sample_latency(self, rng: random.Random) -> float:
        return self.base_latency + (rng.uniform(0.0, self.jitter) if self.jitter else 0.0)

    def drop_probability(self, size: int) -> float:
        if self.loss <= 0:
            return 0.0
        if self.mtu_bytes > 0:
            packets = max(1, math.ceil(size / self.mtu_bytes))
            return 1.0 - (1.0 - self.loss) ** packets
        return self.loss

    def serialization_cost(self, size: int) -> float:
        cost = self.msg_overhead
        if self.bytes_per_ms > 0:
            cost += size / self.bytes_per_ms
        return cost


class VectorLinkRNG:
    """Batched per-(src, dst) uniform streams for the vectorized link model
    (``Cluster(link_rng="vectorized")``).

    Determinism contract: the i-th uniform consumed on directed link
    (src, dst) depends ONLY on (seed, src, dst, i) — never on traffic on
    other links, on cluster size, or on wall-clock interleaving. Draws are
    generated a block at a time (one backend call per ``block`` draws per
    link) instead of one scalar ``random.Random`` call per message; block i
    of a pair's stream is seeded from (seed, crc32(src->dst), i), so streams
    are reproducible and extendable without re-generating prefixes. The
    block size is part of the stream definition and therefore fixed.

    Backends: "numpy" (default when importable), "jax" (same contract via
    fold_in-keyed uniforms, useful when the surrounding experiment already
    lives on an accelerator), "python" (pure-Python fallback, no deps).
    Note this mode is deterministic per seed but intentionally NOT
    draw-for-draw identical to the default shared-``sim.rng`` stream: the
    shared stream interleaves all links into one sequence, which is exactly
    the coupling the per-link contract removes. Schedule-equivalence
    guarantees apply to the default mode; vectorized runs are a separate,
    self-consistent family of schedules."""

    def __init__(self, seed: int = 0, block: int = 512, backend: str = "auto"):
        self.seed = seed
        self.block = block
        if backend == "auto":
            try:
                import numpy  # noqa: F401
                backend = "numpy"
            except ImportError:  # pragma: no cover - numpy is normally present
                backend = "python"
        self.backend = backend
        # (src, dst) -> [buffer, cursor, next_block_index]
        self._streams: Dict[Tuple[NodeId, NodeId], list] = {}

    def _gen_block(self, src: NodeId, dst: NodeId, block_index: int):
        pair_key = zlib.crc32(f"{src}->{dst}".encode())
        if self.backend == "numpy":
            import numpy as np

            gen = np.random.default_rng([self.seed, pair_key, block_index])
            return gen.random(self.block).tolist()
        if self.backend == "jax":
            import jax

            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(self.seed), pair_key),
                block_index,
            )
            return [float(u) for u in jax.random.uniform(key, (self.block,))]
        r = random.Random((self.seed, pair_key, block_index))
        return [r.random() for _ in range(self.block)]

    def next(self, src: NodeId, dst: NodeId) -> float:
        st = self._streams.get((src, dst))
        if st is None:
            st = [self._gen_block(src, dst, 0), 0, 1]
            self._streams[(src, dst)] = st
        elif st[1] >= self.block:
            st[0] = self._gen_block(src, dst, st[2])
            st[1] = 0
            st[2] += 1
        u = st[0][st[1]]
        st[1] += 1
        return u


class Cluster:
    """N consensus nodes over a lossy simulated network.

    protocol: "raft" | "fastraft"
    engine:   "slotted" (default) — typed event records, closure-free hot
              path, incremental quorum bookkeeping in the nodes. "legacy" —
              the pre-optimization closure engine and node-level slow
              paths, kept as the benchmark/equivalence baseline. Both
              produce BYTE-IDENTICAL schedules for identical seeds (gated
              by tests/test_sim_equivalence.py); legacy only reproduces the
              old CPU cost profile.
    link_rng: "shared" (default) — per-message scalar draws from the one
              sim.rng stream, exactly the seed-era network. "vectorized" —
              batched per-(src, dst) uniform streams (VectorLinkRNG):
              deterministic per seed, draws decoupled across links, one
              backend call per block instead of one RNG call per message.
    """

    def __init__(
        self,
        n: int = 3,
        protocol: str = "fastraft",
        seed: int = 0,
        loss: float = 0.0,
        base_latency: float = 5.0,
        jitter: float = 0.0,
        msg_overhead: float = 0.0,
        bytes_per_ms: float = 0.0,
        mtu_bytes: float = 0.0,
        config: Optional[RaftConfig] = None,
        tick_interval: float = 10.0,
        node_prefix: str = "n",
        sim: Optional[Simulation] = None,
        snapshot_store=None,
        state_machine_factory: Optional[Callable[[NodeId], StateMachine]] = None,
        clock_skew_ms: float = 0.0,
        clock_drift: float = 0.0,
        engine: str = "slotted",
        link_rng: str = "shared",
        link_rng_backend: str = "auto",
        witnesses: Sequence[NodeId] = (),
        record_bytes: bool = False,
    ):
        if engine not in ("slotted", "legacy"):
            raise ValueError(f"unknown engine {engine!r}")
        if link_rng not in ("shared", "vectorized"):
            raise ValueError(f"unknown link_rng {link_rng!r}")
        self.engine = engine
        self._vec_rng = (
            VectorLinkRNG(seed, backend=link_rng_backend)
            if link_rng == "vectorized"
            else None
        )
        self.sim = sim or Simulation(seed)
        self.link = LinkModel(loss, base_latency, jitter, msg_overhead,
                              bytes_per_ms, mtu_bytes)
        # Wire accounting (Recorder.link_bytes) is always on for size-aware
        # links, where wire_size is computed anyway; record_bytes=True also
        # accounts on pure-latency links (an extra wire_size per message —
        # observational only, never a schedule change).
        self.record_bytes = record_bytes
        self.link_overrides: Dict[Tuple[NodeId, NodeId], LinkModel] = {}
        self._link_busy: Dict[Tuple[NodeId, NodeId], float] = {}
        self.blocked: set = set()  # directed (src, dst) pairs
        self.metrics = Recorder()
        self.tick_interval = tick_interval
        self.config = config or RaftConfig()
        self.protocol = protocol
        self.seed = seed
        # Optional checkpoint.SnapshotStore: compaction snapshots persist
        # through it and restart_from_store() restores a node from disk.
        self.snapshot_store = snapshot_store
        # Pluggable state machine: one fresh instance per node (None =
        # LogListMachine, the seed-identical default).
        self.state_machine_factory = state_machine_factory
        self._replacements: Dict[NodeId, int] = {}
        # Skewed per-node clocks for the lease safety story: each node's
        # wall clock is offset by U(-clock_skew_ms, clock_skew_ms) and runs
        # at rate 1 + U(-clock_drift, clock_drift). Constant offsets cancel
        # out of lease-duration arithmetic; RATE drift is the hazard
        # RaftConfig.clock_skew_ms must cover. Both default to 0 (seed
        # behavior, perfectly synchronized clocks).
        self.clock_skew_ms = clock_skew_ms
        self.clock_drift = clock_drift
        # Linearizable read records: read_id -> {query, via, issued_at,
        # ok, value, served_index, completed_at}. Populated by read() and
        # completed through the nodes' read_done_fn.
        self.reads: Dict[EntryId, Dict] = {}
        self._read_counter = 0
        # Read watchers (same shape as Recorder.commit_watchers): sets of
        # read ids drained as their reads complete, so run_until_reads'
        # stop predicate is an O(1) emptiness check.
        self._read_watchers: List[set] = []
        # Optional message-level fault injector (fuzzer hook); None =
        # transparent transport, exactly the seed behavior.
        self.adversary: Optional[Adversary] = None
        # Per-node failure profiles (empty dict = perfectly reliable fleet,
        # exactly the seed behavior). Installed via set_failure_profiles;
        # _fp_gen invalidates scheduled crash/recover events on clear.
        self.failure_profiles: Dict[NodeId, FailureProfile] = {}
        self._fp_gen = 0
        self._fp_rngs: Dict[NodeId, random.Random] = {}
        # Membership operation queue (serialized; see MembershipOp).
        self._mops: List[MembershipOp] = []
        self._mop_poll_scheduled = False
        self.membership_failures: List[MembershipOp] = []

        ids = [f"{node_prefix}{i}" for i in range(n)]
        # Witness members (quorum-only voters, see ClusterConfig): named
        # founding nodes join with the marker set from slot one. Empty
        # tuple (the default) builds the seed-identical all-voter config.
        wits = tuple(sorted(set(witnesses)))
        bad = set(wits) - set(ids)
        if bad:
            raise ValueError(f"witnesses not in cluster: {sorted(bad)}")
        init_cfg = ClusterConfig.of(ids, witnesses=wits) if wits else None
        self.nodes: Dict[NodeId, RaftNode] = {}
        for i, nid in enumerate(ids):
            self.nodes[nid] = self._make_node(
                nid, ids, seed * 1000 + i, cluster_config=init_cfg
            )
        for node in self.nodes.values():
            node.start(self.sim.now)
            self._schedule_tick(node.id)

    def _make_node(
        self, nid: NodeId, members, seed: int, cluster_config=None
    ) -> RaftNode:
        """Construct a node wired exactly like the initial fleet: metrics,
        a fresh state machine from the factory, and — when a snapshot store
        is configured — the persistence sinks (joiners and replacements must
        persist too, not only the founding nodes)."""
        cls: Type[RaftNode] = FastRaftNode if self.protocol == "fastraft" else RaftNode
        sm = (
            self.state_machine_factory(nid)
            if self.state_machine_factory is not None
            else None
        )
        node = cls(nid, list(members), config=RaftConfig(**vars(self.config)),
                   seed=seed, state_machine=sm, cluster_config=cluster_config)
        node._legacy_mode = self.engine == "legacy"
        node.metrics = self.metrics
        node.read_done_fn = self._read_completed
        if self.clock_skew_ms > 0 or self.clock_drift > 0:
            # Separate RNG stream: drawing from node.rng would perturb the
            # election-timeout schedule of every seed-default test.
            r = random.Random(zlib.crc32(nid.encode()) ^ (seed * 7 + 13))
            node.clock_offset = r.uniform(-self.clock_skew_ms, self.clock_skew_ms)
            node.clock_drift = r.uniform(-self.clock_drift, self.clock_drift)
        if self.snapshot_store is not None:
            node.snapshot_sink = self.snapshot_store.save
            node.hard_state_sink = self.snapshot_store.save_hard_state
        return node

    # ------------------------------------------------------------ plumbing

    def _schedule_tick(self, nid: NodeId) -> None:
        if self.engine == "legacy":
            def tick():
                node = self.nodes.get(nid)
                if node is not None:
                    if node.alive:
                        self.dispatch(nid, node.on_tick(self.sim.now))
                    self._schedule_tick(nid)

            self.sim.schedule(self.tick_interval, tick)
            return
        self.sim.schedule_record(self.tick_interval, EV_TICK, self, nid)

    def _fire_tick(self, nid: NodeId) -> None:
        """Slotted-engine tick event: semantically identical to the legacy
        tick closure — looking the node up by id at FIRE time is the timer
        cancellation (crashed-and-popped or replaced nodes simply miss),
        and a dead-but-present node keeps its timer ticking so restart
        needs no rescheduling."""
        node = self.nodes.get(nid)
        if node is not None:
            if node.alive:
                self.dispatch(nid, node.on_tick(self.sim.now))
            sim = self.sim
            heapq.heappush(
                sim._events,
                (sim.now + self.tick_interval, next(sim._seq), EV_TICK, self, nid),
            )

    def _link_for(self, src: NodeId, dst: NodeId) -> LinkModel:
        return self.link_overrides.get((src, dst), self.link)

    def _bytes_accounted(self, src: NodeId, dst: NodeId) -> bool:
        if self.record_bytes:
            return True
        link = self.link_overrides.get((src, dst), self.link)
        return link.bytes_per_ms > 0 or link.mtu_bytes > 0

    def dispatch(self, src: NodeId, outputs: Sequence[Tuple[NodeId, Message]]) -> None:
        for dst, msg in outputs:
            self.send(src, dst, msg)

    def send(self, src: NodeId, dst: NodeId, msg: Message) -> None:
        if (src, dst) in self.blocked:
            return
        if dst not in self.nodes:
            return
        adv = self.adversary
        if adv is not None and adv.active(self.sim.now):
            for copy_ in adv.apply(msg, self.metrics):
                self._transmit(src, dst, copy_)
            return
        self._transmit(src, dst, msg)

    def _transmit(self, src: NodeId, dst: NodeId, msg: Message) -> None:
        link = self._link_for(src, dst)
        size_aware = link.bytes_per_ms > 0 or link.mtu_bytes > 0
        account = size_aware or self.record_bytes
        size = wire_size(msg) if account else 0
        if account:
            self.metrics.bytes_sent(src, dst, type(msg).__name__, size)
        # Failure-profile link multipliers compose per DIRECTED link:
        # src's outbound times dst's inbound. Multiplicative, so a
        # lossless base network stays lossless and the RNG draw gating
        # below (no draw when link.loss == 0) — and therefore the
        # schedule — is untouched by installing all-1.0 profiles.
        loss_mult = lat_mult = 1.0
        if self.failure_profiles:
            fs = self.failure_profiles.get(src)
            fd = self.failure_profiles.get(dst)
            if fs is not None:
                loss_mult *= fs.loss_mult
                lat_mult *= fs.latency_mult
            if fd is not None:
                loss_mult *= fd.in_loss_mult
                lat_mult *= fd.in_latency_mult
        vr = self._vec_rng
        if vr is None:
            if link.loss > 0 and self.sim.rng.random() < min(
                1.0, link.drop_probability(size) * loss_mult
            ):
                self.metrics.count("dropped")
                if account:
                    self.metrics.bytes_dropped(src, dst, type(msg).__name__, size)
                return
            delay = link.sample_latency(self.sim.rng) * lat_mult
        else:
            # Vectorized mode: same gating as the scalar path (a lossless
            # link consumes no loss draw, a jitter-free link no jitter
            # draw), uniforms pulled from the (src, dst) block stream.
            if link.loss > 0 and vr.next(src, dst) < min(
                1.0, link.drop_probability(size) * loss_mult
            ):
                self.metrics.count("dropped")
                if account:
                    self.metrics.bytes_dropped(src, dst, type(msg).__name__, size)
                return
            delay = (
                link.base_latency
                + (link.jitter * vr.next(src, dst) if link.jitter else 0.0)
            ) * lat_mult
        overhead = link.serialization_cost(size)
        if overhead > 0:
            # Per-RPC serialization (+ size-proportional transmission when
            # bytes_per_ms is set): messages queue on the directed link, so
            # a burst of unbatched sends pays the overhead N times while a
            # batch pays it once, and a fat message blocks the link longer
            # than a lean one. (Skipped entirely at 0 so default-config
            # schedules are bit-identical to the seed's.)
            start = max(self.sim.now, self._link_busy.get((src, dst), 0.0))
            self._link_busy[(src, dst)] = start + overhead
            delay += (start + overhead) - self.sim.now

        if self.engine == "legacy":
            def deliver():
                node = self.nodes.get(dst)
                if node is not None and node.alive and (src, dst) not in self.blocked:
                    if self._bytes_accounted(src, dst):
                        self.metrics.bytes_delivered(
                            src, dst, type(msg).__name__, wire_size(msg)
                        )
                    self.dispatch(dst, node.on_message(msg, self.sim.now))

            self.sim.schedule(delay, deliver)
            return
        sim = self.sim
        heapq.heappush(
            sim._events,
            (sim.now + delay, next(sim._seq), EV_DELIVER, self, src, dst, msg),
        )

    def _deliver(self, src: NodeId, dst: NodeId, msg: Message) -> None:
        """Slotted-engine delivery event (the legacy deliver closure's
        body): liveness and partition state are evaluated at DELIVERY time,
        so messages in flight when a node crashes or a partition forms are
        lost exactly as before."""
        node = self.nodes.get(dst)
        if node is not None and node.alive and (src, dst) not in self.blocked:
            if self._bytes_accounted(src, dst):
                self.metrics.bytes_delivered(
                    src, dst, type(msg).__name__, wire_size(msg)
                )
            self.dispatch(dst, node.on_message(msg, self.sim.now))

    # ------------------------------------------------------------ workload

    def submit(self, command, via: Optional[NodeId] = None) -> EntryId:
        via = via or next(iter(self.nodes))
        node = self.nodes[via]
        eid = EntryId(via, node.next_seq())
        self.dispatch(via, node.client_request(command, self.sim.now, entry_id=eid))
        return eid

    def submit_batch(self, commands, via: Optional[NodeId] = None) -> List[EntryId]:
        """Submit a burst of commands as ONE client batch: a single
        multi-entry append (leader), one relay RPC (classic follower), or a
        multi-slot FastPropose window (fast track)."""
        via = via or next(iter(self.nodes))
        node = self.nodes[via]
        pairs = [(command, EntryId(via, node.next_seq())) for command in commands]
        self.dispatch(via, node.client_request_batch(pairs, self.sim.now))
        return [eid for _, eid in pairs]

    def read(
        self,
        query,
        via: Optional[NodeId] = None,
        mode: str = "leader",
        max_staleness_ms: float = 0.0,
        retry_ms: Optional[float] = None,
    ) -> EntryId:
        """Submit a read at ``via``.

        ``mode="leader"`` (default): linearizable via the leader — the read
        forwards there and is served from applied state after a ReadIndex
        confirmation round (or zero rounds under a leader lease); it never
        rides the log. ``mode="replica"``: served locally AT ``via`` (any
        follower/learner/leader) from the leader-published certified
        watermark, with ``max_staleness_ms`` as the staleness contract
        (0 = linearizable).

        Targeting a host that was removed from the cluster raises
        :class:`MembershipError`; targeting a crashed host fails the read
        fast (``ok=False, error="host down"``) instead of letting it hang
        until some deadline with no signal. ``retry_ms`` turns both cases
        (and any other stall) into client-side failover: every ``retry_ms``
        sim-ms an uncompleted read is re-issued at the next live host,
        cycling through the membership once before giving up.

        Returns a read id; the outcome lands in ``self.reads`` (see
        :meth:`read_value` / :meth:`run_until_reads`)."""
        via = via or next(iter(self.nodes))
        if via not in self.nodes:
            raise MembershipError(
                f"read via {via!r}: not a cluster member (removed or never added)"
            )
        node = self.nodes[via]
        self._read_counter += 1
        # Cluster-scoped id stream: never collides with write EntryIds and
        # survives node replacement (node-local counters may reset).
        rid = EntryId(f"{via}/read", self._read_counter)
        self.reads[rid] = {
            "query": query,
            "via": via,
            "mode": mode,
            "staleness_ms": max_staleness_ms if mode == "replica" else 0.0,
            "issued_at": self.sim.now,
            "ok": None,
            "value": None,
            "served_index": None,
            "completed_at": None,
            "error": None,
            "attempts": [via],
        }
        # A witness has no state machine: a replica read targeted at one
        # can never be served there. Fail fast (like a crashed host) or,
        # with retries on, leave it to the failover loop — which also
        # skips witness hosts when cycling.
        unservable = mode == "replica" and node.is_witness()
        if (not node.alive or unservable) and retry_ms is None:
            rec = self.reads[rid]
            rec["ok"] = False
            rec["error"] = (
                f"witness host: {via}" if unservable else f"host down: {via}"
            )
            rec["completed_at"] = self.sim.now
            return rid
        if node.alive and not unservable:
            self.dispatch(
                via,
                node.client_read(
                    query, self.sim.now, read_id=rid,
                    mode=mode, max_staleness_ms=max_staleness_ms,
                ),
            )
        if retry_ms is not None and retry_ms > 0:
            self._schedule_read_failover(rid, retry_ms)
        return rid

    def _schedule_read_failover(self, rid: EntryId, retry_ms: float) -> None:
        """Client-side retry/failover loop for one read: while uncompleted,
        re-issue the (idempotent) query at the next live host every
        ``retry_ms``. One full cycle through the membership without a
        completion fails the read with a clear reason."""

        def poll() -> None:
            rec = self.reads.get(rid)
            if rec is None or rec["completed_at"] is not None:
                return
            hosts = sorted(self.nodes)
            if len(rec["attempts"]) > len(hosts):
                rec["ok"] = False
                rec["error"] = "read failover exhausted: no host completed it"
                rec["completed_at"] = self.sim.now
                self._notify_read_watchers(rid)
                return
            # Next host after the last attempt, round-robin over the
            # current membership (live hosts only).
            last = rec["attempts"][-1]
            start = (hosts.index(last) + 1) if last in hosts else 0
            target = None
            for i in range(len(hosts)):
                cand = hosts[(start + i) % len(hosts)]
                if not self.nodes[cand].alive:
                    continue
                if rec["mode"] == "replica" and self.nodes[cand].is_witness():
                    continue  # no state machine to serve from
                target = cand
                break
            if target is not None:
                rec["attempts"].append(target)
                self.metrics.count("read_client_failovers")
                self.dispatch(
                    target,
                    self.nodes[target].client_read(
                        rec["query"], self.sim.now, read_id=rid,
                        mode=rec["mode"], max_staleness_ms=rec["staleness_ms"],
                    ),
                )
            self.sim.schedule(retry_ms, poll)

        self.sim.schedule(retry_ms, poll)

    def _read_completed(self, read_id, result: Dict) -> None:
        rec = self.reads.get(read_id)
        if rec is None or rec["completed_at"] is not None:
            return
        rec["ok"] = result.get("ok", False)
        rec["value"] = result.get("value")
        rec["served_index"] = result.get("served_index")
        rec["completed_at"] = self.sim.now
        # Replica-read certification metadata (the oracle's watermark-
        # safety check keys off these; leader-served reads carry none).
        for k in ("wm_index", "wm_time"):
            if k in result:
                rec[k] = result[k]
        self._notify_read_watchers(read_id)

    def _notify_read_watchers(self, read_id) -> None:
        if self._read_watchers:
            for w in self._read_watchers:
                w.discard(read_id)

    def read_value(self, read_id: EntryId):
        return self.reads[read_id]["value"]

    def run_until_reads(self, read_ids, max_time: float = 30_000.0) -> bool:
        """Run until every listed read completed (or max_time). The stop
        condition is event-driven: completion hooks drain a pending set, so
        each periodic stop check is O(1) regardless of how many reads are
        being awaited. Event population (and thus the schedule) is
        identical to the scan-based formulation."""
        if self.engine == "legacy":
            def done() -> bool:
                return all(
                    self.reads[r]["completed_at"] is not None for r in read_ids
                )

            self.sim.run_until(self.sim.now + max_time, stop=done)
            return done()
        # No early return when pending is already empty: the scan-based
        # engine still ran up to check_every events before its first stop
        # check, and skipping them here would fork the schedule.
        pending = {r for r in read_ids if self.reads[r]["completed_at"] is None}
        self._read_watchers.append(pending)
        try:
            self.sim.run_until(self.sim.now + max_time, stop=lambda: not pending)
        finally:
            self._read_watchers.remove(pending)
        return not pending

    def run(self, duration: float, stop: Optional[Callable[[], bool]] = None) -> None:
        self.sim.run_until(self.sim.now + duration, stop)

    def run_until_committed(self, entry_ids: Sequence[EntryId], max_time: float = 10_000.0) -> bool:
        """Run until every listed entry committed (or max_time). Event-
        driven: Recorder.committed() drains a registered pending set as
        entries first commit, so the periodic stop check is an O(1)
        emptiness test instead of a scan over entry_ids — on long traces
        awaiting thousands of entries the scan was itself a hot spot.
        Schedule-preserving: no events are added or removed."""
        if self.engine == "legacy":
            def done() -> bool:
                return all(
                    self.metrics.traces.get(e) is not None
                    and self.metrics.traces[e].committed
                    for e in entry_ids
                )

            self.sim.run_until(self.sim.now + max_time, stop=done)
            return done()
        # No early return when pending is already empty: the scan-based
        # engine still ran up to check_every events before its first stop
        # check, and skipping them here would fork the schedule.
        traces = self.metrics.traces
        pending = {
            e for e in entry_ids
            if (t := traces.get(e)) is None or not t.committed
        }
        self.metrics.watch_commits(pending)
        try:
            self.sim.run_until(self.sim.now + max_time, stop=lambda: not pending)
        finally:
            self.metrics.unwatch_commits(pending)
        return not pending

    def run_until_leader(self, max_time: float = 10_000.0) -> Optional[NodeId]:
        def has_leader() -> bool:
            return self.leader() is not None

        self.sim.run_until(self.sim.now + max_time, stop=has_leader)
        return self.leader()

    # -------------------------------------------------------------- chaos

    def crash(self, nid: NodeId) -> None:
        self.nodes[nid].crash()

    def restart(self, nid: NodeId) -> None:
        self.nodes[nid].restart(self.sim.now)

    def restart_from_store(self, nid: NodeId, seed: Optional[int] = None) -> None:
        """Replace a node with a FRESH instance restored only from the
        persisted snapshot store (models losing the host's disk except the
        checkpoint volume). Requires a snapshot_store.

        The replacement's seed is derived per (node, replacement count) so
        simultaneous host replacements never share an RNG stream — two
        replaced nodes with identical election timeouts can livelock an
        election indefinitely. Pass ``seed`` to override (reproduce a
        specific schedule)."""
        assert self.snapshot_store is not None, "no snapshot store configured"
        old = self.nodes[nid]
        if seed is None:
            self._replacements[nid] = self._replacements.get(nid, 0) + 1
            seed = (
                self.seed * 1000003
                + zlib.crc32(nid.encode()) * 31
                + self._replacements[nid]
            ) % 2**31
        node = self._make_node(nid, old.members, seed,
                               cluster_config=old.cluster_config)
        snap = self.snapshot_store.load(nid)
        if snap is not None:
            node.restore_snapshot(snap)
        hard = self.snapshot_store.load_hard_state(nid)
        if hard is not None:
            # Without this the fresh node could double-vote in a term the
            # lost host already voted in, or reuse burned EntryId seqs.
            node.restore_hard_state(*hard)
        node.start(self.sim.now)
        self.nodes[nid] = node
        # The old node's scheduled tick closure looks nodes up by id, so the
        # replacement is ticked automatically from the next interval on.

    def compact(self, nid: NodeId) -> None:
        """Chaos hook: force an immediate compaction of nid's applied prefix
        (e.g. mid-partition, before a follower can catch up classically)."""
        self.nodes[nid].compact()

    # ------------------------------------------------- failure profiles

    def set_failure_profiles(
        self, profiles: Dict[NodeId, FailureProfile]
    ) -> None:
        """Install per-node :class:`FailureProfile`\\ s (replacing any
        already installed). Crash/recover renewal processes start
        immediately; apply lag takes effect on the node's next commit;
        link multipliers on the next message sent.

        Each node's schedule comes from a dedicated RNG stream keyed by
        (cluster seed, node id), drawn in a fixed order (up-time, then
        down-time, repeating) — so two experiments on the same seed see
        the SAME failure schedule regardless of which protocol variant,
        engine, or election policy is under test."""
        self.clear_failure_profiles()
        self.failure_profiles = dict(profiles)
        gen = self._fp_gen
        for nid in sorted(profiles):
            fp = profiles[nid]
            node = self.nodes.get(nid)
            if node is not None and fp.apply_lag_ms > 0:
                node.config.apply_lag_ms = fp.apply_lag_ms
            if fp.mtbf_ms > 0:
                r = random.Random(
                    zlib.crc32(f"failure:{nid}".encode())
                    ^ (self.seed * 2654435761 + 101) % 2**31
                )
                self._fp_rngs[nid] = r
                self._fp_schedule(nid, gen, r.expovariate(1.0 / fp.mtbf_ms), True)

    def clear_failure_profiles(self) -> None:
        """Lift all failure profiles: pending crash/recover events are
        invalidated (generation check at fire time), apply lag returns to
        zero, link multipliers stop applying. Nodes currently down stay
        down — recovery policy belongs to the caller (see fuzzer
        ``recover()``)."""
        self._fp_gen += 1
        self._fp_rngs = {}
        for nid in self.failure_profiles:
            node = self.nodes.get(nid)
            if node is not None:
                node.config.apply_lag_ms = 0.0
        self.failure_profiles = {}

    def _fp_schedule(
        self, nid: NodeId, gen: int, delay: float, crash: bool
    ) -> None:
        """Self-rescheduling crash/recover event for one profiled node.
        Fires through the engine's closure channel; a stale generation
        (profiles cleared/replaced) or a popped node ends the chain."""

        def fire() -> None:
            if gen != self._fp_gen:
                return
            fp = self.failure_profiles.get(nid)
            node = self.nodes.get(nid)
            if fp is None or node is None:
                return
            r = self._fp_rngs[nid]
            if crash:
                if node.alive:
                    node.crash()
                    self.metrics.count("fp_crashes")
                self._fp_schedule(
                    nid, gen, r.expovariate(1.0 / max(1e-9, fp.mttr_ms)), False
                )
            else:
                # restart(), not restart_from_store(): a flaky node loses
                # its process, not its disk (volatile state resets, log
                # and hard state survive — exactly RaftNode.restart).
                if not node.alive:
                    self.nodes[nid].restart(self.sim.now)
                    self.metrics.count("fp_recoveries")
                self._fp_schedule(
                    nid, gen, r.expovariate(1.0 / fp.mtbf_ms), True
                )

        self.sim.schedule(delay, fire)

    def crash_group(self, group: str) -> List[NodeId]:
        """Correlated failure: crash every live node whose installed
        profile names this ``group`` (rack loss, AZ outage, spot-pool
        reclaim). Returns the nodes felled."""
        felled = []
        for nid in sorted(self.failure_profiles):
            if self.failure_profiles[nid].group == group:
                node = self.nodes.get(nid)
                if node is not None and node.alive:
                    node.crash()
                    felled.append(nid)
        if felled:
            self.metrics.count("fp_group_crashes")
        return felled

    def partition(self, *groups: Sequence[NodeId]) -> None:
        """Block all links that cross group boundaries."""
        self.heal()
        group_of = {}
        for gi, g in enumerate(groups):
            for nid in g:
                group_of[nid] = gi
        for a in self.nodes:
            for b in self.nodes:
                if a != b and group_of.get(a) != group_of.get(b):
                    self.blocked.add((a, b))

    def heal(self) -> None:
        self.blocked.clear()

    def set_link(self, src: NodeId, dst: NodeId, **kw) -> None:
        self.link_overrides[(src, dst)] = LinkModel(**kw)

    # ------------------------------------------------------------- queries

    def leader(self) -> Optional[NodeId]:
        """The live leader of the highest term, if any."""
        leaders = [
            n for n in self.nodes.values() if n.alive and n.role.value == "leader"
        ]
        if not leaders:
            return None
        return max(leaders, key=lambda n: n.term).id

    def committed_logs(self) -> Dict[NodeId, List]:
        return {nid: n.committed_commands() for nid, n in self.nodes.items()}

    def check_log_consistency(self) -> None:
        """Safety invariant: committed commands agree at every absolute
        index two nodes can both enumerate. Reduced-state machines (KV)
        only enumerate the tail above their own compaction horizon, and
        horizons differ per node — so alignment is by absolute index, not
        list position. (With the default LogListMachine every history
        starts at index 1 and this is the classic prefix check.)"""
        indexed = {
            nid: {x: e.command for x, e in node.committed_by_index().items()}
            for nid, node in self.nodes.items()
        }
        items = list(indexed.items())
        for i in range(len(items)):
            for j in range(i + 1, len(items)):
                (na, a), (nb, b) = items[i], items[j]
                common = sorted(set(a) & set(b))
                got_a = [a[x] for x in common]
                got_b = [b[x] for x in common]
                assert got_a == got_b, (
                    f"committed log divergence between {na} and {nb}:\n"
                    f"  {got_a}\n  {got_b}"
                )

    def check_applied_order(self) -> None:
        """Each node applied strictly increasing, gap-free indexes."""
        for nid, applied in self.metrics.applied.items():
            idxs = [i for i, _ in applied]
            assert idxs == sorted(set(idxs)), f"{nid} applied out of order: {idxs}"
            # Re-applies after restart start from 1 again; allow restarts by
            # checking per-run monotonicity only when no restart happened.

    # --------------------------------------------------------- membership
    #
    # All membership changes flow through ClusterConfig entries in the
    # replicated log: learner additions are simple (non-quorum-changing)
    # config entries; every voter-set change goes through joint consensus
    # (C_old,new then C_new — see repro.core.raft.propose_config_change).
    # The single-step instant-voter path is gone. Ops queue, retry across
    # leader churn, and fail EXPLICITLY at their deadline.

    def _joiner_seed(self, nid: NodeId) -> int:
        return (zlib.crc32(nid.encode()) ^ (self.seed * 7919 + 97)) % 2**31

    def _live_config(self) -> ClusterConfig:
        lead = self.leader()
        if lead is not None:
            return self.nodes[lead].cluster_config
        best = max(
            (n for n in self.nodes.values()),
            key=lambda n: (n.alive, n.commit_index, n.term),
        )
        return best.cluster_config

    def _committed_config(self) -> ClusterConfig:
        """Best committed view across live nodes — what membership ops
        poll for completion (survives the proposing leader stepping down)."""
        best = max(
            (n for n in self.nodes.values() if n.alive),
            key=lambda n: n.commit_index,
            default=None,
        )
        if best is None:
            return self._live_config()
        return best.committed_config()

    def add_learner(
        self, nid: NodeId, seed: Optional[int] = None, timeout: float = 60_000.0
    ) -> MembershipOp:
        """Bring up ``nid`` as a non-voting learner: it receives full
        replication (including pipelined chunked snapshots) but counts
        toward no quorum until promoted. The joiner is wired exactly like
        founding nodes — persistence sinks included."""
        if nid not in self.nodes:
            cfg = self._live_config()
            init = ClusterConfig.of(cfg.voters, set(cfg.learners) | {nid})
            node = self._make_node(
                nid,
                sorted(set(cfg.members) | {nid}),
                self._joiner_seed(nid) if seed is None else seed,
                cluster_config=init,
            )
            node.start(self.sim.now)
            self.nodes[nid] = node
            self._schedule_tick(nid)
        return self._enqueue_mop(
            MembershipOp("learner", nid, deadline=self.sim.now + timeout)
        )

    def promote(self, nid: NodeId, timeout: float = 60_000.0) -> MembershipOp:
        """Promote learner ``nid`` to voter, once caught up, through joint
        consensus."""
        return self._enqueue_mop(
            MembershipOp("promote", nid, deadline=self.sim.now + timeout)
        )

    def add_witness(
        self, nid: NodeId, seed: Optional[int] = None, timeout: float = 60_000.0
    ) -> List[MembershipOp]:
        """Add ``nid`` as a WITNESS voter (quorum-only member: votes and
        acks rounds, stores log skeletons, never campaigns, never serves
        reads) — the cheap way to odd-size a cluster. Joins as a learner
        first, then one joint change promotes it straight into the voter
        set with the witness marker."""
        op1 = self.add_learner(nid, seed=seed, timeout=timeout)
        op2 = self._enqueue_mop(
            MembershipOp("witness", nid, deadline=self.sim.now + timeout)
        )
        return [op1, op2]

    def remove_node(
        self, nid: NodeId, pop: bool = False, timeout: float = 60_000.0
    ) -> MembershipOp:
        """Remove ``nid`` (voter or learner) through joint consensus. Once
        the final config commits the node is crashed (the pod is killed);
        ``pop=True`` also drops it from ``self.nodes`` (host physically
        leaves — used by hierarchy pod rebalancing)."""
        return self._enqueue_mop(
            MembershipOp("remove", nid, pop=pop, deadline=self.sim.now + timeout)
        )

    def replace_node(
        self,
        old: NodeId,
        new: NodeId,
        seed: Optional[int] = None,
        timeout: float = 120_000.0,
    ) -> List[MembershipOp]:
        """Replace voter ``old`` with fresh host ``new``: ``new`` joins as
        a learner, catches up via the pipelined chunked snapshot path, and
        one joint config change then swaps it in as ``old`` leaves — the
        leader itself may be ``old`` (it steps down after C_new commits)."""
        op1 = self.add_learner(new, seed=seed, timeout=timeout)
        op2 = self._enqueue_mop(
            MembershipOp("swap", old, new=new, deadline=self.sim.now + timeout)
        )
        return [op1, op2]

    def add_node(self, nid: NodeId, seed: int = 9999) -> MembershipOp:
        """Legacy convenience: learner catch-up then promotion (the
        single-step instant-voter join no longer exists)."""
        self.add_learner(nid, seed=seed)
        return self.promote(nid)

    def run_until_membership(
        self, max_time: float = 120_000.0, raise_on_failure: bool = True
    ) -> bool:
        """Run until every queued membership op completed. Raises
        :class:`MembershipError` if any op failed (explicitly surfaced —
        never silently dropped)."""
        self.sim.run_until(self.sim.now + max_time, stop=lambda: not self._mops)
        if raise_on_failure and self.membership_failures:
            fails, self.membership_failures = self.membership_failures, []
            raise MembershipError(
                "; ".join(f"{o.kind}({o.nid}): {o.error}" for o in fails)
            )
        return not self._mops

    # -- op queue driving ---------------------------------------------------

    def _enqueue_mop(self, op: MembershipOp) -> MembershipOp:
        self._mops.append(op)
        if not self._mop_poll_scheduled:
            self._mop_poll_scheduled = True
            self._schedule_mop_poll()
        return op

    def _schedule_mop_poll(self) -> None:
        def poll():
            self._membership_poll()
            if self._mops:
                self.sim.schedule(self.tick_interval, poll)
            else:
                self._mop_poll_scheduled = False

        self.sim.schedule(self.tick_interval, poll)

    def _membership_poll(self) -> None:
        while self._mops:
            op = self._mops[0]
            if self.sim.now >= op.deadline:
                op.state = "failed"
                op.error = op.error or (
                    f"timed out waiting for {op.kind}({op.nid}) "
                    f"[leader={self.leader()}]"
                )
                self.membership_failures.append(op)
                self._mops.pop(0)
                continue
            if not self._advance_mop(op):
                return
            op.state = "done"
            self._mops.pop(0)

    def _learner_caught_up(self, lead: RaftNode, nid: NodeId) -> bool:
        match = lead.match_index.get(nid, 0)
        return match >= lead.commit_index or lead.last_log_index() - match <= 2

    def _advance_mop(self, op: MembershipOp) -> bool:
        """One scheduling step for the head op; True once it completed."""
        committed = self._committed_config()
        in_transition = committed.joint
        if op.kind == "learner" and op.nid in committed.members:
            return True
        if (
            op.kind == "promote"
            and not in_transition
            and op.nid in committed.voters
        ):
            return True
        if (
            op.kind == "witness"
            and not in_transition
            and op.nid in committed.witnesses
        ):
            return True
        if op.kind in ("remove", "swap"):
            gone = not in_transition and op.nid not in committed.members
            swapped = op.kind == "remove" or op.new in committed.voters
            if gone and swapped:
                node = self.nodes.get(op.nid)
                if node is not None and node.alive:
                    node.crash()  # the removed pod is killed
                if op.pop:
                    self.nodes.pop(op.nid, None)
                return True
        lead_id = self.leader()
        if lead_id is None:
            return False
        lead = self.nodes[lead_id]
        cur = lead.cluster_config
        if op.kind == "learner":
            eid, out = lead.propose_config_change(
                learners=sorted(set(cur.learners) | {op.nid}), now=self.sim.now
            )
        elif op.kind == "promote":
            if op.nid not in cur.members or not self._learner_caught_up(lead, op.nid):
                return False
            eid, out = lead.propose_config_change(
                voters=sorted(set(cur.voters) | {op.nid}), now=self.sim.now
            )
        elif op.kind == "witness":
            # A witness only acks rounds it has the skeleton for, so the
            # same catch-up gate as a real promotion applies.
            if op.nid not in cur.members or not self._learner_caught_up(lead, op.nid):
                return False
            eid, out = lead.propose_config_change(
                voters=sorted(set(cur.voters) | {op.nid}),
                witnesses=sorted(set(cur.witnesses) | {op.nid}),
                now=self.sim.now,
            )
        elif op.kind == "remove":
            eid, out = lead.propose_config_change(
                voters=sorted(set(cur.voters) - {op.nid}),
                learners=sorted(set(cur.learners) - {op.nid}),
                now=self.sim.now,
            )
        elif op.kind == "swap":
            if op.new not in cur.members or not self._learner_caught_up(lead, op.new):
                return False
            eid, out = lead.propose_config_change(
                voters=sorted((set(cur.voters) - {op.nid}) | {op.new}),
                learners=sorted(set(cur.learners) - {op.new, op.nid}),
                now=self.sim.now,
            )
        else:  # pragma: no cover - unknown kind
            op.error = f"unknown membership op kind {op.kind!r}"
            return False
        # A refused proposal (change in flight / joint transition still
        # finishing) simply retries at the next poll; a lost proposal is
        # re-proposed against whichever leader emerges.
        self.dispatch(lead_id, out)
        return False
