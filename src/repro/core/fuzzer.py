"""Protocol fuzzer: a seeded, deterministic adversary that mints regression
tests.

The chaos suite exercises failure scenarios we thought of; this module
explores the ones we didn't. A :class:`ProtocolFuzzer` drives a live
:class:`repro.core.sim.Cluster` through a seeded random schedule of
partitions, crashes, restarts (warm and from the persisted checkpoint
store), clock skew, message drop/duplication/corruption windows
(:class:`repro.core.sim.Adversary`), membership churn, and client
writes/reads — and checks the FULL oracle suite from
``tests/commit_history.py`` after every single step:

  agreement · no-duplicates · durability of acked commits · per-client FIFO
  (single-batch origins) · read freshness/validity · joint-config
  discipline · election safety (plus the Recorder's online commit/election
  safety asserts, which fire mid-run).

Everything is deterministic per seed: ops are generated up front from one
``random.Random(seed)`` with every target resolved to a concrete node name,
so the trace needs no RNG to replay — same seed ⇒ identical trace ⇒
identical verdict. A failing schedule is shrunk (ddmin-style chunk removal)
to a minimal op list and saved as a JSON trace file; any trace file replays
standalone via :func:`replay_trace_file` — the one-liner a regression test
needs (see ``tests/regressions/``).

Trace file format (version 1)::

    {
      "version": 1,
      "seed":    <int>,                 # provenance only; replay is RNG-free
      "profile": { ...FuzzProfile... },
      "ops":     [ {"op": "...", ...}, ... ],
      "expect":  {                      # all optional; checked after recovery
        "require_leader":       true,
        "max_leader_elections": <int>,  # total leaderships ever elected
        "max_term":             <int>,  # highest term any node reached
        "min_commits":          <int>,  # committed entries cluster-wide
        "min_counters":         {"adv_corrupted": 1, ...},  # scenario proof
        "max_counters":         {"checkquorum_stepdowns": 0, ...}
      }
    }

CLI (the CI fuzz lane)::

    PYTHONPATH=src python -m repro.core.fuzzer --seeds 1-20 --steps 40 \
        --out artifacts/fuzz [--no-shrink]

exits non-zero if any seed fails, writing the shrunk failing trace to the
out directory — the workflow uploads it as an artifact, and promoting it to
a named regression test is one ``cp`` into ``tests/regressions/``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import random
import sys
import tempfile
import traceback
from typing import Any, Dict, List, Optional, Tuple

from repro.checkpoint.manager import SnapshotStore
from repro.core.raft import RaftConfig
from repro.core.sim import Adversary, Cluster, FailureProfile
from repro.core.statemachine import KVMachine
from repro.core.types import EntryId

TRACE_VERSION = 1


def preset_failure_profiles(
    name: str, nodes: List[str]
) -> Dict[str, FailureProfile]:
    """Named per-node FailureProfile presets for fuzz sweeps, a pure
    function of (name, node order) so a trace that records only the
    preset name replays against the identical fleet.

    - "crashy":      staggered crash/recover renewal on every node, two
                     correlated-failure groups (the nightly crash-heavy
                     lane);
    - "slow-cpu":    a minority of nodes applies 10-40 ms behind commit;
    - "flaky-links": asymmetric per-node loss/latency multipliers (loss
                     multipliers only bite when the base network is lossy);
    - "mixed":       all three at once, milder.
    """
    out: Dict[str, FailureProfile] = {}
    if name == "crashy":
        for i, nid in enumerate(nodes):
            out[nid] = FailureProfile(
                mtbf_ms=3000.0 + 1100.0 * i,
                mttr_ms=400.0 + 170.0 * i,
                group=f"g{i % 2}",
            )
    elif name == "slow-cpu":
        for i, nid in enumerate(nodes):
            if i % 3 == 0:
                out[nid] = FailureProfile(apply_lag_ms=10.0 + 10.0 * (i % 4))
    elif name == "flaky-links":
        for i, nid in enumerate(nodes):
            out[nid] = FailureProfile(
                loss_mult=1.0 + 0.8 * (i % 3),
                latency_mult=1.0 + 0.5 * (i % 4),
                in_loss_mult=1.0 + 0.4 * ((i + 1) % 3),
                in_latency_mult=1.0 + 0.25 * ((i + 2) % 4),
            )
    elif name == "mixed":
        for i, nid in enumerate(nodes):
            out[nid] = FailureProfile(
                mtbf_ms=6000.0 + 1300.0 * i,
                mttr_ms=500.0,
                apply_lag_ms=8.0 if i % 2 else 0.0,
                latency_mult=1.0 + 0.3 * (i % 3),
                group=f"g{i % 2}",
            )
    elif name:
        raise ValueError(f"unknown failure profile preset {name!r}")
    return out


# Named (bytes_per_ms, mtu_bytes) link presets for the --link-profile CLI.
# Pure data: the chosen numbers are serialized into the trace profile, so a
# replay needs no preset lookup. "thin" is a serialization-limited pipe
# where appends and snapshot chunks queue behind each other; "congested"
# crawls AND fragments (per-packet loss bites big messages hardest);
# "mtu-lossy" keeps infinite rate but makes loss size-aware.
LINK_PROFILES: Dict[str, Tuple[float, float]] = {
    "": (0.0, 0.0),
    "thin": (60.0, 1400.0),
    "congested": (25.0, 512.0),
    "mtu-lossy": (0.0, 256.0),
}


@dataclasses.dataclass
class FuzzProfile:
    """Cluster shape + protocol knobs a trace runs against. Serialized into
    every trace file so a regression replays against the exact
    configuration that failed, not today's defaults."""

    n: int = 5
    protocol: str = "fastraft"
    pre_vote: bool = True
    check_quorum: bool = True
    lease_duration_ms: float = 120.0
    clock_skew_ms: float = 20.0
    clock_drift: float = 0.0001
    election_timeout_min: float = 150.0
    election_timeout_max: float = 300.0
    heartbeat_interval: float = 50.0
    snapshot_threshold: int = 12
    snapshot_chunk_bytes: int = 96
    snapshot_chunk_window: int = 2
    loss: float = 0.0
    jitter: float = 1.0
    # Read-path knobs. BOTH must default to the pre-replica-read behavior
    # (0.0 / False): from_dict fills missing keys with these defaults, so
    # regression traces minted before the knobs existed must replay against
    # the schedule they failed under, not today's.
    read_coalesce_window: float = 0.0
    election_noop: bool = False
    # Reliability knobs — same backward-compat rule: "" / 0 reproduce the
    # pre-knob schedules exactly. ``failure_profile`` names a preset from
    # :func:`preset_failure_profiles` installed at cluster construction
    # (crash/recover renewal chaos on top of the op schedule);
    # ``witnesses`` marks the LAST w founding nodes as quorum-only
    # witness members.
    failure_profile: str = ""
    witnesses: int = 0
    # Link-capacity knobs (bandwidth-constrained fuzzing). 0.0 = infinite
    # capacity, the schedule every pre-link trace was minted under.
    # ``bytes_per_ms`` gives each directed link a serial transmit rate
    # (messages queue FIFO behind each other); ``mtu_bytes`` makes loss
    # per-packet, so big messages die more often than small ones.
    bytes_per_ms: float = 0.0
    mtu_bytes: float = 0.0
    # Wire-efficiency knobs (DESIGN.md section 13) — defaults off so
    # pre-knob traces replay byte-identically.
    delta_snapshots: bool = False
    ack_piggyback: bool = False

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "FuzzProfile":
        fields = {f.name for f in dataclasses.fields(FuzzProfile)}
        return FuzzProfile(**{k: v for k, v in d.items() if k in fields})

    def raft_config(self) -> RaftConfig:
        return RaftConfig(
            election_timeout_min=self.election_timeout_min,
            election_timeout_max=self.election_timeout_max,
            heartbeat_interval=self.heartbeat_interval,
            pre_vote=self.pre_vote,
            check_quorum=self.check_quorum,
            lease_duration_ms=self.lease_duration_ms,
            clock_skew_ms=self.clock_skew_ms,
            snapshot_threshold=self.snapshot_threshold,
            snapshot_chunk_bytes=self.snapshot_chunk_bytes,
            snapshot_chunk_window=self.snapshot_chunk_window,
            read_coalesce_window=self.read_coalesce_window,
            election_noop=self.election_noop,
            delta_snapshots=self.delta_snapshots,
            ack_piggyback=self.ack_piggyback,
        )


@dataclasses.dataclass
class FuzzReport:
    ok: bool
    error: str = ""
    failed_at_step: int = -1  # index into ops; -1 = setup/expect phase
    n_ops: int = 0
    n_commits: int = 0
    n_reads_checked: int = 0
    leader_elections: int = 0
    max_term: int = 0
    counters: Dict[str, int] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def make_trace(
    seed: int,
    ops: List[Dict[str, Any]],
    profile: Optional[FuzzProfile] = None,
    expect: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    return {
        "version": TRACE_VERSION,
        "seed": seed,
        "profile": (profile or FuzzProfile()).to_dict(),
        "ops": ops,
        "expect": expect or {},
    }


def save_trace(trace: Dict[str, Any], path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(trace, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


def load_trace(path: str) -> Dict[str, Any]:
    with open(path) as f:
        trace = json.load(f)
    assert trace.get("version") == TRACE_VERSION, (
        f"unknown trace version {trace.get('version')!r} in {path}"
    )
    return trace


def replay_trace_file(path: str, engine: str = "slotted") -> FuzzReport:
    """THE regression entry point: replay a saved trace standalone.

    ``engine`` selects the simulator event engine ("slotted" or "legacy");
    both produce byte-identical schedules, so a trace minted under either
    replays identically under the other (tests/test_sim_equivalence.py
    gates this)."""
    return replay(load_trace(path), engine=engine)


# ---------------------------------------------------------------- replayer


class _TraceRunner:
    """Applies one trace's ops to a live cluster, oracle-checking after
    every step. Tolerant of structurally-invalid ops (unknown node, double
    crash): shrinking removes ops arbitrarily, and only ORACLE failures may
    count as failures — never bookkeeping artifacts of the shrink itself."""

    def __init__(self, trace: Dict[str, Any], store_dir: str, engine: str = "slotted"):
        self.profile = FuzzProfile.from_dict(trace.get("profile", {}))
        self.expect = trace.get("expect", {}) or {}
        self.store = SnapshotStore(store_dir)
        p = self.profile
        wits = [f"n{i}" for i in range(p.n - p.witnesses, p.n)] if p.witnesses else []
        self.cluster = Cluster(
            n=p.n,
            protocol=p.protocol,
            seed=trace.get("seed", 0),
            loss=p.loss,
            jitter=p.jitter,
            config=p.raft_config(),
            snapshot_store=self.store,
            state_machine_factory=lambda nid: KVMachine(),
            clock_skew_ms=p.clock_skew_ms,
            clock_drift=p.clock_drift,
            engine=engine,
            witnesses=wits,
            bytes_per_ms=p.bytes_per_ms,
            mtu_bytes=p.mtu_bytes,
        )
        if p.failure_profile:
            self.cluster.set_failure_profiles(
                preset_failure_profiles(
                    p.failure_profile, [f"n{i}" for i in range(p.n)]
                )
            )
        self.writes: List[Tuple[EntryId, str]] = []  # every KV write submitted
        self.submit_batches: Dict[str, int] = {}  # origin -> batch count
        self.n_reads_checked = 0

    # -- op execution ------------------------------------------------------

    def apply_op(self, op: Dict[str, Any]) -> None:
        c = self.cluster
        kind = op.get("op")
        if kind == "run":
            c.run(float(op.get("ms", 500.0)))
        elif kind == "partition":
            groups = [
                [n for n in g if n in c.nodes] for g in op.get("groups", [])
            ]
            groups = [g for g in groups if g]
            if len(groups) >= 2:
                c.partition(*groups)
        elif kind == "heal":
            c.heal()
        elif kind == "crash":
            node = c.nodes.get(op.get("node"))
            if node is not None:
                node.crash()
        elif kind == "restart":
            node = c.nodes.get(op.get("node"))
            if node is not None:
                node.restart(c.sim.now)
        elif kind == "restart_from_store":
            if op.get("node") in c.nodes:
                c.restart_from_store(op["node"], seed=int(op.get("seed", 1)))
        elif kind == "clock_skew":
            node = c.nodes.get(op.get("node"))
            if node is not None:
                # Clamp inside the configured safety margin: skew beyond
                # clock_skew_ms makes stale lease reads a CONFIG error, not
                # a protocol bug — the fuzzer only probes the promised
                # envelope.
                m = self.profile.clock_skew_ms
                node.clock_offset = max(-m, min(m, float(op.get("offset_ms", 0.0))))
        elif kind == "adversary":
            c.adversary = Adversary(
                seed=int(op.get("seed", 0)),
                drop_p=float(op.get("drop", 0.0)),
                dup_p=float(op.get("dup", 0.0)),
                corrupt_p=float(op.get("corrupt", 0.0)),
                until=c.sim.now + float(op.get("ms", 1000.0)),
            )
        elif kind == "adversary_off":
            c.adversary = None
        elif kind == "failure_profiles":
            # Install a named preset over the CURRENT membership (or lift
            # all profiles with preset "").
            preset = op.get("preset", "")
            if preset:
                c.set_failure_profiles(
                    preset_failure_profiles(preset, sorted(c.nodes))
                )
            else:
                c.clear_failure_profiles()
        elif kind == "crash_group":
            c.crash_group(op.get("group", ""))
        elif kind == "submit":
            via = op.get("via")
            if via in c.nodes and c.nodes[via].alive:
                cmds = [
                    f"SET {key} {val}"
                    for key, val in zip(op.get("keys", []), op.get("vals", []))
                ]
                if cmds:
                    eids = c.submit_batch(cmds, via=via)
                    self.writes.extend(zip(eids, cmds))
                    self.submit_batches[via] = self.submit_batches.get(via, 0) + 1
        elif kind == "read":
            via = op.get("via")
            if via in c.nodes and c.nodes[via].alive:
                # Three flavors, all oracle-checked: "leader" (ReadIndex /
                # lease; also every pre-replica-read trace, which carries
                # no mode key), "replica" (watermark-linearizable at via),
                # "stale" (replica with an explicit staleness bound).
                mode = op.get("mode", "leader")
                staleness = 0.0
                if mode == "stale":
                    mode = "replica"
                    staleness = float(op.get("staleness_ms", 500.0))
                c.read(
                    f"GET {op.get('key', 'k0')}", via=via,
                    mode=mode, max_staleness_ms=staleness,
                )
        elif kind == "membership":
            self._apply_membership(op)
        # Unknown kinds are ignored (forward compatibility + shrink safety).

    def _apply_membership(self, op: Dict[str, Any]) -> None:
        c = self.cluster
        mk = op.get("kind")
        timeout = float(op.get("timeout", 60_000.0))
        try:
            if mk == "remove" and op.get("node") in c.nodes:
                c.remove_node(op["node"], timeout=timeout)
            elif mk == "add" and op.get("node") not in c.nodes:
                c.add_learner(op["node"], timeout=timeout)
                c.promote(op["node"], timeout=timeout)
            elif mk == "replace" and op.get("node") in c.nodes:
                if op.get("new") not in c.nodes:
                    c.replace_node(op["node"], op["new"], timeout=timeout)
        except AssertionError:
            raise
        except Exception:
            pass  # structurally impossible op after shrinking: skip

    # -- oracles -----------------------------------------------------------

    def check_oracles(self, final: bool = False) -> None:
        # Imported lazily: tests/ is importable because conftest puts the
        # repo root on sys.path for pytest, and the CLI below mirrors that.
        from tests.commit_history import (
            check_commit_history,
            check_config_oracle,
            check_kv_consistency,
            check_read_oracle,
            committed_acks,
        )

        c = self.cluster
        # Acked-durability is asserted only on the FINAL settled pass:
        # restarting a quorum rolls volatile commit_index back until the
        # leader re-advances it, so mid-step the entry is safe in every log
        # yet enumerable on no node — a timing artifact, not a loss. A real
        # loss cannot heal, so the final pass still catches it.
        acked = (
            committed_acks(c, [e for e, _ in self.writes]) if final else []
        )
        # Per-client FIFO is promised for SEQUENTIAL submitters. Claim it
        # for origins that (a) submitted exactly one batch and (b) had no
        # fast-track fallback: losing a contested slot re-proposes the
        # entry through the leader, legitimately reordering it relative to
        # window-mates that won their slots.
        fifo = []
        for origin, batches in self.submit_batches.items():
            if batches != 1:
                continue
            eids = [e for e, _ in self.writes if e.origin == origin]
            if all(
                c.metrics.traces[e].fallbacks == 0
                for e in eids
                if e in c.metrics.traces
            ):
                fifo.append(origin)
        check_commit_history(c, acked=acked, fifo_origins=fifo)
        check_kv_consistency(c)
        check_config_oracle(c)
        self.n_reads_checked = check_read_oracle(c, self.writes)

    def check_expectations(self) -> None:
        c = self.cluster
        exp = self.expect
        if exp.get("require_leader"):
            assert c.leader() is not None, "no leader after recovery"
        elections = sum(len(s) for s in c.metrics.leaders.values())
        if "max_leader_elections" in exp:
            assert elections <= exp["max_leader_elections"], (
                f"{elections} leaderships elected "
                f"(expected <= {exp['max_leader_elections']}): "
                f"{dict(sorted(c.metrics.leaders.items()))}"
            )
        if "max_term" in exp:
            hi = max(n.term for n in c.nodes.values())
            assert hi <= exp["max_term"], (
                f"term inflated to {hi} (expected <= {exp['max_term']})"
            )
        if "min_commits" in exp:
            n = len(c.metrics.committed_at)
            assert n >= exp["min_commits"], (
                f"only {n} commits (expected >= {exp['min_commits']})"
            )
        for k, v in (exp.get("min_counters") or {}).items():
            got = c.metrics.counters.get(k, 0)
            assert got >= v, f"counter {k}={got} (expected >= {v})"
        for k, v in (exp.get("max_counters") or {}).items():
            got = c.metrics.counters.get(k, 0)
            assert got <= v, f"counter {k}={got} (expected <= {v})"

    def recover(self) -> None:
        """End-of-trace recovery: lift every fault and let the cluster
        settle, so expectations (and the final oracle pass) judge the
        protocol, not a still-partitioned network."""
        c = self.cluster
        c.adversary = None
        c.clear_failure_profiles()  # stop the crash/recover renewal chaos
        c.heal()
        for nid in list(c.nodes):
            if not c.nodes[nid].alive and c.nodes[nid].is_voter():
                c.nodes[nid].restart(c.sim.now)
        settle = float(self.expect.get("settle_ms", 10_000.0))
        lead = c.run_until_leader(max_time=settle)
        # Act like a client: one read forces the lazy __noop__ read barrier,
        # which is how a fresh leader commits prior-term entries in this
        # codebase (there is no eager per-election no-op). Without it a
        # quiet healed cluster keeps acked prior-term entries uncommitted
        # forever and the durability oracle would flag a phantom loss.
        if lead is not None:
            c.read("GET __settle__", via=lead)
        c.run(settle)

    def report(self, ok: bool, error: str = "", step: int = -1, n_ops: int = 0) -> FuzzReport:
        c = self.cluster
        return FuzzReport(
            ok=ok,
            error=error,
            failed_at_step=step,
            n_ops=n_ops,
            n_commits=len(c.metrics.committed_at),
            n_reads_checked=self.n_reads_checked,
            leader_elections=sum(len(s) for s in c.metrics.leaders.values()),
            max_term=max(n.term for n in c.nodes.values()),
            counters=dict(c.metrics.counters),
        )


def replay(trace: Dict[str, Any], engine: str = "slotted") -> FuzzReport:
    """Replay a trace against a fresh cluster; deterministic per trace."""
    ops = trace.get("ops", [])
    with tempfile.TemporaryDirectory(prefix="fuzz-store-") as store_dir:
        runner = _TraceRunner(trace, store_dir, engine=engine)
        for i, op in enumerate(ops):
            try:
                runner.apply_op(op)
                runner.check_oracles()
            except AssertionError as e:
                return runner.report(
                    False, f"step {i} {op.get('op')}: {e}", step=i, n_ops=len(ops)
                )
        try:
            runner.recover()
            runner.check_oracles(final=True)
            runner.check_expectations()
        except AssertionError as e:
            return runner.report(False, f"recovery/expect: {e}", n_ops=len(ops))
        return runner.report(True, n_ops=len(ops))


# ---------------------------------------------------------------- shrinking


def shrink(
    trace: Dict[str, Any], max_replays: int = 200
) -> Tuple[Dict[str, Any], int]:
    """ddmin-style trace minimization: repeatedly try dropping chunks of
    ops (halves, then smaller, down to single ops), keeping any candidate
    that still fails. Returns (shrunk trace, replays used). Deterministic:
    replay order and chunk schedule are fixed by the input alone."""
    ops = list(trace.get("ops", []))
    replays = 0

    def fails(candidate_ops: List[Dict[str, Any]]) -> bool:
        nonlocal replays
        if replays >= max_replays:
            return False
        replays += 1
        t = dict(trace)
        t["ops"] = candidate_ops
        return not replay(t).ok

    chunk = max(1, len(ops) // 2)
    while chunk >= 1:
        i = 0
        progressed = False
        while i < len(ops):
            candidate = ops[:i] + ops[i + chunk:]
            if candidate and fails(candidate):
                ops = candidate
                progressed = True
                # Same position now holds the next chunk; retry in place.
            else:
                i += chunk
        if chunk == 1 and not progressed:
            break
        chunk = chunk // 2 if chunk > 1 else (1 if progressed else 0)
    out = dict(trace)
    out["ops"] = ops
    return out, replays


# --------------------------------------------------------------- generation


class ProtocolFuzzer:
    """Generates one deterministic trace per seed and runs it.

    Generation is decoupled from execution: the whole op schedule is drawn
    up front from ``random.Random(seed)`` with concrete node names, so the
    emitted trace IS the execution — no hidden RNG state to replay."""

    def __init__(
        self,
        seed: int,
        steps: int = 40,
        profile: Optional[FuzzProfile] = None,
        engine: str = "slotted",
    ):
        self.seed = seed
        self.steps = steps
        self.profile = profile or FuzzProfile()
        self.engine = engine

    def generate(self) -> Dict[str, Any]:
        rng = random.Random(self.seed * 0x9E3779B1 + 7)
        p = self.profile
        nodes = [f"n{i}" for i in range(p.n)]
        joiners = 0
        ops: List[Dict[str, Any]] = [{"op": "run", "ms": 2000.0}]
        kinds = (
            # (weight, kind)
            (22, "run"),
            (14, "submit"),
            (10, "read"),
            (8, "partition"),
            (6, "heal"),
            (8, "crash"),
            (8, "restart"),
            (4, "restart_from_store"),
            (5, "adversary"),
            (3, "adversary_off"),
            (4, "clock_skew"),
            (4, "membership"),
        )
        bag = [k for w, k in kinds for _ in range(w)]
        if p.failure_profile:
            # Reliability chaos rides on top of the preset installed at
            # setup: correlated group crashes, plus toggling the profiles
            # off/on mid-trace (testing install/clear at any point).
            bag += ["crash_group"] * 3 + ["failure_profiles"] * 2
        for step in range(self.steps):
            kind = rng.choice(bag)
            if kind == "run":
                ops.append({"op": "run", "ms": rng.choice([200.0, 500.0, 1000.0, 2000.0])})
            elif kind == "submit":
                n = rng.randint(1, 4)
                ops.append(
                    {
                        "op": "submit",
                        "via": rng.choice(nodes),
                        "keys": [f"k{rng.randint(0, 5)}" for _ in range(n)],
                        "vals": [f"s{step}v{j}" for j in range(n)],
                    }
                )
            elif kind == "read":
                op = {
                    "op": "read",
                    "via": rng.choice(nodes),
                    "key": f"k{rng.randint(0, 5)}",
                }
                roll = rng.random()
                if roll < 0.35:
                    op["mode"] = "replica"
                elif roll < 0.55:
                    op["mode"] = "stale"
                    op["staleness_ms"] = rng.choice([100.0, 500.0, 2000.0])
                # else: leader mode (no key — matches pre-replica traces)
                ops.append(op)
            elif kind == "partition":
                cut = rng.randint(1, max(1, len(nodes) - 1))
                picks = rng.sample(nodes, cut)
                rest = [n for n in nodes if n not in picks]
                if picks and rest:
                    ops.append({"op": "partition", "groups": [picks, rest]})
            elif kind == "heal":
                ops.append({"op": "heal"})
            elif kind in ("crash", "restart", "restart_from_store", "clock_skew"):
                node = rng.choice(nodes)
                op: Dict[str, Any] = {"op": kind, "node": node}
                if kind == "restart_from_store":
                    op["seed"] = rng.randint(1, 2**30)
                if kind == "clock_skew":
                    op["offset_ms"] = rng.uniform(-p.clock_skew_ms, p.clock_skew_ms)
                ops.append(op)
            elif kind == "adversary":
                ops.append(
                    {
                        "op": "adversary",
                        "seed": rng.randint(1, 2**30),
                        "drop": round(rng.uniform(0.0, 0.25), 3),
                        "dup": round(rng.uniform(0.0, 0.2), 3),
                        "corrupt": round(rng.uniform(0.0, 0.2), 3),
                        "ms": rng.choice([500.0, 1500.0, 3000.0]),
                    }
                )
            elif kind == "adversary_off":
                ops.append({"op": "adversary_off"})
            elif kind == "crash_group":
                ops.append({"op": "crash_group", "group": f"g{rng.randint(0, 1)}"})
                ops.append({"op": "run", "ms": rng.choice([500.0, 1500.0])})
            elif kind == "failure_profiles":
                ops.append(
                    {
                        "op": "failure_profiles",
                        "preset": rng.choice(["", p.failure_profile]),
                    }
                )
            elif kind == "membership":
                which = rng.random()
                if which < 0.4 and len(nodes) > 3:
                    victim = rng.choice(nodes)
                    nodes = [n for n in nodes if n != victim]
                    ops.append({"op": "membership", "kind": "remove", "node": victim})
                elif which < 0.7:
                    joiners += 1
                    new = f"x{joiners}"
                    old = rng.choice(nodes)
                    nodes = [n for n in nodes if n != old] + [new]
                    ops.append(
                        {"op": "membership", "kind": "replace", "node": old, "new": new}
                    )
                else:
                    joiners += 1
                    new = f"x{joiners}"
                    nodes = nodes + [new]
                    ops.append({"op": "membership", "kind": "add", "node": new})
                ops.append({"op": "run", "ms": 3000.0})
        ops.append({"op": "heal"})
        return make_trace(self.seed, ops, self.profile)

    def run(self) -> Tuple[Dict[str, Any], FuzzReport]:
        trace = self.generate()
        return trace, replay(trace, engine=self.engine)


# ------------------------------------------------------- hierarchy sweep


def hierarchy_sweep(
    seed: int, steps: int = 30, profile: Optional[FuzzProfile] = None,
    engine: str = "slotted",
) -> Tuple[Dict[str, Any], FuzzReport]:
    """Seeded adversary sweep at the HIERARCHY level: three pods under one
    simulation, driven through pod-leader crashes, intra-pod partitions,
    global-link adversaries, pod writes and pod reads in all three modes
    (leader / replica / bounded-stale), with the per-pod read + KV oracles
    checked after every step and the cross-pod delivery oracle at the end.

    Unlike :class:`ProtocolFuzzer` traces this is not ddmin-shrinkable
    (the action log spans several coupled clusters); the log itself is the
    artifact — it is returned (and saved by the CLI) so a failure replays
    by re-running the seed."""
    from repro.core.hierarchy import HierarchicalCluster
    from tests.commit_history import check_kv_consistency, check_read_oracle

    p = profile or FuzzProfile()
    rng = random.Random(seed * 0x9E3779B1 + 13)
    h = HierarchicalCluster(
        n_pods=3, hosts_per_pod=3, seed=seed, config=p.raft_config(),
        state_machine_factory=lambda nid: KVMachine(),
        engine=engine,
    )
    h.bootstrap()
    actions: List[Dict[str, Any]] = []
    writes: Dict[str, List[Tuple[EntryId, str]]] = {pod: [] for pod in h.pod_ids}
    n_reads_checked = 0
    wi = 0
    ok, error, failed_at = True, "", -1

    def live_hosts(pod: str) -> List[str]:
        return [n for n, node in h.pods[pod].nodes.items() if node.alive]

    kinds = [
        "run", "run", "write", "write", "read", "read", "read",
        "crash_leader", "restart_down", "isolate_host", "heal_pod",
        "global_adversary", "global_adversary_off",
    ]
    try:
        for step in range(steps):
            pod = rng.choice(h.pod_ids)
            local = h.pods[pod]
            kind = rng.choice(kinds)
            act: Dict[str, Any] = {"step": step, "op": kind, "pod": pod}
            if kind == "run":
                act["ms"] = rng.choice([200.0, 500.0, 1000.0])
                h.run(act["ms"])
            elif kind == "write":
                hosts = live_hosts(pod)
                if hosts:
                    via = rng.choice(hosts)
                    wi += 1
                    cmd = f"SET hk{rng.randint(0, 4)} w{wi}"
                    act.update(via=via, cmd=cmd)
                    writes[pod].append((local.submit(cmd, via=via), cmd))
            elif kind == "read":
                roll = rng.random()
                if roll < 0.4:
                    mode, staleness, via = "leader", 0.0, None
                elif roll < 0.75:
                    mode, staleness, via = "replica", 0.0, None
                else:
                    mode = "replica"
                    staleness = rng.choice([100.0, 500.0, 2000.0])
                    via = None
                act.update(mode=mode, staleness_ms=staleness)
                h.read_pod(pod, f"GET hk{rng.randint(0, 4)}", via_host=via,
                           mode=mode, max_staleness_ms=staleness)
            elif kind == "crash_leader":
                lead = local.leader()
                if lead is not None:
                    act["node"] = lead
                    local.crash(lead)
            elif kind == "restart_down":
                for nid, node in local.nodes.items():
                    if not node.alive:
                        node.restart(h.sim.now)
                        act.setdefault("nodes", []).append(nid)
            elif kind == "isolate_host":
                hosts = sorted(local.nodes)
                victim = rng.choice(hosts)
                act["node"] = victim
                local.partition([victim], [n for n in hosts if n != victim])
            elif kind == "heal_pod":
                local.heal()
            elif kind == "global_adversary":
                act.update(drop=round(rng.uniform(0.0, 0.3), 3),
                           ms=rng.choice([500.0, 1500.0]))
                h.set_global_adversary(Adversary(
                    seed=rng.randint(1, 2**30), drop_p=act["drop"],
                    until=h.sim.now + act["ms"],
                ))
            elif kind == "global_adversary_off":
                h.set_global_adversary(None)
            actions.append(act)
            for pd in h.pod_ids:
                check_kv_consistency(h.pods[pd])
                check_read_oracle(h.pods[pd], writes[pd])
    except AssertionError as e:
        ok, error, failed_at = False, f"step: {e}", len(actions) - 1
    if ok:
        try:
            # Recovery: lift every fault, settle, and drain the read
            # backlog. One leader-mode read per pod forces the lazy
            # __noop__ barrier, which is also what re-certifies a
            # watermark after leader churn on an idle pod — pending
            # linearizable replica reads drain behind it.
            h.set_global_adversary(None)
            for pod in h.pod_ids:
                local = h.pods[pod]
                local.heal()
                for nid, node in local.nodes.items():
                    if not node.alive:
                        node.restart(h.sim.now)
            h.run(2_000)
            for pod in h.pod_ids:
                h.read_pod(pod, "GET __settle__")
            h.run(8_000)
            for pod in h.pod_ids:
                check_kv_consistency(h.pods[pod])
                n_reads_checked += check_read_oracle(h.pods[pod], writes[pod])
            h.check_consistency()
        except AssertionError as e:
            ok, error = False, f"recovery: {e}"
    n_commits = sum(
        len(h.pods[pod].metrics.committed_at) for pod in h.pod_ids
    )
    report = FuzzReport(
        ok=ok, error=error, failed_at_step=failed_at, n_ops=len(actions),
        n_commits=n_commits, n_reads_checked=n_reads_checked,
    )
    artifact = {
        "version": TRACE_VERSION,
        "seed": seed,
        "kind": "hierarchy_sweep",
        "profile": p.to_dict(),
        "actions": actions,
        "error": error,
    }
    return artifact, report


# ---------------------------------------------------------------------- CLI


def _parse_seeds(spec: str) -> List[int]:
    out: List[int] = []
    for part in spec.split(","):
        part = part.strip()
        if "-" in part[1:]:
            lo, hi = part.split("-", 1)
            out.extend(range(int(lo), int(hi) + 1))
        elif part:
            out.append(int(part))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seeds", default="1-10", help="e.g. 3 or 1,2,9 or 1-20")
    ap.add_argument("--steps", type=int, default=40, help="ops per seed")
    ap.add_argument("--out", default="artifacts/fuzz", help="failing-trace dir")
    ap.add_argument("--no-shrink", action="store_true")
    ap.add_argument("--json", metavar="PATH", help="write run summary JSON")
    ap.add_argument(
        "--coalesce-window", type=float, default=0.0, metavar="MS",
        help="run with RaftConfig.read_coalesce_window=MS (0 = off)",
    )
    ap.add_argument(
        "--election-noop", action="store_true",
        help="run with RaftConfig.election_noop (eager per-term barrier)",
    )
    ap.add_argument(
        "--engine", choices=("slotted", "legacy"), default="slotted",
        help="simulator event engine (schedules are byte-identical; legacy "
        "exists for equivalence gating and performance baselines)",
    )
    ap.add_argument(
        "--hierarchy", action="store_true",
        help="run the hierarchy-level sweep (3 pods, pod-leader crashes, "
        "intra-pod partitions, global-link adversaries, all read modes) "
        "instead of flat-cluster trace fuzzing",
    )
    ap.add_argument(
        "--failure-profile", default="",
        choices=("", "crashy", "slow-cpu", "flaky-links", "mixed"),
        help="install a named FailureProfile preset on every node at setup "
        "and let the fuzzer toggle/crash-group it mid-trace (flat mode only)",
    )
    ap.add_argument(
        "--witnesses", type=int, default=0, metavar="W",
        help="make the last W founding nodes quorum-only witnesses "
        "(flat mode only)",
    )
    ap.add_argument(
        "--link-profile", default="", choices=sorted(LINK_PROFILES),
        help="bandwidth-constrain every link with a named "
        "(bytes_per_ms, mtu_bytes) preset; '' = infinite capacity",
    )
    ap.add_argument(
        "--wire-frugal", action="store_true",
        help="run with RaftConfig.delta_snapshots + ack_piggyback on "
        "(the bandwidth-frugal stack, DESIGN.md section 13)",
    )
    args = ap.parse_args(argv)

    link_bpm, link_mtu = LINK_PROFILES[args.link_profile]
    profile = FuzzProfile(
        read_coalesce_window=args.coalesce_window,
        election_noop=args.election_noop,
        failure_profile=args.failure_profile,
        witnesses=args.witnesses,
        bytes_per_ms=link_bpm,
        mtu_bytes=link_mtu,
        delta_snapshots=args.wire_frugal,
        ack_piggyback=args.wire_frugal,
    )
    rows: List[Dict[str, Any]] = []
    failures = 0
    for seed in _parse_seeds(args.seeds):
        try:
            if args.hierarchy:
                trace, rep = hierarchy_sweep(
                    seed, steps=args.steps, profile=profile, engine=args.engine
                )
            else:
                fz = ProtocolFuzzer(
                    seed, steps=args.steps, profile=profile, engine=args.engine
                )
                trace, rep = fz.run()
        except Exception:  # an oracle escaped as a crash: still a failure
            failures += 1
            print(f"seed {seed}: CRASH\n{traceback.format_exc()}")
            rows.append({"seed": seed, "ok": False, "error": "crash"})
            continue
        row = {"seed": seed, **rep.to_dict()}
        rows.append(row)
        status = "ok" if rep.ok else f"FAIL ({rep.error})"
        print(
            f"seed {seed}: {status} · {rep.n_ops} ops · {rep.n_commits} commits "
            f"· {rep.leader_elections} elections · term<= {rep.max_term} "
            f"· {rep.n_reads_checked} reads checked"
        )
        if not rep.ok:
            failures += 1
            if not args.hierarchy and not args.no_shrink:
                trace, used = shrink(trace)
                print(
                    f"  shrunk to {len(trace['ops'])} ops in {used} replays; "
                    f"verdict: {replay(trace).error}"
                )
            name = ("hier-" if args.hierarchy else "") + f"seed{seed}.json"
            path = os.path.join(args.out, name)
            save_trace(trace, path)
            print(f"  trace saved: {path}")
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
    print(f"{len(rows)} seeds, {failures} failing")
    return 1 if failures else 0


if __name__ == "__main__":
    # The oracle suite lives under tests/ at the repo root (src/../..):
    # make `from tests.commit_history import ...` work for CLI runs that
    # only have src/ on PYTHONPATH.
    _repo_root = os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    )
    sys.path.insert(0, _repo_root)
    raise SystemExit(main())
