"""Version compatibility shims for the jax API surface we use.

The repo targets the image's pinned jax (0.4.37 today) while staying
forward-compatible with the stable APIs newer releases promote out of
``jax.experimental``. Keep every version branch here so call sites stay
clean.
"""
from __future__ import annotations

from typing import Optional

import jax


_partial_auto_ready = False


def ensure_partial_auto_partitioner() -> None:
    """Make partially-manual shard_map (manual DP x auto TP) compilable.

    Legacy jax's GSPMD path emits ``Sharding`` custom-calls without the
    manual-subgroup wrapper inside partial-manual regions, and the SPMD
    partitioner aborts the process on them (``Check failed:
    target.IsManualSubgroup() == sharding().IsManualSubgroup()``). The
    Shardy partitioner handles these correctly, so on legacy jax we flip it
    on (process-wide, once) before building such a computation. Newer jax
    (with ``jax.shard_map``) needs nothing.
    """
    global _partial_auto_ready
    if _partial_auto_ready or hasattr(jax, "shard_map"):
        _partial_auto_ready = True
        return
    jax.config.update("jax_use_shardy_partitioner", True)
    _partial_auto_ready = True


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized to a dict: some versions /
    partitioners return a per-device list instead."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost


def wsc_in_partial_manual_ok() -> bool:
    """Whether ``lax.with_sharding_constraint`` may be used inside a
    partially-manual shard_map body. On legacy jax's GSPMD path the
    constraint lowers without the manual-subgroup wrapper and trips an
    SPMD-partitioner check (``IsManualSubgroup`` mismatch), aborting the
    process. Fine on new jax, and on legacy jax once
    :func:`ensure_partial_auto_partitioner` has flipped to Shardy."""
    return hasattr(jax, "shard_map") or _partial_auto_ready


def axis_size(name) -> int:
    """``lax.axis_size`` with fallback to ``psum(1, name)`` for jax
    versions that predate it (the psum of a literal 1 folds to the static
    axis size inside shard_map/pmap)."""
    from jax import lax

    if hasattr(lax, "axis_size"):
        return lax.axis_size(name)
    return lax.psum(1, name)


def shard_map(f, mesh, in_specs, out_specs, axis_names=None,
              check_vma: Optional[bool] = None):
    """``jax.shard_map`` (>= 0.6 API) with fallback to
    ``jax.experimental.shard_map.shard_map`` (<= 0.4/0.5 API).

    ``axis_names`` is the set of mesh axes the body is MANUAL over (the new
    API's parameter); the legacy API expresses the same thing inversely via
    ``auto`` = all mesh axes not in ``axis_names``. ``check_vma`` maps to the
    legacy ``check_rep``.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)

    from jax.experimental.shard_map import shard_map as legacy_sm

    kwargs = {}
    if check_vma is not None:
        kwargs["check_rep"] = check_vma
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - set(axis_names)
        if auto:
            kwargs["auto"] = auto
    return legacy_sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
