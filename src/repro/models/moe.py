"""Mixture-of-Experts FFN: GShard-style top-k routing with capacity, expert
tensors shaped (E, ...) so expert parallelism is a PartitionSpec on the
leading axis ('model' by default, or a dedicated 'expert' axis).

Dispatch/combine use the dense one-hot einsum formulation (Lepikhin et al.):
tokens -> (E, C, d) buffers -> expert FFN -> weighted combine. The einsums
partition cleanly under pjit (all-to-all on (E, C) when experts are sharded)
and the capacity bound keeps FLOPs proportional to top_k, not n_experts.

Auxiliary losses: Switch-style load-balance loss and router z-loss, returned
to the caller to fold into the training objective.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import _dense_init

Params = Dict[str, Any]


def init_moe(cfg: ArchConfig, rng, dtype=jnp.bfloat16) -> Params:
    assert cfg.moe is not None
    E = cfg.moe.n_experts
    r = jax.random.split(rng, 4)

    def stack(key, in_dim, out_dim):
        ks = jax.random.split(key, E)
        return jnp.stack([_dense_init(k, in_dim, out_dim, dtype) for k in ks])

    # Expert weights carry an "_e" suffix so sharding rules can tell the
    # (E, d, f) expert tensors apart from a STACKED dense FFN (G, d, f).
    p: Params = {"router": _dense_init(r[0], cfg.d_model, E, jnp.float32)}
    if cfg.activation == "swiglu":
        p["w_gate_e"] = stack(r[1], cfg.d_model, cfg.d_ff)
        p["w_up_e"] = stack(r[2], cfg.d_model, cfg.d_ff)
        p["w_down_e"] = stack(r[3], cfg.d_ff, cfg.d_model)
    else:
        p["w_up_e"] = stack(r[1], cfg.d_model, cfg.d_ff)
        p["w_down_e"] = stack(r[2], cfg.d_ff, cfg.d_model)
    return p


def _capacity(cfg: ArchConfig, tokens: int) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * tokens * m.top_k / m.n_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def apply_moe(cfg: ArchConfig, p: Params, x: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, T, d) -> (y, aux_losses). Routing is per-token."""
    m = cfg.moe
    B, T, d = x.shape
    S = B * T
    E, K = m.n_experts, m.top_k
    C = _capacity(cfg, S)
    xt = x.reshape(S, d)

    logits = (xt.astype(jnp.float32) @ p["router"])  # (S, E), fp32 routing
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)  # (S, K)
    # Renormalize the chosen gates (standard for top-k > 1).
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # Position of each (token, k) within its expert's buffer, via cumsum over
    # the flattened (K, S) choice order (priority to k=0 choices).
    choice_onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # (S, K, E)
    flat = choice_onehot.transpose(1, 0, 2).reshape(K * S, E)
    pos_in_expert = jnp.cumsum(flat, axis=0) - flat  # (K*S, E)
    pos = jnp.sum(flat * pos_in_expert, axis=-1).reshape(K, S).transpose(1, 0)  # (S, K)
    keep = pos < C
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    if m.dispatch == "einsum":
        # GShard dense one-hot dispatch (kept as the reference/baseline —
        # O(S*E*C*d) FLOPs in the dispatch/combine einsums).
        pos_onehot = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=x.dtype)  # (S,K,C)
        dispatch = jnp.einsum("ske,skc->sec", choice_onehot.astype(x.dtype), pos_onehot)
        combine = jnp.einsum(
            "ske,skc,sk->sec",
            choice_onehot.astype(jnp.float32),
            pos_onehot.astype(jnp.float32),
            gate_vals,
        ).astype(x.dtype)
        xe = jnp.einsum("sd,sec->ecd", xt, dispatch)
    else:
        # Scatter dispatch (default): tokens land in their (expert, slot)
        # buffer via one scatter-add — O(S*K*d) data movement, no dispatch
        # matmul FLOPs (E/K x fewer than the one-hot form; §Perf iter 7).
        slot = expert_idx * C + pos.astype(jnp.int32)          # (S, K)
        slot = jnp.where(keep, slot, E * C)                    # drops -> sink row
        upd = jnp.repeat(xt, K, axis=0)                        # (S*K, d)
        xe_flat = jnp.zeros((E * C + 1, d), xt.dtype).at[slot.reshape(-1)].add(upd)
        xe = xe_flat[: E * C].reshape(E, C, d)

    if cfg.activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate_e"]))
        h = h * jnp.einsum("ecd,edf->ecf", xe, p["w_up_e"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, p["w_up_e"]))
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down_e"])

    if m.dispatch == "einsum":
        y = jnp.einsum("ecd,sec->sd", ye, combine)
    else:
        ye_flat = jnp.concatenate(
            [ye.reshape(E * C, d), jnp.zeros((1, d), ye.dtype)], axis=0
        )
        picked = ye_flat[slot]                                  # (S, K, d)
        y = jnp.einsum("skd,sk->sd", picked.astype(jnp.float32),
                       gate_vals).astype(xt.dtype)

    # Aux losses (Switch Transformer):
    me = jnp.mean(choice_onehot[:, 0, :], axis=0)          # fraction routed (top-1)
    pe = jnp.mean(probs, axis=0)                           # mean router prob
    aux = {
        "moe_load_balance": jnp.sum(me * pe) * E * m.aux_loss_coef,
        "moe_router_z": jnp.mean(
            jnp.square(jax.nn.logsumexp(logits, axis=-1))
        ) * m.router_z_coef,
    }
    return y.reshape(B, T, d), aux
