"""Public model API: ``build(config) -> Model`` with init / loss / forward /
prefill / decode_step — everything the runtime, dry-run and benchmarks use.

Batch conventions (see ``launch.dryrun.input_specs`` for the dry-run
stand-ins):
  train:   {"tokens": (B,T) i32, "labels": (B,T) i32}            (token archs)
           {"embeddings": (B,T,d) bf16, "labels": (B,T) i32}     (frontend archs)
  prefill: {"tokens"| "embeddings"}                  -> (last_logits, cache)
  decode:  {"tokens": (B,1)}, cache                  -> (logits,     cache)

The modality frontend for [audio]/[vlm] archs is a STUB per the assignment:
precomputed frame/patch embeddings enter where token embeddings would.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import transformer as T

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    dtype: Any = jnp.bfloat16

    # ------------------------------------------------------------------ init

    def init(self, rng) -> Params:
        r = jax.random.split(rng, 3)
        return {
            "embed": L.init_embedding(self.cfg, r[0], self.dtype),
            "stack": T.init_stack(self.cfg, r[1], self.dtype),
            "final_norm": L.init_norm(self.cfg, self.cfg.d_model),
        }

    # ----------------------------------------------------------- embeddings

    def _embed(self, params: Params, batch: Dict[str, jax.Array],
               pos_offset: jax.Array | int = 0) -> jax.Array:
        if "embeddings" in batch:
            h = batch["embeddings"].astype(self.dtype)
        else:
            h = L.embed_lookup(params["embed"]["tok"], batch["tokens"])
        if self.cfg.pos == "learned":
            B, Tn = h.shape[:2]
            idx = jnp.arange(Tn) + pos_offset
            h = h + L.embed_lookup(params["embed"]["pos"], idx)[None]
        return h

    def _head(self, params: Params, h: jax.Array) -> jax.Array:
        if self.cfg.tie_embeddings:
            return h @ params["embed"]["tok"].T
        return h @ params["embed"]["head"]

    # -------------------------------------------------------------- forward

    def forward(self, params: Params, batch: Dict[str, jax.Array],
                train: bool = False, gather_fn=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        h = self._embed(params, batch)
        h, aux, _ = T.apply_stack(self.cfg, params["stack"], h, train=train,
                                  gather_fn=gather_fn)
        h = L.apply_norm(self.cfg, params["final_norm"], h)
        logits = self._head(params, h)
        return logits, aux

    def loss(self, params: Params, batch: Dict[str, jax.Array],
             gather_fn=None) -> Tuple[jax.Array, Dict]:
        logits, aux = self.forward(params, batch, train=True, gather_fn=gather_fn)
        labels = batch["labels"]
        lf = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
        mask = batch.get("loss_mask")
        if mask is None:
            ce = jnp.mean(nll)
        else:
            ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        total = ce + sum(aux.values())
        metrics = {"ce": ce, **aux}
        return total, metrics

    # -------------------------------------------------------------- serving

    def init_cache(self, batch_size: int, max_len: int) -> Params:
        return {
            "layers": T.init_stack_cache(self.cfg, batch_size, max_len, self.dtype),
            "pos": jnp.zeros((), jnp.int32),
        }

    def prefill(self, params: Params, batch: Dict[str, jax.Array],
                max_len: int) -> Tuple[jax.Array, Params]:
        """Parallel prompt pass that also populates decode caches: attention
        layers write prompt K/V into cache slots [0, T); recurrent layers
        fold the prompt into their carried state through their chunked
        forms. Every mixer supports multi-token cached steps, so this is one
        fused forward (cache given, cache_pos=0), not T sequential steps."""
        B = (batch.get("tokens", batch.get("embeddings"))).shape[0]
        h = self._embed(params, batch)
        cache = self.init_cache(B, max_len)
        h, aux, new_layers = T.apply_stack(
            self.cfg, params["stack"], h,
            positions=None,
            caches=cache["layers"], cache_pos=jnp.zeros((), jnp.int32),
            train=False,
        )
        h = L.apply_norm(self.cfg, params["final_norm"], h)
        logits = self._head(params, h[:, -1:])[:, 0]
        Tn = (batch.get("tokens", batch.get("embeddings"))).shape[1]
        return logits, {"layers": new_layers, "pos": jnp.asarray(Tn, jnp.int32)}

    def decode_step(self, params: Params, cache: Params,
                    batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Params]:
        """One token for every sequence in the batch."""
        pos = cache["pos"]
        h = self._embed(params, batch, pos_offset=pos)
        h, _, new_layers = T.apply_stack(
            self.cfg, params["stack"], h,
            positions=None, caches=cache["layers"], cache_pos=pos, train=False,
        )
        h = L.apply_norm(self.cfg, params["final_norm"], h)
        logits = self._head(params, h[:, -1:])[:, 0]
        return logits, {"layers": new_layers, "pos": pos + h.shape[1]}


def build(cfg: ArchConfig, dtype=jnp.bfloat16) -> Model:
    return Model(cfg, dtype)
