"""Decoder stack: periodic layer groups scanned with stacked parameters.

Heterogeneous architectures (jamba's mamba/attn interleave, xlstm's
mlstm/slstm mix, MoE-every-other-layer) are handled by finding the smallest
repeating *period* of (block_kind, is_moe) signatures: parameters are
stacked over period repetitions and the repetitions are driven by
``lax.scan`` (small HLO, fast 512-device compiles), while the sublayers
inside one period are unrolled in the scan body. Dense homogeneous stacks
reduce to period=1, i.e. classic scan-over-layers.

Block structure:
  attn:   x += Attn(norm(x));  x += FFN/MoE(norm(x))    (if d_ff > 0)
  mamba:  x += Mamba(norm(x)); x += FFN/MoE(norm(x))    (if d_ff > 0)
  mlstm:  x += mLSTM(norm(x))          (integrated up/down projections)
  slstm:  x += sLSTM(norm(x))          (integrated 4/3 FFN)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

Params = Dict[str, Any]

AUX_KEYS = ("moe_load_balance", "moe_router_z")


def period_signature(cfg: ArchConfig) -> List[Tuple[str, bool]]:
    sig = list(zip(cfg.block_types(), cfg.moe_layer_mask()))
    n = len(sig)
    for p in range(1, n + 1):
        if n % p == 0 and sig == sig[:p] * (n // p):
            return sig[:p]
    return sig


def n_groups(cfg: ArchConfig) -> int:
    return cfg.n_layers // len(period_signature(cfg))


# ------------------------------------------------------------------- blocks


def init_block(cfg: ArchConfig, kind: str, is_moe: bool, rng, dtype) -> Params:
    r = jax.random.split(rng, 4)
    p: Params = {"norm1": L.init_norm(cfg, cfg.d_model)}
    if kind == "attn":
        p["mixer"] = L.init_attention(cfg, r[0], dtype)
    elif kind == "mamba":
        p["mixer"] = S.init_mamba(cfg, r[0], dtype)
    elif kind == "mlstm":
        p["mixer"] = S.init_mlstm(cfg, r[0], dtype)
    elif kind == "slstm":
        p["mixer"] = S.init_slstm(cfg, r[0], dtype)
    else:
        raise ValueError(kind)
    if cfg.d_ff > 0 and kind in ("attn", "mamba"):
        p["norm2"] = L.init_norm(cfg, cfg.d_model)
        p["ffn"] = M.init_moe(cfg, r[1], dtype) if is_moe else L.init_ffn(cfg, r[1], dtype)
    return p


def apply_block(
    cfg: ArchConfig,
    kind: str,
    is_moe: bool,
    p: Params,
    x: jax.Array,
    *,
    positions: Optional[jax.Array],
    cache: Optional[Params],
    cache_pos: Optional[jax.Array],
) -> Tuple[jax.Array, Dict[str, jax.Array], Optional[Params]]:
    aux = {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}
    h = L.apply_norm(cfg, p["norm1"], x)
    new_cache = cache
    if kind == "attn":
        y, new_cache = L.attention(
            cfg, p["mixer"], h, positions=positions, cache=cache, cache_pos=cache_pos
        )
    elif kind == "mamba":
        y, new_cache = S.apply_mamba(cfg, p["mixer"], h, state=cache)
    elif kind == "mlstm":
        y, new_cache = S.apply_mlstm(cfg, p["mixer"], h, state=cache)
    elif kind == "slstm":
        y, new_cache = S.apply_slstm(cfg, p["mixer"], h, state=cache)
    else:
        raise ValueError(kind)
    x = x + y
    if cfg.d_ff > 0 and kind in ("attn", "mamba"):
        h2 = L.apply_norm(cfg, p["norm2"], x)
        if is_moe:
            y2, moe_aux = M.apply_moe(cfg, p["ffn"], h2)
            aux = {k: aux[k] + moe_aux.get(k, 0.0) for k in AUX_KEYS}
        else:
            y2 = L.apply_ffn(cfg, p["ffn"], h2)
        x = x + y2
    return x, aux, new_cache


def init_block_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int, dtype) -> Params:
    if kind == "attn":
        return L.init_attn_cache(cfg, batch, max_len, dtype)
    if kind == "mamba":
        return S.init_mamba_state(cfg, batch, dtype)
    if kind == "mlstm":
        return S.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return S.init_slstm_state(cfg, batch)
    raise ValueError(kind)


# -------------------------------------------------------------------- stack


def init_stack(cfg: ArchConfig, rng, dtype) -> Params:
    sig = period_signature(cfg)
    G = n_groups(cfg)

    def init_group(key):
        ks = jax.random.split(key, len(sig))
        return {
            f"b{j}": init_block(cfg, kind, is_moe, ks[j], dtype)
            for j, (kind, is_moe) in enumerate(sig)
        }

    keys = jax.random.split(rng, G)
    groups = [init_group(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *groups)


def init_stack_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
    sig = period_signature(cfg)
    G = n_groups(cfg)
    one = {
        f"b{j}": init_block_cache(cfg, kind, batch, max_len, dtype)
        for j, (kind, is_moe) in enumerate(sig)
    }
    return jax.tree_util.tree_map(lambda a: jnp.stack([a] * G), one)


def apply_stack(
    cfg: ArchConfig,
    stack_params: Params,
    x: jax.Array,
    *,
    positions: Optional[jax.Array] = None,
    caches: Optional[Params] = None,
    cache_pos: Optional[jax.Array] = None,
    train: bool = False,
    gather_fn=None,
) -> Tuple[jax.Array, Dict[str, jax.Array], Optional[Params]]:
    """gather_fn (optional): FSDP weight streaming — applied to each group's
    parameter subtree INSIDE the scan body, so only one layer-group of full
    weights is live at a time (ZeRO-3). Its autodiff transpose produces the
    per-group reduce-scatter of gradients for free."""
    sig = period_signature(cfg)

    def group_body(carry, xs):
        x, aux = carry
        if caches is None:
            gp = xs
            gc = {f"b{j}": None for j in range(len(sig))}
        else:
            gp, gc = xs
        if gather_fn is not None:
            gp = gather_fn(gp)
        new_gc = {}
        for j, (kind, is_moe) in enumerate(sig):
            x, a, c = apply_block(
                cfg, kind, is_moe, gp[f"b{j}"], x,
                positions=positions, cache=gc[f"b{j}"], cache_pos=cache_pos,
            )
            aux = {k: aux[k] + a[k] for k in AUX_KEYS}
            new_gc[f"b{j}"] = c
        out = new_gc if caches is not None else None
        return (x, aux), out

    if train and cfg.remat != "none":
        policy = (
            jax.checkpoint_policies.checkpoint_dots
            if cfg.remat == "dots"
            else jax.checkpoint_policies.nothing_saveable
        )
        group_body = jax.checkpoint(group_body, policy=policy, prevent_cse=False)

    aux0 = {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}
    xs = stack_params if caches is None else (stack_params, caches)
    (x, aux), new_caches = jax.lax.scan(group_body, (x, aux0), xs)
    return x, aux, new_caches
