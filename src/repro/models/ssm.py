"""State-space / recurrent mixers: Mamba (selective SSM), mLSTM and sLSTM
(xLSTM). Each mixer exposes three entry points used by the stack:

  init_*(cfg, rng)                  -> params
  apply_*(cfg, p, x)                -> y                    (train / prefill)
  step_*(cfg, p, x_t, state)        -> (y_t, state)         (decode)
  init_*_state(cfg, batch)          -> state

All are TPU-shaped: the sequential dimension is processed in CHUNKS with a
recurrent carry between chunks (lax.scan) and parallel math inside a chunk
(associative_scan / batched matmuls), which bounds peak activation memory by
the chunk size instead of the sequence length and keeps decode O(1) per
token — this is what qualifies xLSTM/Jamba for the 500k-token shape.
Stabilized exponential gating follows the xLSTM paper (appendix A):
everything passes through fp32 log-space with a running max stabilizer.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models.layers import _dense_init

Params = Dict[str, Any]


def _ssm(cfg: ArchConfig) -> SSMConfig:
    return cfg.ssm or SSMConfig()


# =============================================================== Mamba (S6)


def mamba_dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    s = _ssm(cfg)
    d_in = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return d_in, dt_rank, s.d_state


def init_mamba(cfg: ArchConfig, rng, dtype=jnp.bfloat16) -> Params:
    s = _ssm(cfg)
    d_in, dt_rank, N = mamba_dims(cfg)
    r = jax.random.split(rng, 6)
    # S4D-real initialization for A.
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (d_in, 1))
    dt = jnp.exp(
        jax.random.uniform(r[4], (d_in,), jnp.float32)
        * (math.log(0.1) - math.log(0.001))
        + math.log(0.001)
    )
    inv_softplus_dt = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": _dense_init(r[0], cfg.d_model, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(r[1], (s.d_conv, d_in), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": _dense_init(r[2], d_in, dt_rank + 2 * N, dtype),
        "dt_proj": _dense_init(r[3], dt_rank, d_in, dtype),
        "dt_bias": inv_softplus_dt,          # fp32
        "A_log": jnp.log(A),                 # fp32
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": _dense_init(r[5], d_in, cfg.d_model, dtype),
    }


def _mamba_conv(p: Params, x: jax.Array, state: jax.Array | None):
    """Causal depthwise conv along T. x: (B, T, d_in). state: (B, K-1, d_in)."""
    K = p["conv_w"].shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, T+K-1, d)
    out = sum(
        xp[:, k : k + x.shape[1], :] * p["conv_w"][k][None, None, :] for k in range(K)
    )
    new_state = xp[:, -(K - 1) :, :]
    return out + p["conv_b"][None, None, :], new_state


def _selective_scan_chunk(a: jax.Array, bx: jax.Array, h0: jax.Array):
    """h_t = a_t * h_{t-1} + bx_t within one chunk via associative scan.

    a, bx: (B, c, d_in, N) fp32; h0: (B, d_in, N). Returns (h_all, h_last).
    """

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    a0 = jnp.concatenate([jnp.ones_like(h0)[:, None], a], axis=1)
    b0 = jnp.concatenate([h0[:, None], bx], axis=1)
    _, h = jax.lax.associative_scan(combine, (a0, b0), axis=1)
    return h[:, 1:], h[:, -1]


def apply_mamba(
    cfg: ArchConfig,
    p: Params,
    x: jax.Array,
    state: Params | None = None,
    chunk: int | None = None,
):
    """Training / prefill / multi-token cached step. x: (B, T, d_model).
    Returns (y, new_state); new_state is None when state is None (training)."""
    s = _ssm(cfg)
    d_in, dt_rank, N = mamba_dims(cfg)
    B, T, _ = x.shape
    chunk = chunk or min(T, s.chunk_size)
    assert T % chunk == 0, (T, chunk)

    xz = x @ p["in_proj"]
    xb, z = jnp.split(xz, 2, axis=-1)
    xb, conv_state = _mamba_conv(p, xb, None if state is None else state["conv"])
    xb = jax.nn.silu(xb)

    dtbc = xb @ p["x_proj"]
    dt, Bm, Cm = jnp.split(dtbc, [dt_rank, dt_rank + N], axis=-1)
    delta = jax.nn.softplus(
        (dt @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"][None, None, :]
    )  # (B, T, d_in) fp32
    A = -jnp.exp(p["A_log"])  # (d_in, N)

    xb32 = xb.astype(jnp.float32)
    Bm32 = Bm.astype(jnp.float32)
    Cm32 = Cm.astype(jnp.float32)

    n_chunks = T // chunk

    def chunk_body(h, args):
        d_c, x_c, B_c, C_c = args  # (B, c, ...) fp32
        a = jnp.exp(d_c[..., None] * A[None, None])             # (B,c,d_in,N)
        bx = (d_c * x_c)[..., None] * B_c[:, :, None, :]        # (B,c,d_in,N)
        h_all, h_last = _selective_scan_chunk(a, bx, h)
        y = jnp.einsum("bcdn,bcn->bcd", h_all, C_c)
        return h_last, y

    args = tuple(
        t.reshape(B, n_chunks, chunk, -1).swapaxes(0, 1)
        for t in (delta, xb32, Bm32, Cm32)
    )
    h0 = jnp.zeros((B, d_in, N), jnp.float32) if state is None else state["h"]
    h_last, ys = jax.lax.scan(chunk_body, h0, args)
    y = ys.swapaxes(0, 1).reshape(B, T, d_in)
    y = y + xb32 * p["D"][None, None, :]
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    new_state = None if state is None else {"conv": conv_state, "h": h_last}
    return y, new_state


def init_mamba_state(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> Params:
    s = _ssm(cfg)
    d_in, _, N = mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, d_in), dtype),
        "h": jnp.zeros((batch, d_in, N), jnp.float32),
    }


def step_mamba(cfg: ArchConfig, p: Params, x: jax.Array, state: Params):
    """Cached step (T >= 1): delegates to the chunked path with the carried
    state, which the parity tests pin against the pure recurrence."""
    return apply_mamba(cfg, p, x, state=state, chunk=x.shape[1])


# ================================================================== mLSTM


def mlstm_dims(cfg: ArchConfig) -> Tuple[int, int]:
    s = _ssm(cfg)
    d_in = int(s.proj_factor_mlstm * cfg.d_model)
    return d_in, d_in // cfg.n_heads


def init_mlstm(cfg: ArchConfig, rng, dtype=jnp.bfloat16) -> Params:
    d_in, dh = mlstm_dims(cfg)
    H = cfg.n_heads
    r = jax.random.split(rng, 7)

    def block_diag(key):  # per-head BlockLinear, as in the xLSTM release
        ks = jax.random.split(key, H)
        return jnp.stack([
            (jax.random.normal(k2, (dh, dh), jnp.float32) / math.sqrt(dh)).astype(dtype)
            for k2 in ks
        ])

    return {
        "up": _dense_init(r[0], cfg.d_model, 2 * d_in, dtype),
        "wq_blk": block_diag(r[1]),
        "wk_blk": block_diag(r[2]),
        "wv_blk": block_diag(r[3]),
        "w_gates": _dense_init(r[4], cfg.d_model, 2 * H, jnp.float32),
        "b_gates": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]),
        "gn_scale": jnp.ones((d_in,), jnp.float32),
        "down": _dense_init(r[5], d_in, cfg.d_model, dtype),
    }


def init_mlstm_state(cfg: ArchConfig, batch: int) -> Params:
    d_in, dh = mlstm_dims(cfg)
    H = cfg.n_heads
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def _mlstm_chunk(q, k, v, li, lf, state):
    """Stabilized chunk-parallel mLSTM.

    q,k,v: (B,H,c,dh) fp32; li,lf: (B,H,c) fp32 log gates;
    state: dict(C,n,m). Returns (h (B,H,c,dh), new_state).
    """
    B, H, c, dh = q.shape
    F = jnp.cumsum(lf, axis=-1)                       # inclusive: sum_{r<=t} lf_r
    g = li - F                                        # g_s = li_s - F_s
    m_intra = jax.lax.cummax(g, axis=g.ndim - 1)      # max_{s<=t} g_s
    m_state = state["m"]                              # reference stabilizer
    m_t = F + jnp.maximum(m_state[..., None], m_intra)  # (B,H,c)

    # Intra-chunk decay weights: D_{ts} = exp(F_t + g_s - m_t) for s <= t.
    logD = F[..., :, None] + g[..., None, :] - m_t[..., :, None]
    mask = jnp.tril(jnp.ones((c, c), bool))
    D = jnp.where(mask[None, None], jnp.exp(logD), 0.0)
    kq = (q @ k.swapaxes(-1, -2)) / math.sqrt(dh)     # (B,H,t,s)
    scores = kq * D
    # Inter-chunk contribution of the carried state, same stabilization.
    w_in = jnp.exp(F + m_state[..., None] - m_t)      # (B,H,c)
    h_num = scores @ v + w_in[..., None] * jnp.einsum(
        "bhtd,bhde->bhte", q / math.sqrt(dh), state["C"]
    )
    # Normalizer n_t · q_t (k·q weighted by the same decays).
    nq_total = jnp.sum(scores, axis=-1) + w_in * jnp.einsum(
        "bhd,bhtd->bht", state["n"], q
    ) / math.sqrt(dh)
    denom = jnp.maximum(jnp.abs(nq_total), jnp.exp(-m_t))
    h = h_num / denom[..., None]

    # State update to end of chunk (t = c).
    F_c = F[..., -1:]                                 # (B,H,1)
    m_out = F_c[..., 0] + jnp.maximum(m_state, jnp.max(g, axis=-1))
    w_state = jnp.exp(F_c[..., 0] + m_state - m_out)  # (B,H)
    w_tok = jnp.exp(F_c + g - m_out[..., None])       # (B,H,c)
    C_out = w_state[..., None, None] * state["C"] + jnp.einsum(
        "bhs,bhsd,bhse->bhde", w_tok, k, v
    )
    n_out = w_state[..., None] * state["n"] + jnp.einsum("bhs,bhsd->bhd", w_tok, k)
    return h, {"C": C_out, "n": n_out, "m": m_out}


def apply_mlstm(cfg: ArchConfig, p: Params, x: jax.Array, state: Params | None = None):
    """Returns (y, new_state); new_state is None when state is None."""
    s = _ssm(cfg)
    d_in, dh = mlstm_dims(cfg)
    H = cfg.n_heads
    B, T, _ = x.shape
    c = min(T, s.chunk_size)
    assert T % c == 0

    up = x @ p["up"]
    xb, z = jnp.split(up, 2, axis=-1)
    xh = xb.reshape(B, T, H, dh)
    q = jnp.einsum("bthd,hde->bhte", xh, p["wq_blk"]).astype(jnp.float32)
    k = jnp.einsum("bthd,hde->bhte", xh, p["wk_blk"]).astype(jnp.float32)
    v = jnp.einsum("bthd,hde->bhte", xh, p["wv_blk"]).astype(jnp.float32)
    gates = x.astype(jnp.float32) @ p["w_gates"] + p["b_gates"][None, None]
    li, lf = jnp.split(gates, 2, axis=-1)             # (B,T,H)
    li = li.transpose(0, 2, 1)
    lf = jax.nn.log_sigmoid(lf.transpose(0, 2, 1))

    n_chunks = T // c

    def body(state, args):
        qc, kc, vc, lic, lfc = args
        h, new_state = _mlstm_chunk(qc, kc, vc, lic, lfc, state)
        return new_state, h

    def split(t):  # (B,H,T,...) -> (n_chunks,B,H,c,...)
        t = t.reshape(B, H, n_chunks, c, *t.shape[3:])
        return jnp.moveaxis(t, 2, 0)

    args = tuple(split(t) for t in (q, k, v, li, lf))
    state0 = init_mlstm_state(cfg, B) if state is None else state
    state_out, hs = jax.lax.scan(body, state0, args)
    h = jnp.moveaxis(hs, 0, 2).reshape(B, H, T, dh)
    h = h.transpose(0, 2, 1, 3).reshape(B, T, d_in)

    # Headwise group norm, output gate, down projection.
    h = _groupnorm(h, H, p["gn_scale"]).astype(x.dtype)
    y = (h * jax.nn.silu(z)) @ p["down"]
    return y, (None if state is None else state_out)


def _groupnorm(h: jax.Array, n_groups: int, scale: jax.Array, eps=1e-6) -> jax.Array:
    B, T, d = h.shape
    hg = h.reshape(B, T, n_groups, d // n_groups).astype(jnp.float32)
    mu = jnp.mean(hg, axis=-1, keepdims=True)
    var = jnp.var(hg, axis=-1, keepdims=True)
    hn = (hg - mu) * jax.lax.rsqrt(var + eps)
    return hn.reshape(B, T, d) * scale[None, None].astype(jnp.float32)


def step_mlstm(cfg: ArchConfig, p: Params, x: jax.Array, state: Params):
    """Cached step (T >= 1) via the chunked path."""
    return apply_mlstm(cfg, p, x, state=state)


# ================================================================== sLSTM


def init_slstm(cfg: ArchConfig, rng, dtype=jnp.bfloat16) -> Params:
    s = _ssm(cfg)
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    d_ff = int(s.proj_factor_slstm * d)
    r = jax.random.split(rng, 12)
    p: Params = {"gn_scale": jnp.ones((d,), jnp.float32)}
    for i, name in enumerate(("i", "f", "z", "o")):
        p[f"w_{name}"] = _dense_init(r[i], d, d, dtype)
        # Block-diagonal (per-head) recurrent matrices, as in the paper.
        p[f"r_{name}"] = (
            jax.random.normal(r[4 + i], (H, dh, dh), jnp.float32) / math.sqrt(dh)
        ).astype(jnp.float32)
        p[f"b_{name}"] = jnp.zeros((d,), jnp.float32)
    p["b_f"] = p["b_f"] + 3.0  # forget-gate bias init
    # Post-block gated FFN (proj factor 4/3), part of the sLSTM block.
    p["ff_up"] = _dense_init(r[8], d, 2 * d_ff, dtype)
    p["ff_down"] = _dense_init(r[9], d_ff, d, dtype)
    return p


def init_slstm_state(cfg: ArchConfig, batch: int) -> Params:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -1e30, jnp.float32),
    }


def _slstm_cell(cfg: ArchConfig, p: Params, xw: Params, state: Params):
    """One timestep. xw: precomputed input projections {i,f,z,o}: (B, d)."""
    H = cfg.n_heads
    d = cfg.d_model
    dh = d // H
    B = state["h"].shape[0]

    def rec(name):
        hh = state["h"].reshape(B, H, dh)
        return jnp.einsum("bhd,hde->bhe", hh, p[f"r_{name}"]).reshape(B, d)

    it = xw["i"] + rec("i") + p["b_i"]
    ft = xw["f"] + rec("f") + p["b_f"]
    zt = jnp.tanh(xw["z"] + rec("z") + p["b_z"])
    ot = jax.nn.sigmoid(xw["o"] + rec("o") + p["b_o"])
    lf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(lf + state["m"], it)
    i_bar = jnp.exp(it - m_new)
    f_bar = jnp.exp(lf + state["m"] - m_new)
    c_new = f_bar * state["c"] + i_bar * zt
    n_new = f_bar * state["n"] + i_bar
    h_new = ot * c_new / jnp.maximum(n_new, 1.0)
    return h_new, {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def apply_slstm(cfg: ArchConfig, p: Params, x: jax.Array, state: Params | None = None):
    """Returns (y, new_state); new_state is None when state is None."""
    B, T, d = x.shape
    xf = x.astype(jnp.float32)
    xw = {k: (xf @ p[f"w_{k}"].astype(jnp.float32)) for k in ("i", "f", "z", "o")}

    def body(st, t_slices):
        h, new_st = _slstm_cell(cfg, p, t_slices, st)
        return new_st, h

    seq = {k: v.swapaxes(0, 1) for k, v in xw.items()}  # (T, B, d)
    state0 = init_slstm_state(cfg, B) if state is None else state
    state_out, hs = jax.lax.scan(body, state0, seq)
    h = hs.swapaxes(0, 1)  # (B, T, d)
    h = _groupnorm(h, cfg.n_heads, p["gn_scale"]).astype(x.dtype)
    up, gate = jnp.split(h @ p["ff_up"], 2, axis=-1)
    y = (jax.nn.gelu(up) * gate) @ p["ff_down"]
    return y, (None if state is None else state_out)


def step_slstm(cfg: ArchConfig, p: Params, x: jax.Array, state: Params):
    """Cached step (T >= 1) via the scan path."""
    return apply_slstm(cfg, p, x, state=state)
