"""Shared neural layers: norms, RoPE, GQA attention (train/prefill/decode),
and dense FFNs. Pure functions over parameter pytrees; no framework.

Conventions:
  x:      (B, T, d_model) activations, compute dtype bf16 by default
  params: nested dicts of jnp arrays
  cache:  {"k": (B, S, Hkv, Dh), "v": (B, S, Hkv, Dh)} per attention layer
Softmax/norm statistics are computed in fp32 regardless of compute dtype.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------- init utils


def _dense_init(rng, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(rng, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def _embed_init(rng, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(rng, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------- norms


def init_norm(cfg: ArchConfig, dim: int, dtype=jnp.float32) -> Params:
    p = {"scale": jnp.ones((dim,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def apply_norm(cfg: ArchConfig, p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMS norm over the head dim (Qwen3 qk_norm)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------- RoPE


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, Dh); positions: (B, T) or (T,)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (Dh/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, T, Dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention


def init_attention(cfg: ArchConfig, rng, dtype=jnp.bfloat16) -> Params:
    r = jax.random.split(rng, 5)
    p: Params = {
        "wq": _dense_init(r[0], cfg.d_model, cfg.q_dim, dtype),
        "wk": _dense_init(r[1], cfg.d_model, cfg.kv_dim, dtype),
        "wv": _dense_init(r[2], cfg.d_model, cfg.kv_dim, dtype),
        "wo": _dense_init(r[3], cfg.q_dim, cfg.d_model, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
    return p


def _project_qkv(cfg: ArchConfig, p: Params, x: jax.Array, positions: jax.Array):
    B, T, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"])
        k = rms_head_norm(k, p["k_norm"])
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, *, causal: bool, q_offset: int | jax.Array = 0,
          kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Reference scaled-dot-product attention with GQA.

    q: (B, Tq, Hq, Dh); k, v: (B, Tk, Hkv, Dh). fp32 softmax.
    kv_len: optional (B,) valid-length mask for cached decode.
    """
    B, Tq, Hq, Dh = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    qf = q.astype(jnp.float32) / math.sqrt(Dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(B, Tq, Hkv, group, Dh)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf)
    neg = jnp.asarray(-1e30, jnp.float32)
    if causal:
        off = jnp.asarray(q_offset)
        off = jnp.broadcast_to(off.reshape(-1), (B,))  # per-batch offset
        qpos = jnp.arange(Tq)[None, :] + off[:, None]  # (B, Tq)
        kpos = jnp.arange(Tk)
        mask = qpos[:, :, None] >= kpos[None, None, :]  # (B, Tq, Tk)
        scores = jnp.where(mask[:, None, None], scores, neg)
    if kv_len is not None:
        valid = jnp.arange(Tk)[None, :] < kv_len[:, None]  # (B, Tk)
        scores = jnp.where(valid[:, None, None, None], scores, neg)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, vf)
    return out.reshape(B, Tq, Hq, Dh).astype(q.dtype)


def _chunked_attention(
    q, k, v, *, causal: bool, q_offset=None, kv_len=None,
    blk_q: int = 512, blk_k: int = 1024,
) -> jax.Array:
    """Flash-style attention in pure jnp: double lax.scan with online
    softmax, fp32 accumulators, O(blk_q * blk_k) live scores. This is the
    memory- and FLOP-shape the Pallas kernel has on TPU, expressed portably —
    the dry-run lowers this, so compile-time memory analysis reflects the
    production tiling. Wrapped in remat(nothing_saveable): the backward
    recomputes tiles exactly like the flash backward kernel."""
    B, Tq, Hq, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    bq = min(blk_q, Tq)
    bk = min(blk_k, Tk)
    assert Tq % bq == 0 and Tk % bk == 0
    nq, nk = Tq // bq, Tk // bk
    scale = 1.0 / math.sqrt(D)
    if q_offset is None:
        q_offset = jnp.zeros((B,), jnp.int32)
    q_offset = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32).reshape(-1), (B,))

    qf = (q.astype(jnp.float32) * scale).reshape(B, nq, bq, Hkv, G, D)
    kf = k.astype(jnp.float32).reshape(B, nk, bk, Hkv, D)
    vf = v.astype(jnp.float32).reshape(B, nk, bk, Hkv, D)

    def q_chunk(qi, q_blk):
        # q_blk: (B, bq, Hkv, G, D)
        qpos = q_offset[:, None] + qi * bq + jnp.arange(bq)[None, :]  # (B,bq)

        def k_chunk(carry, args):
            ki, k_blk, v_blk = args
            m, l, acc = carry
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk)
            kpos = ki * bk + jnp.arange(bk)  # (bk,)
            neg = jnp.asarray(-1e30, jnp.float32)
            if causal:
                msk = qpos[:, :, None] >= kpos[None, None, :]  # (B,bq,bk)
                s = jnp.where(msk[:, None, None], s, neg)      # (B,1,1,bq,bk)
            if kv_len is not None:
                valid = kpos[None, :] < kv_len[:, None]  # (B,bk)
                s = jnp.where(valid[:, None, None, None, :], s, neg)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, v_blk
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, bq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, bq, D), jnp.float32)
        ks = (jnp.arange(nk), kf.swapaxes(0, 1), vf.swapaxes(0, 1))
        (m, l, acc), _ = jax.lax.scan(k_chunk, (m0, l0, a0), ks)
        out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,Hkv,G,bq,D)
        return out.transpose(0, 3, 1, 2, 4)                   # (B,bq,Hkv,G,D)

    outs = jax.lax.map(lambda args: q_chunk(*args),
                       (jnp.arange(nq), qf.swapaxes(0, 1)))   # (nq,B,bq,Hkv,G,D)
    out = outs.swapaxes(0, 1).reshape(B, Tq, Hq, D)
    return out.astype(q.dtype)


@functools.lru_cache(maxsize=None)
def _chunked_remat(causal: bool, has_kvlen: bool, blk_q: int, blk_k: int):
    """Static-config wrapper (jax.checkpoint traces kwargs, so bools must be
    closed over, not passed)."""

    def f(q, k, v, q_offset, kv_len):
        return _chunked_attention(
            q, k, v, causal=causal, q_offset=q_offset,
            kv_len=kv_len if has_kvlen else None, blk_q=blk_q, blk_k=blk_k,
        )

    return jax.checkpoint(
        f, policy=jax.checkpoint_policies.nothing_saveable, prevent_cse=False
    )


def chunked_attention(q, k, v, *, causal, q_offset=None, kv_len=None,
                      blk_q=512, blk_k=1024):
    B = q.shape[0]
    qo = (jnp.zeros((B,), jnp.int32) if q_offset is None
          else jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32).reshape(-1), (B,)))
    kl = (jnp.zeros((B,), jnp.int32) if kv_len is None
          else jnp.asarray(kv_len, jnp.int32))
    f = _chunked_remat(bool(causal), kv_len is not None, blk_q, blk_k)
    return f(q, k, v, qo, kl)


CHUNKED_ATTN_THRESHOLD = 1024  # use tiled path at/above this many kv tokens


def attention(
    cfg: ArchConfig,
    p: Params,
    x: jax.Array,
    *,
    positions: Optional[jax.Array] = None,
    cache: Optional[Params] = None,
    cache_pos: Optional[jax.Array] = None,
    learned_pos_table: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    """Full attention: training/prefill when cache is None, one-step decode
    when cache is given (x has T=1; cache_pos is the write index (B,) or
    scalar)."""
    B, T, _ = x.shape
    if positions is None:
        if cache is None:
            positions = jnp.arange(T)[None, :].repeat(B, 0)
        else:
            cp = jnp.broadcast_to(
                jnp.asarray(cache_pos, jnp.int32).reshape(-1), (B,)
            )
            positions = cp[:, None] + jnp.arange(T)[None, :]
    q, k, v = _project_qkv(cfg, p, x, positions)

    if cache is None:
        if cfg.use_flash:
            from repro.kernels import ops as kops

            out = kops.flash_attention(q, k, v, causal=True)
        elif T >= CHUNKED_ATTN_THRESHOLD:
            out = chunked_attention(q, k, v, causal=True)
        else:
            out = _sdpa(q, k, v, causal=True)
        new_cache = None
    else:
        idx = jnp.broadcast_to(jnp.asarray(cache_pos, jnp.int32).reshape(-1), (B,))
        k_cache = jax.vmap(lambda c, kn, i: jax.lax.dynamic_update_slice(c, kn, (i, 0, 0)))(
            cache["k"], k, idx
        )
        v_cache = jax.vmap(lambda c, vn, i: jax.lax.dynamic_update_slice(c, vn, (i, 0, 0)))(
            cache["v"], v, idx
        )
        # Causal over the cache: query t (global position idx+t) sees keys
        # [0, idx+t]; kv_len hides never-written slots.
        if k_cache.shape[1] >= CHUNKED_ATTN_THRESHOLD:
            out = chunked_attention(
                q, k_cache, v_cache, causal=True, q_offset=idx, kv_len=idx + T,
                blk_q=min(512, T), blk_k=1024,
            )
        else:
            out = _sdpa(q, k_cache, v_cache, causal=True, q_offset=idx, kv_len=idx + T)
        new_cache = {"k": k_cache, "v": v_cache}

    y = out.reshape(B, T, cfg.q_dim) @ p["wo"]
    return y, new_cache


def init_attn_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ----------------------------------------------------------------------- FFN


def init_ffn(cfg: ArchConfig, rng, dtype=jnp.bfloat16) -> Params:
    r = jax.random.split(rng, 3)
    if cfg.activation == "swiglu":
        return {
            "w_gate": _dense_init(r[0], cfg.d_model, cfg.d_ff, dtype),
            "w_up": _dense_init(r[1], cfg.d_model, cfg.d_ff, dtype),
            "w_down": _dense_init(r[2], cfg.d_ff, cfg.d_model, dtype),
        }
    return {
        "w_up": _dense_init(r[0], cfg.d_model, cfg.d_ff, dtype),
        "b_up": jnp.zeros((cfg.d_ff,), dtype),
        "w_down": _dense_init(r[1], cfg.d_ff, cfg.d_model, dtype),
        "b_down": jnp.zeros((cfg.d_model,), dtype),
    }


def apply_ffn(cfg: ArchConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.activation == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"] + p["b_up"])
    return h @ p["w_down"] + p["b_down"]


# ----------------------------------------------------------------- embedding


def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    """table[tokens] with an explicit f32 scatter-add backward.

    Two reasons this is not a plain gather: (1) fp32 gradient accumulation
    into the (large, shared) embedding table regardless of compute dtype;
    (2) the autodiff transpose-of-gather emits a copy-rooted scatter
    reduction whose bf16 all-reduce XLA:CPU's AllReducePromotion pass cannot
    clone (hard CHECK crash) — the explicit formulation lowers cleanly on
    every backend and shards identically (vocab-parallel)."""
    return _embed_lookup(tuple(table.shape), jnp.dtype(table.dtype).name,
                         table, tokens)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _embed_lookup(shape, dtype_name, table, tokens):
    return table[tokens]


def _embed_lookup_fwd(shape, dtype_name, table, tokens):
    return table[tokens], tokens


def _embed_lookup_bwd(shape, dtype_name, tokens, dout):
    flat_tok = tokens.reshape(-1)
    flat_dout = dout.reshape(-1, shape[-1]).astype(jnp.float32)
    dtable = jnp.zeros(shape, jnp.float32).at[flat_tok].add(flat_dout)
    return dtable.astype(dtype_name), None


_embed_lookup.defvjp(_embed_lookup_fwd, _embed_lookup_bwd)


def init_embedding(cfg: ArchConfig, rng, dtype=jnp.bfloat16) -> Params:
    r = jax.random.split(rng, 3)
    p: Params = {"tok": _embed_init(r[0], cfg.vocab_size, cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["head"] = _dense_init(r[1], cfg.d_model, cfg.vocab_size, dtype)
    if cfg.pos == "learned":
        p["pos"] = _embed_init(r[2], cfg.max_seq_len, cfg.d_model, dtype)
    return p
