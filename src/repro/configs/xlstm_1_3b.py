"""xlstm-1.3b [ssm]: 48L d_model=2048 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks at the paper's 7:1 ratio. [arXiv:2405.04517; unverified]
Blocks carry their own projections (mLSTM pf=2, sLSTM pf=4/3), hence
d_ff=0: no separate FFN sublayer. Recurrent state => sub-quadratic decode,
eligible for long_500k."""
from repro.configs.base import ArchConfig, SSMConfig

_PATTERN = tuple(("mlstm",) * 7 + ("slstm",)) * 6  # 48 layers, 7:1

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50_304, head_dim=512,
    block_pattern=_PATTERN, ssm=SSMConfig(chunk_size=256),
    pos="none", norm="layernorm", sub_quadratic=True, tie_embeddings=True,
)

REDUCED = ArchConfig(
    name="xlstm-1.3b-reduced", family="ssm",
    n_layers=8, d_model=32, n_heads=2, n_kv_heads=2, d_ff=0,
    vocab_size=128, head_dim=16,
    block_pattern=tuple(("mlstm",) * 7 + ("slstm",)),
    ssm=SSMConfig(chunk_size=8),
    pos="none", norm="layernorm", sub_quadratic=True, tie_embeddings=True,
)
