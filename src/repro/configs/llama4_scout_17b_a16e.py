"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16e top-1 — MoE, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
Note: routed experts only (16e top-1) per the assignment line; the shared
expert of the HF release is not modeled (recorded in DESIGN.md)."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab_size=202_048, head_dim=128,
    moe=MoEConfig(n_experts=16, top_k=1),
    activation="swiglu", norm="rmsnorm", pos="rope", rope_theta=500_000.0,
)

REDUCED = ArchConfig(
    name="llama4-scout-17b-a16e-reduced", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab_size=256, head_dim=16,
    moe=MoEConfig(n_experts=4, top_k=1, capacity_factor=8.0),  # drop-free at test scale
    activation="swiglu", norm="rmsnorm", pos="rope",
)
