"""Assigned input shapes (LM family): seq_len x global_batch per shape.

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache / recurrent state of seq_len), NOT ``train_step``. ``long_500k``
requires sub-quadratic decode state and is only run for SSM/hybrid archs
(cfg.sub_quadratic); full-attention archs record a documented skip.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True
