"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig

_MODULES = {
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "qwen1.5-4b": "repro.configs.qwen15_4b",
    "qwen3-1.7b": "repro.configs.qwen3_1_7b",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "musicgen-large": "repro.configs.musicgen_large",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
}


def list_archs() -> List[str]:
    return sorted(_MODULES)


def get(name: str, reduced: bool = False) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    mod = importlib.import_module(_MODULES[name])
    return mod.REDUCED if reduced else mod.CONFIG
