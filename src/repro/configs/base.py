"""Architecture configuration schema for the model zoo.

Every assigned architecture is a single frozen dataclass in its own module
under ``repro.configs``; the registry maps ``--arch <id>`` to it. Reduced
variants (same family, tiny dims) back the CPU smoke tests; the full configs
are exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    every_n_layers: int = 1        # MoE FFN on layers where (l % every_n == every_n-1)
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2
    # "scatter" (default): one scatter-add dispatch + gather combine,
    # O(S*K*d) movement. "einsum": GShard dense one-hot (O(S*E*C*d) FLOPs),
    # kept as the reference baseline. Numerically identical routing.
    dispatch: str = "scatter"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    # mamba
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # default ceil(d_model/16)
    # xlstm
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 4.0 / 3.0
    chunk_size: int = 256          # chunked-parallel mLSTM


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # Attention / block features.
    qkv_bias: bool = False
    qk_norm: bool = False
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    activation: str = "swiglu"     # swiglu | gelu
    pos: str = "rope"              # rope | learned | none
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # Per-layer block types; empty => all "attn". Entries: attn | mamba |
    # mlstm | slstm. Length must equal n_layers when set.
    block_pattern: Tuple[str, ...] = ()

    # Modality frontend stub: None | "audio" | "vision". When set, inputs are
    # precomputed frame/patch embeddings of width d_model (assignment rule).
    frontend: Optional[str] = None

    max_seq_len: int = 32_768
    # Sub-quadratic decode state => eligible for the long_500k shape.
    sub_quadratic: bool = False

    # Training-time knobs.
    remat: str = "dots"            # none | dots | full
    scan_layers: bool = True
    use_flash: bool = False        # Pallas path (TPU); ref path on CPU

    def block_types(self) -> Tuple[str, ...]:
        if self.block_pattern:
            assert len(self.block_pattern) == self.n_layers
            return self.block_pattern
        return ("attn",) * self.n_layers

    def moe_layer_mask(self) -> Tuple[bool, ...]:
        if self.moe is None:
            return (False,) * self.n_layers
        k = self.moe.every_n_layers
        return tuple((l % k) == (k - 1) for l in range(self.n_layers))

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        c = self
        total = c.vocab_size * c.d_model  # embed
        if not c.tie_embeddings:
            total += c.vocab_size * c.d_model  # lm head
        if c.pos == "learned":
            total += c.max_seq_len * c.d_model
        moe_mask = c.moe_layer_mask()
        for l, kind in enumerate(c.block_types()):
            if kind == "attn":
                total += c.d_model * (c.q_dim + 2 * c.kv_dim) + c.q_dim * c.d_model
                if c.qkv_bias:
                    total += c.q_dim + 2 * c.kv_dim
                total += 2 * c.d_model  # norms
                total += self._ffn_params(moe_mask[l])
            elif kind == "mamba":
                s = c.ssm or SSMConfig()
                d_in = s.expand * c.d_model
                dt_rank = s.dt_rank or -(-c.d_model // 16)
                total += c.d_model * 2 * d_in            # in_proj
                total += d_in * s.d_conv                 # conv
                total += d_in * (dt_rank + 2 * s.d_state)  # x_proj
                total += dt_rank * d_in + d_in           # dt_proj
                total += d_in * s.d_state + d_in         # A_log, D
                total += d_in * c.d_model                # out_proj
                total += c.d_model                       # norm
                total += self._ffn_params(moe_mask[l])
            elif kind in ("mlstm", "slstm"):
                s = c.ssm or SSMConfig()
                pf = s.proj_factor_mlstm if kind == "mlstm" else 1.0
                d_in = int(pf * c.d_model)
                if kind == "mlstm":
                    total += c.d_model * 2 * d_in        # up (2 branches)
                    total += 3 * d_in * d_in // c.n_heads  # q,k,v per-head BlockLinear
                    total += c.d_model * 2 * c.n_heads   # i,f gate projections
                    total += d_in * c.d_model            # down
                else:
                    total += 4 * c.d_model * c.d_model   # i,f,z,o
                    total += 2 * int(c.d_model * s.proj_factor_slstm) * c.d_model
                total += 2 * c.d_model
        return total

    def _ffn_params(self, is_moe: bool) -> int:
        c = self
        if c.d_ff == 0:
            return 0
        n_mats = 3 if c.activation == "swiglu" else 2
        per_expert = n_mats * c.d_model * c.d_ff
        if is_moe and c.moe is not None:
            return c.moe.n_experts * per_expert + c.d_model * c.moe.n_experts
        return per_expert

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        c = self
        total = self.param_count()
        moe_layers = sum(self.moe_layer_mask())
        n_mats = 3 if c.activation == "swiglu" else 2
        per_expert = n_mats * c.d_model * c.d_ff
        total -= moe_layers * (c.moe.n_experts - c.moe.top_k) * per_expert
        return total
