"""musicgen-large [audio]: 48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048
— decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]
Backbone only per the assignment: the EnCodec frontend is a STUB —
input_specs() provides precomputed frame embeddings. LayerNorm + GELU +
learned positions, as in the HF release."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=2048, head_dim=64,
    activation="gelu", norm="layernorm", pos="learned",
    frontend="audio", max_seq_len=32_768,
)

REDUCED = ArchConfig(
    name="musicgen-large-reduced", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
    vocab_size=128, head_dim=16,
    activation="gelu", norm="layernorm", pos="learned",
    frontend="audio", max_seq_len=512,
)
