"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE every other
layer. [arXiv:2403.19887; hf]
Period of 8: attention at offset 4, mamba elsewhere; MoE FFN on odd
offsets. Mamba state + 4 attention KV caches => sub-quadratic overall,
eligible for long_500k."""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig

_PERIOD = tuple("attn" if i == 4 else "mamba" for i in range(8))

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14_336,
    vocab_size=65_536, head_dim=128,
    block_pattern=_PERIOD * 4,
    moe=MoEConfig(n_experts=16, top_k=2, every_n_layers=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, chunk_size=256),
    activation="swiglu", norm="rmsnorm", pos="none",
    sub_quadratic=True,
)

REDUCED = ArchConfig(
    name="jamba-v0.1-52b-reduced", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab_size=256, head_dim=16,
    block_pattern=_PERIOD,
    moe=MoEConfig(n_experts=4, top_k=2, every_n_layers=2, capacity_factor=8.0),  # drop-free at test scale
    ssm=SSMConfig(d_state=4, d_conv=4, expand=2, chunk_size=8),
    activation="swiglu", norm="rmsnorm", pos="none",
    sub_quadratic=True,
)
