"""internvl2-2b [vlm]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 — InternViT + InternLM2. [arXiv:2404.16821; hf]
Backbone (InternLM2-1.8B-style LLM) only per the assignment: the InternViT
frontend is a STUB — input_specs() provides precomputed patch embeddings."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
    vocab_size=92_553, head_dim=128,
    activation="swiglu", norm="rmsnorm", pos="rope",
    frontend="vision",
)

REDUCED = ArchConfig(
    name="internvl2-2b-reduced", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab_size=256, head_dim=16,
    activation="swiglu", norm="rmsnorm", pos="rope",
    frontend="vision",
)
