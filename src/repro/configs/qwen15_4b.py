"""qwen1.5-4b [dense]: 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936 — QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, d_ff=6912,
    vocab_size=151_936, head_dim=128, qkv_bias=True,
    activation="swiglu", norm="rmsnorm", pos="rope",
)

REDUCED = ArchConfig(
    name="qwen1.5-4b-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
    vocab_size=256, head_dim=16, qkv_bias=True,
    activation="swiglu", norm="rmsnorm", pos="rope",
)
