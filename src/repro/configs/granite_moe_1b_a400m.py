"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32e top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=512,
    vocab_size=49_155, head_dim=64,
    moe=MoEConfig(n_experts=32, top_k=8),
    activation="swiglu", norm="rmsnorm", pos="rope", tie_embeddings=True,
)

REDUCED = ArchConfig(
    name="granite-moe-1b-a400m-reduced", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
    vocab_size=256, head_dim=16,
    moe=MoEConfig(n_experts=8, top_k=4, capacity_factor=8.0),  # drop-free at test scale
    activation="swiglu", norm="rmsnorm", pos="rope", tie_embeddings=True,
)
