"""qwen3-4b [dense]: 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936 — qk_norm, GQA; head_dim=128 decoupled from d_model (q_dim
4096), as in the Qwen3 family. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, d_ff=9728,
    vocab_size=151_936, head_dim=128, qk_norm=True,
    activation="swiglu", norm="rmsnorm", pos="rope", rope_theta=1_000_000.0,
    tie_embeddings=True,
)

REDUCED = ArchConfig(
    name="qwen3-4b-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=96,
    vocab_size=256, head_dim=16, qk_norm=True,
    activation="swiglu", norm="rmsnorm", pos="rope", tie_embeddings=True,
)
