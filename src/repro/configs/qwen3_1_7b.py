"""qwen3-1.7b [dense]: 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936 — qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=6144,
    vocab_size=151_936, head_dim=128, qk_norm=True,
    activation="swiglu", norm="rmsnorm", pos="rope", rope_theta=1_000_000.0,
    tie_embeddings=True,
)

REDUCED = ArchConfig(
    name="qwen3-1.7b-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab_size=256, head_dim=16, qk_norm=True,
    activation="swiglu", norm="rmsnorm", pos="rope", tie_embeddings=True,
)
