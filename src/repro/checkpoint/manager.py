"""Checkpointing with consensus-committed manifests.

Durability protocol (2-phase, the paper's technique on the control path):
  1. Every host writes its parameter/optimizer shards to
     ``<dir>/step_N/...npy`` plus ``manifest.json.tmp``.
  2. The manifest digest is proposed as a Fast Raft log entry
     (``ckpt:<step>:<digest>``). Only when the entry COMMITS is the manifest
     renamed to ``manifest.json`` — a checkpoint either exists for the whole
     fleet or not at all, and restart-after-failover always agrees on the
     newest committed step (no torn checkpoints after partial pod loss).

Elastic restore: arrays are loaded as host numpy and re-device_put with the
CURRENT mesh's shardings, so the restore mesh may differ from the save mesh
(elastic scaling after node failure).

The async writer runs off the step path; ``wait()`` joins it (called before
the next save or at exit).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

Params = Any


class SnapshotStore:
    """Durable storage for consensus log-compaction snapshots.

    One JSON file per node, written atomically (tmp + rename) so a crash
    mid-write leaves the previous snapshot intact — the same torn-write
    guarantee the manifest path below gives model checkpoints. Wire it to a
    cluster as each node's ``snapshot_sink``; ``load`` rebuilds the
    :class:`repro.core.types.Snapshot` for cold-start restores.

    What persists is the state machine's OPAQUE reduced state plus the
    client-retry dedup filter (see ``repro.core.statemachine``), not the
    entry list — a KV snapshot on disk is O(live keys) exactly like it is
    on the wire. State must be JSON-serializable (the StateMachine
    contract). Legacy entry-list files load as LogListMachine state, whose
    wire shape they already match.
    """

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, node_id: str) -> str:
        return os.path.join(self.dir, f"consensus_snap_{node_id}.json")

    def save(self, node_id: str, snapshot) -> None:
        payload = {
            "last_index": snapshot.last_index,
            "last_term": snapshot.last_term,
            "members": list(snapshot.members),
            "state": snapshot.state,
            "dedup": snapshot.dedup,
            "version": 2,
        }
        # v2: the full ClusterConfig (voters / learners / joint old_voters)
        # persists next to the legacy flat member list, so a host restored
        # from the checkpoint volume rejoins with exact quorum semantics —
        # a learner must not come back believing it is a voter.
        if snapshot.config is not None:
            payload["config"] = snapshot.config.to_wire()
        # Delta provenance (RaftConfig.delta_snapshots): which base the
        # snapshot's state was reconstructed against, when it arrived as a
        # delta stream. Written only when set so pre-delta files are
        # byte-stable.
        if getattr(snapshot, "delta_base", -1) >= 0:
            payload["delta_base"] = snapshot.delta_base
        tmp = self._path(node_id) + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, self._path(node_id))

    def load(self, node_id: str):
        from repro.core.statemachine import DedupTable
        from repro.core.types import ClusterConfig, EntryId, Snapshot

        path = self._path(node_id)
        if not os.path.exists(path):
            return None
        with open(path) as f:
            payload = json.load(f)
        # Legacy (pre-state-machine) files carry "entries" — the same wire
        # shape LogListMachine state uses — and no dedup filter. Rebuild the
        # filter from the entry ids so client-retry dedup (and the _seq
        # floor) survives a legacy restore instead of silently vanishing.
        state = payload.get("state", payload.get("entries"))
        dedup = payload.get("dedup")
        if dedup is None and isinstance(state, list):
            table = DedupTable()
            for d in state:
                if isinstance(d, dict) and "origin" in d and "seq" in d:
                    table.add(EntryId(d["origin"], d["seq"]))
            dedup = table.state()
        cfg = payload.get("config")  # absent in v1 files: all-voter legacy
        return Snapshot(
            last_index=payload["last_index"],
            last_term=payload["last_term"],
            state=state,
            members=tuple(payload["members"]),
            dedup=dedup,
            config=None if cfg is None else ClusterConfig.from_wire(cfg),
            delta_base=payload.get("delta_base", -1),
        )

    def latest_index(self, node_id: str) -> int:
        snap = self.load(node_id)
        return snap.last_index if snap is not None else 0

    # Raft hard state (term, voted_for, next client seq) — must be durable
    # independently of snapshots: votes change every election and seqs every
    # submission, while snapshots only appear at compaction. A node restored
    # without these could double-vote in a term it voted in, or reuse
    # EntryIds and have fresh commands swallowed as retries.

    def _hard_state_path(self, node_id: str) -> str:
        return os.path.join(self.dir, f"consensus_hard_{node_id}.json")

    def save_hard_state(
        self,
        node_id: str,
        term: int,
        voted_for,
        seq: int,
        floor_index: int = 0,
        floor_term: int = 0,
    ) -> None:
        tmp = self._hard_state_path(node_id) + ".tmp"
        with open(tmp, "w") as f:
            json.dump(
                {
                    "term": term,
                    "voted_for": voted_for,
                    "seq": seq,
                    # Acked-log floor: the store keeps no log, so a restored
                    # node needs this to refuse electing candidates missing
                    # entries it acknowledged before the crash.
                    "floor_index": floor_index,
                    "floor_term": floor_term,
                },
                f,
            )
        os.replace(tmp, self._hard_state_path(node_id))

    def load_hard_state(self, node_id: str):
        """Returns (term, voted_for, seq, floor_index, floor_term) or None.
        Files written before the ack floor existed load with a zero floor."""
        path = self._hard_state_path(node_id)
        if not os.path.exists(path):
            return None
        with open(path) as f:
            payload = json.load(f)
        return (
            payload["term"],
            payload["voted_for"],
            payload["seq"],
            payload.get("floor_index", 0),
            payload.get("floor_term", 0),
        )


def _flatten_with_paths(tree: Params) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((key, np.asarray(leaf)))
    return out


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        commit_fn: Optional[Callable[[str], bool]] = None,
        keep_last: int = 3,
    ):
        """commit_fn: proposes the manifest record through the control plane
        and returns True once committed. None = local-only commit (tests)."""
        self.dir = directory
        self.commit_fn = commit_fn
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save

    def save(self, step: int, trees: Dict[str, Params], async_: bool = True) -> None:
        self.wait()
        # Materialize on host BEFORE going async (donated buffers may die).
        host_trees = {
            name: _flatten_with_paths(tree) for name, tree in trees.items()
        }

        def work():
            try:
                self._write(step, host_trees)
            except BaseException as e:  # surfaced by wait()
                self._error = e

        if async_:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self._raise_if_failed()

    def _write(self, step: int, host_trees) -> None:
        d = os.path.join(self.dir, f"step_{step:08d}")
        os.makedirs(d, exist_ok=True)
        index = {}
        digest = hashlib.sha256()
        for name, leaves in host_trees.items():
            for key, arr in leaves:
                fname = f"{name}__{key.replace('/', '__')}.npy"
                np.save(os.path.join(d, fname), arr)
                index[f"{name}/{key}"] = {
                    "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
                }
                digest.update(fname.encode())
                digest.update(str(arr.shape).encode())
        manifest = {"step": step, "index": index, "digest": digest.hexdigest()}
        tmp = os.path.join(d, "manifest.json.tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        # 2-phase commit through the control plane.
        record = f"ckpt:{step}:{manifest['digest']}"
        committed = True if self.commit_fn is None else self.commit_fn(record)
        if committed:
            os.replace(tmp, os.path.join(d, "manifest.json"))
            self._gc()
        # Uncommitted checkpoints keep only the .tmp manifest and are
        # invisible to restore() — exactly the torn-checkpoint guarantee.

    def _gc(self) -> None:
        steps = self.committed_steps()
        for s in steps[: -self.keep_last] if self.keep_last else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # --------------------------------------------------------------- restore

    def committed_steps(self) -> List[int]:
        steps = []
        if not os.path.isdir(self.dir):
            return steps
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.dir, name, "manifest.json")
            ):
                steps.append(int(name[5:]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        templates: Dict[str, Params],
        step: Optional[int] = None,
        shardings: Optional[Dict[str, Any]] = None,
    ) -> Tuple[int, Dict[str, Params]]:
        """Load into the structure of ``templates``; optionally device_put
        with per-tree shardings (elastic re-shard)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        out: Dict[str, Params] = {}
        for name, template in templates.items():
            flat, treedef = jax.tree_util.tree_flatten_with_path(template)
            leaves = []
            for path, leaf in flat:
                key = "/".join(
                    str(getattr(k, "key", getattr(k, "idx", k))) for k in path
                )
                entry = manifest["index"][f"{name}/{key}"]
                arr = np.load(os.path.join(d, entry["file"]))
                assert list(arr.shape) == list(leaf.shape), (key, arr.shape, leaf.shape)
                leaves.append(arr.astype(leaf.dtype))
            tree = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(template), leaves
            )
            if shardings is not None and name in shardings and shardings[name] is not None:
                tree = jax.device_put(tree, shardings[name])
            out[name] = tree
        return step, out
