"""AdamW with fp32 state + optional fp32 master weights over bf16 params,
global-norm clipping, and warmup-cosine schedule. Elementwise throughout, so
optimizer state shards exactly like its parameter (FSDP x TP) and the update
runs on local shards with no extra communication (the clip norm is computed
upstream and passed in — the Trainer folds it into the gradient-sync psum)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    master_weights: bool = True


class OptState(NamedTuple):
    m: Params
    v: Params
    master: Optional[Params]
    step: jax.Array


def init(cfg: AdamWConfig, params: Params) -> OptState:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = (
        jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
        if cfg.master_weights
        else None
    )
    return OptState(m=zeros, v=jax.tree_util.tree_map(jnp.copy, zeros),
                    master=master, step=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def update(
    cfg: AdamWConfig,
    grads: Params,
    state: OptState,
    params: Params,
    grad_norm: Optional[jax.Array] = None,
) -> Tuple[Params, OptState]:
    """One AdamW step. grads are the (already averaged) fp32-castable grads;
    grad_norm, when given, is the GLOBAL gradient norm for clipping."""
    step = state.step + 1
    lr = schedule(cfg, step)
    if grad_norm is None:
        grad_norm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(grads))
        )
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(grad_norm, 1e-9))

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    ref = state.master if state.master is not None else params

    def one(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        upd = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (upd + cfg.weight_decay * p32)
        return m_new, v_new, p_new

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)
    flat_p = jax.tree_util.tree_leaves(ref)
    outs = [one(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    m_new = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    v_new = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    p32_new = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])

    orig_dtypes = jax.tree_util.tree_map(lambda p: p.dtype, params)
    params_new = jax.tree_util.tree_map(
        lambda p32, dt: p32.astype(dt), p32_new, orig_dtypes
    )
    master_new = p32_new if state.master is not None else None
    return params_new, OptState(m=m_new, v=v_new, master=master_new, step=step)
