"""Gradient compression for cross-pod (DCN) reduction: int8 quantization
with error feedback (residual carried across steps, so compression noise is
unbiased over time — Seide et al. / Karimireddy et al.).

Used on the pod axis only: in-pod reductions ride full-precision ICI; the
narrow DCN hop carries int8 + per-leaf fp32 scale. The roundtrip is exact
enough that EF keeps convergence (validated in tests/test_optim.py)."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any


def quantize(tree: Params, residual: Params) -> Tuple[Params, Params, Params]:
    """Returns (q_int8, scales, new_residual). residual is added before
    quantization (error feedback)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_r = gf - q.astype(jnp.float32) * scale
        return q, scale, new_r

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    res_leaves = jax.tree_util.tree_leaves(residual)
    outs = [one(g, r) for g, r in zip(leaves, res_leaves)]
    un = lambda i: jax.tree_util.tree_unflatten(treedef, [o[i] for o in outs])
    return un(0), un(1), un(2)


def dequantize(q_tree: Params, scales: Params) -> Params:
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scales
    )


def init_residual(tree: Params) -> Params:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), tree
    )
