"""Causal flash attention for TPU (Pallas): forward + backward kernels.

TPU adaptation notes (vs the CUDA flash-attention algorithm):
- Tiling is chosen for the MXU (128x128 systolic array) and VMEM residency:
  q/k blocks default to 128 rows, head_dim rides along in full (<= 128).
- The streaming softmax state (m, l, acc) lives in VMEM scratch and is
  carried across the *innermost grid dimension* (k blocks), declared
  "arbitrary" so Mosaic keeps it sequential; batch/head/q-block dims are
  "parallel". This replaces the CUDA warp-level accumulation.
- Causal skipping: k blocks strictly above the diagonal are skipped via
  pl.when, saving ~half the FLOPs at long sequence.
- GQA is handled by the ops.py wrapper (kv head repeat / group-sum for
  gradients) so the kernels stay MHA-shaped — one fewer index map level in
  VMEM addressing.

Layouts: q, k, v, o are (B, T, H, D); lse is (B, H, T) fp32.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# ------------------------------------------------------------------ forward


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
                *, scale, causal, blk_q, blk_k, nk):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (ki * blk_k <= qi * blk_q + blk_q - 1) if causal else (ki >= 0)

    @pl.when(run)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale      # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)              # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = q @ k.T                                            # (bq, bk)
        if causal:
            qpos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
            kpos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0, :] = m_scr[...] + jnp.log(l)


def flash_attention_fwd(q, k, v, *, causal=True, scale=None,
                        blk_q=128, blk_k=128, interpret=False):
    B, Tq, H, D = q.shape
    Tk = k.shape[1]
    assert k.shape == (B, Tk, H, D) and v.shape == (B, Tk, H, D), "MHA-shaped"
    blk_q = min(blk_q, Tq)
    blk_k = min(blk_k, Tk)
    assert Tq % blk_q == 0 and Tk % blk_k == 0
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    nq, nk = Tq // blk_q, Tk // blk_k

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, blk_q=blk_q, blk_k=blk_k, nk=nk
    )
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, blk_k, 1, D), lambda b, h, qi, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, blk_k, 1, D), lambda b, h, qi, ki: (b, ki, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, 1, blk_q), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Tq, H, D), q.dtype),
            jax.ShapeDtypeStruct((B, H, Tq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, D), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return o, lse


# ----------------------------------------------------------------- backward


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, dq_scr,
               *, scale, causal, blk_q, blk_k, nk):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    run = (ki * blk_k <= qi * blk_q + blk_q - 1) if causal else (ki >= 0)

    @pl.when(run)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        lse = lse_ref[0, 0, :]
        delta = delta_ref[0, 0, :]
        s = q @ k.T
        if causal:
            qpos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
            kpos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = do @ v.T
        ds = p * (dp - delta[:, None]) * scale
        dq_scr[...] += ds @ k

    @pl.when(ki == nk - 1)
    def _done():
        dq_ref[0, :, 0, :] = dq_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr,
                *, scale, causal, blk_q, blk_k, nq):
    ki = pl.program_id(2)
    qi = pl.program_id(3)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    run = (qi * blk_q + blk_q - 1 >= ki * blk_k) if causal else (qi >= 0)

    @pl.when(run)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        lse = lse_ref[0, 0, :]
        delta = delta_ref[0, 0, :]
        s = q @ k.T                                            # (bq, bk)
        if causal:
            qpos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
            kpos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dv_scr[...] += p.T @ do
        dp = do @ v.T
        ds = p * (dp - delta[:, None]) * scale  # one factor of scale total
        dk_scr[...] += ds.T @ (q / scale)       # q_ref was pre-scaled; undo

    @pl.when(qi == nq - 1)
    def _done():
        dk_ref[0, :, 0, :] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, :, 0, :] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, *, causal=True, scale=None,
                        blk_q=128, blk_k=128, interpret=False):
    B, Tq, H, D = q.shape
    Tk = k.shape[1]
    blk_q = min(blk_q, Tq)
    blk_k = min(blk_k, Tk)
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    nq, nk = Tq // blk_q, Tk // blk_k

    # delta = rowsum(dO * O): cheap, done outside the kernels.
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    delta = delta.transpose(0, 2, 1)  # (B, H, Tq)

    qspec = pl.BlockSpec((1, blk_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0))
    kspec = pl.BlockSpec((1, blk_k, 1, D), lambda b, h, qi, ki: (b, ki, h, 0))
    statq = pl.BlockSpec((1, 1, blk_q), lambda b, h, qi, ki: (b, h, qi))
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          blk_q=blk_q, blk_k=blk_k, nk=nk),
        grid=(B, H, nq, nk),
        in_specs=[qspec, kspec, kspec, qspec, statq, statq],
        out_specs=[qspec],
        out_shape=[jax.ShapeDtypeStruct((B, Tq, H, D), q.dtype)],
        scratch_shapes=[pltpu.VMEM((blk_q, D), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v, do, lse, delta)[0]

    qspec2 = pl.BlockSpec((1, blk_q, 1, D), lambda b, h, ki, qi: (b, qi, h, 0))
    kspec2 = pl.BlockSpec((1, blk_k, 1, D), lambda b, h, ki, qi: (b, ki, h, 0))
    statq2 = pl.BlockSpec((1, 1, blk_q), lambda b, h, ki, qi: (b, h, qi))
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          blk_q=blk_q, blk_k=blk_k, nq=nq),
        grid=(B, H, nk, nq),
        in_specs=[qspec2, kspec2, kspec2, qspec2, statq2, statq2],
        out_specs=[kspec2, kspec2],
        out_shape=[
            jax.ShapeDtypeStruct((B, Tk, H, D), k.dtype),
            jax.ShapeDtypeStruct((B, Tk, H, D), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((blk_k, D), jnp.float32),
            pltpu.VMEM((blk_k, D), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
