"""Flash-decoding for TPU (Pallas): single-token attention against a long
KV cache, split-K style.

The sequence axis of the cache is split across the innermost grid dimension;
each split produces a partial (acc, m, l) in fp32, written per split, and
the splits are merged with a logsumexp combine in the jit'd wrapper (the
merge is O(splits * D) — negligible). This mirrors flash-decoding on GPU but
tiles for VMEM: each split streams blk_s cache rows through VMEM while the
(H, D) query block stays resident.

Layouts: q (B, H, D); k, v (B, S, H, D); kv_len (B,) valid lengths.
Outputs: acc (B, H, nsplit, D) fp32, m/l (B, H, nsplit) fp32.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(kvlen_ref, q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
                   *, scale, blk_s):
    si = pl.program_id(2)
    q = q_ref[0].astype(jnp.float32) * scale           # (H, D)
    k = k_ref[0].astype(jnp.float32)                   # (blk_s, H, D)
    v = v_ref[0].astype(jnp.float32)
    kv_len = kvlen_ref[0]

    s = jnp.einsum("hd,khd->hk", q, k)                 # (H, blk_s)
    kpos = si * blk_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < kv_len, s, NEG_INF)
    m = jnp.max(s, axis=-1)                            # (H,)
    # All-masked splits: exp(NEG_INF - NEG_INF) would be 1; force p to 0.
    safe_m = jnp.maximum(m, NEG_INF / 2)
    p = jnp.exp(s - safe_m[:, None])
    p = jnp.where(kpos < kv_len, p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("hk,khd->hd", p, v)
    acc_ref[0, :, 0, :] = acc
    m_ref[0, :, 0] = m
    l_ref[0, :, 0] = l


def decode_attention_splits(q, k, v, kv_len, *, scale=None, blk_s=512,
                            interpret=False):
    """Partial-attention pass. Returns (acc, m, l) per split."""
    B, H, D = q.shape
    S = k.shape[1]
    assert k.shape == (B, S, H, D) and v.shape == (B, S, H, D)
    blk_s = min(blk_s, S)
    assert S % blk_s == 0
    nsplit = S // blk_s
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    kernel = functools.partial(_decode_kernel, scale=scale, blk_s=blk_s)
    acc, m, l = pl.pallas_call(
        kernel,
        grid=(B, H, nsplit),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, si: (b,)),
            pl.BlockSpec((1, H, D), lambda b, h, si: (b, 0, 0)),
            pl.BlockSpec((1, blk_s, H, D), lambda b, h, si: (b, si, 0, 0)),
            pl.BlockSpec((1, blk_s, H, D), lambda b, h, si: (b, si, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, 1, D), lambda b, h, si: (b, 0, si, 0)),
            pl.BlockSpec((1, H, 1), lambda b, h, si: (b, 0, si)),
            pl.BlockSpec((1, H, 1), lambda b, h, si: (b, 0, si)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nsplit, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H, nsplit), jnp.float32),
            jax.ShapeDtypeStruct((B, H, nsplit), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), q, k, v)
    return acc, m, l


def combine_splits(acc, m, l, out_dtype):
    """Logsumexp merge of split partials: (B,H,ns,D),(B,H,ns)x2 -> (B,H,D)."""
    m_glob = jnp.max(m, axis=-1, keepdims=True)               # (B,H,1)
    w = jnp.exp(m - m_glob)                                   # (B,H,ns)
    l_glob = jnp.sum(l * w, axis=-1)                          # (B,H)
    o = jnp.einsum("bhsd,bhs->bhd", acc, w)
    return (o / jnp.maximum(l_glob, 1e-30)[..., None]).astype(out_dtype)
