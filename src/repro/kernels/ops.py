"""Jit'd public wrappers for the Pallas kernels.

- ``flash_attention``: custom_vjp (Pallas fwd + Pallas bwd), GQA handled
  here (kv-head repeat going in, group-sum for dk/dv coming out).
- ``decode_attention``: split-K partials + logsumexp combine.
- ``rmsnorm``: fused forward (training uses the ref path's autodiff).

On the CPU host platform (this container, and any unit test) the kernels
run with interpret=True; on TPU they compile through Mosaic. The dry-run
lowers the FLOP-equivalent ref path instead (kernels are TPU-targeted).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import rmsnorm as _rms


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _repeat_kv(k, group):
    if group == 1:
        return k
    return jnp.repeat(k, group, axis=2)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal=True, blk_q=128, blk_k=128):
    """q: (B,T,Hq,D); k, v: (B,T,Hkv,D). Causal flash attention."""
    o, _ = _flash_fwd(q, k, v, causal, blk_q, blk_k)
    return o


def _flash_fwd(q, k, v, causal, blk_q, blk_k):
    group = q.shape[2] // k.shape[2]
    kr, vr = _repeat_kv(k, group), _repeat_kv(v, group)
    o, lse = _fa.flash_attention_fwd(
        q, kr, vr, causal=causal, blk_q=blk_q, blk_k=blk_k, interpret=_interpret()
    )
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, blk_q, blk_k, res, do):
    q, k, v, o, lse = res
    group = q.shape[2] // k.shape[2]
    kr, vr = _repeat_kv(k, group), _repeat_kv(v, group)
    dq, dk, dv = _fa.flash_attention_bwd(
        q, kr, vr, o, lse, do, causal=causal, blk_q=blk_q, blk_k=blk_k,
        interpret=_interpret(),
    )
    if group > 1:
        B, T, Hq, D = dk.shape
        dk = dk.reshape(B, T, Hq // group, group, D).sum(axis=3)
        dv = dv.reshape(B, T, Hq // group, group, D).sum(axis=3)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def decode_attention(q, k, v, kv_len, *, blk_s: int = 512):
    """q: (B,Hq,D) single token; k, v: (B,S,Hkv,D); kv_len: (B,)."""
    group = q.shape[1] // k.shape[2]
    kr, vr = _repeat_kv(k, group), _repeat_kv(v, group)
    acc, m, l = _dec.decode_attention_splits(
        q, kr, vr, kv_len, blk_s=blk_s, interpret=_interpret()
    )
    return _dec.combine_splits(acc, m, l, q.dtype)


def rmsnorm(x, scale, eps: float = 1e-6):
    return _rms.rmsnorm(x, scale, eps=eps, interpret=_interpret())


# Re-export oracles for tests/benchmarks.
ref = _ref
