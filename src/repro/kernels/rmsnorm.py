"""Fused RMSNorm (Pallas): one HBM round-trip instead of separate
square/mean/rsqrt/mul kernels. Rows are tiled over the grid; the feature
dim stays resident in VMEM (d_model <= a few K fits comfortably)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)                 # (blk_rows, d)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * scale_ref[...].astype(jnp.float32)[None]
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps: float = 1e-6, blk_rows: int = 256,
            interpret=False):
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = int(x.size // d)
    x2 = x.reshape(rows, d)
    blk = min(blk_rows, rows)
    # Pad rows to a block multiple.
    pad = (-rows) % blk
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, d), x2.dtype)], axis=0)
    n = x2.shape[0] // blk
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
