"""Pure-jnp oracles for every Pallas kernel. The kernels are validated
against these in interpret mode (CPU) across shape/dtype sweeps; the
dry-run lowers these FLOP-equivalent paths on the host platform."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: (B, Tq, Hq, D); k, v: (B, Tk, Hkv, D) with Hq % Hkv == 0."""
    B, Tq, Hq, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    group = Hq // Hkv
    qf = (q.astype(jnp.float32) * scale).reshape(B, Tq, Hkv, group, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    if causal:
        mask = jnp.arange(Tq)[:, None] >= jnp.arange(Tk)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Tq, Hq, D).astype(q.dtype)


def decode_attention(q, k, v, kv_len, *, scale: float | None = None):
    """Single-token decode: q (B, Hq, D); k, v (B, S, Hkv, D); kv_len (B,)."""
    B, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    group = Hq // Hkv
    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, group, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k.astype(jnp.float32))
    valid = jnp.arange(S)[None, :] < kv_len[:, None]
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, D).astype(q.dtype)


def rmsnorm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)
