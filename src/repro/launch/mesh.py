"""Production mesh construction. A FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets the
512-device host platform before calling it; tests and benches keep their
single real device."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16 x 16 = 256 chips (data x model).
    Multi-pod: 2 x 16 x 16 = 512 chips (pod x data x model) — the 'pod' axis
    is the DCN dimension; parameters never shard over it."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate mesh for single-device tests/examples."""
    return jax.make_mesh((1, 1), ("data", "model"))
