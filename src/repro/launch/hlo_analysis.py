"""Trip-count-aware analysis of post-SPMD HLO text.

XLA's ``HloCostAnalysis`` (and any naive line scan) visits a while-loop body
ONCE, so scan-over-layers programs under-report FLOPs and collective bytes
by the trip count (48x for a 48-layer stack). This module parses the HLO
text into its computation graph, extracts each while loop's trip count from
its condition (canonical `i < N` form emitted by lax.scan), and accumulates
dot FLOPs and collective bytes with the correct execution multiplier:

  mult(ENTRY) = 1
  while op in computation C with body B, trip T:  mult(B) += mult(C) * T
  call / conditional / fusion edges:              mult(callee) += mult(C)

FLOPs counted: dot ops (2 * prod(result_dims) * prod(contracting_dims)),
which dominate transformer compute; elementwise FLOPs are ignored (<2%).
Collective bytes use ring estimates (see ``KIND_FACTORS``).
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_SHAPE = re.compile(r"^\(?([a-z0-9]+)\[([0-9,]*)\]")
_OPNAME = re.compile(r"^(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+([\w\-]+)\(")
_OPERANDS = re.compile(r"\(([^)]*)\)")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_CALLED = re.compile(r"(?:body|condition|to_apply|calls|branch_computations)=\{?%?([\w.\-]+(?:, *%?[\w.\-]+)*)\}?")
_CONSTANT = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_of(rhs: str) -> Optional[Tuple[str, Tuple[int, ...]]]:
    m = _SHAPE.match(rhs)
    if not m:
        return None
    dtype = m.group(1)
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return dtype, dims


def _nbytes(dtype: str, dims: Tuple[int, ...]) -> int:
    n = _DTYPE_BYTES.get(dtype, 0)
    for d in dims:
        n *= d
    return n


class Instr:
    __slots__ = ("name", "rhs", "op", "shape", "operands")

    def __init__(self, name: str, rhs: str):
        self.name = name
        self.rhs = rhs
        m = _OPNAME.match(rhs)
        self.op = m.group(1) if m else ""
        self.shape = _shape_of(rhs.lstrip("("))
        # Operand names (first parenthesized list after the op name).
        self.operands: List[str] = []
        if self.op:
            idx = rhs.find(self.op + "(")
            if idx >= 0:
                depth = 0
                args = ""
                for ch in rhs[idx + len(self.op):]:
                    if ch == "(":
                        depth += 1
                        if depth == 1:
                            continue
                    if ch == ")":
                        depth -= 1
                        if depth == 0:
                            break
                    if depth >= 1:
                        args += ch
                self.operands = [
                    a.strip().lstrip("%") for a in args.split(",") if a.strip().startswith("%")
                ]


def parse_computations(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if not line.startswith(" "):
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                continue
            if line.strip() == "}":
                cur = None
                continue
        if cur is None:
            continue
        mi = _INSTR.match(line)
        if mi:
            comps[cur].append(Instr(mi.group(1), mi.group(2)))
    return comps


def _entry_name(text: str) -> Optional[str]:
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line.strip())
            if m:
                return m.group(1)
    return None


def _trip_count(cond: List[Instr]) -> int:
    """Canonical lax.scan condition: compare(i, constant(N)), direction=LT."""
    constants = {}
    for ins in cond:
        m = _CONSTANT.search(ins.rhs)
        if m and ins.shape and ins.shape[0].startswith(("s", "u")):
            constants[ins.name] = int(m.group(1))
    for ins in cond:
        if ins.op == "compare" and "direction=LT" in ins.rhs:
            for o in ins.operands:
                if o in constants:
                    return constants[o]
    # Fallbacks: GT / unique constant.
    if len(constants) == 1:
        return next(iter(constants.values()))
    return 1


def _multipliers(
    comps: Dict[str, List[Instr]], entry: str
) -> Tuple[Dict[str, float], Dict[str, bool]]:
    """Returns (exec multiplier, hbm_level) per computation. hbm_level marks
    computations whose instructions touch HBM at op granularity (entry,
    while bodies/conditions, call/conditional branches) as opposed to
    fusion bodies / reducers (calls= / to_apply=), whose internals stay in
    registers/VMEM."""
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    hbm: Dict[str, bool] = {c: False for c in comps}
    mult[entry] = 1.0
    hbm[entry] = True
    # Topological-ish: iterate to fixpoint (call graph is a DAG; few levels).
    for _ in range(16):
        changed = False
        for cname, instrs in comps.items():
            m = mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for ins in instrs:
                if ins.op == "while":
                    bm = re.search(r"body=%?([\w.\-]+)", ins.rhs)
                    cm = re.search(r"condition=%?([\w.\-]+)", ins.rhs)
                    body = bm.group(1) if bm else None
                    cond = cm.group(1) if cm else None
                    trips = _trip_count(comps.get(cond, [])) if cond else 1
                    for tgt, mm in ((body, m * trips), (cond, m * (trips + 1))):
                        if tgt in mult and mult[tgt] < mm:
                            mult[tgt] = mm
                            changed = True
                        if tgt in hbm and hbm.get(cname) and not hbm[tgt]:
                            hbm[tgt] = True
                            changed = True
                    continue
                hbm_edge = ins.op in ("call", "conditional")
                for grp in _CALLED.findall(ins.rhs):
                    for n in (g.strip().lstrip("%") for g in grp.split(",")):
                        if n not in mult:
                            continue
                        if mult[n] < m:
                            mult[n] = m
                            changed = True
                        if hbm_edge and hbm.get(cname) and not hbm[n]:
                            hbm[n] = True
                            changed = True
        if not changed:
            break
    return mult, hbm


def _dot_flops(ins: Instr, shapes: Dict[str, Tuple[str, Tuple[int, ...]]]) -> float:
    if ins.shape is None:
        return 0.0
    out_elems = 1
    for d in ins.shape[1]:
        out_elems *= d
    lhs = shapes.get(ins.operands[0]) if ins.operands else None
    contract = 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rhs)
    if lhs and m and m.group(1):
        for d in m.group(1).split(","):
            i = int(d)
            if i < len(lhs[1]):
                contract *= lhs[1][i]
    return 2.0 * out_elems * contract


def _collective_bytes(ins: Instr, n_devices: int) -> Tuple[str, float, int]:
    kind = next((k for k in COLLECTIVES if ins.op.startswith(k)), None)
    if kind is None or ins.shape is None:
        return "", 0.0, 1
    dtype, dims = ins.shape
    size = _nbytes(dtype, dims)
    gm = _GROUPS_IOTA.search(ins.rhs)
    if gm:
        G = int(gm.group(2))
    else:
        gl = _GROUPS_LIST.search(ins.rhs)
        G = len(gl.group(1).split(",")) if gl else n_devices
    G = max(G, 1)
    if kind == "all-gather":
        moved = size * (G - 1) / G
    elif kind == "all-reduce":
        moved = 2 * size * (G - 1) / G
    elif kind == "reduce-scatter":
        moved = size * (G - 1)
    elif kind == "all-to-all":
        moved = size * (G - 1) / G
    else:
        moved = size
    return kind, moved, G


def analyze(text: str, n_devices: int) -> Dict[str, Any]:
    """Trip-count-aware per-device totals for the compiled module."""
    comps = parse_computations(text)
    entry = _entry_name(text)
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""
    mult, hbm = _multipliers(comps, entry)

    flops = 0.0
    coll_totals: Dict[str, float] = {}
    coll_counts: Dict[str, float] = {}
    bytes_hbm = 0.0
    biggest: List[Dict[str, Any]] = []
    skip_bytes_ops = {"parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast", "while", "call", "conditional"}
    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        shapes = {i.name: i.shape for i in instrs if i.shape is not None}
        for ins in instrs:
            if ins.op == "dot":
                flops += m * _dot_flops(ins, shapes)
            elif any(ins.op.startswith(k) for k in COLLECTIVES):
                kind, moved, G = _collective_bytes(ins, n_devices)
                if kind:
                    coll_totals[kind] = coll_totals.get(kind, 0.0) + m * moved
                    coll_counts[kind] = coll_counts.get(kind, 0.0) + m
                    biggest.append({"kind": kind, "comp": cname, "mult": m,
                                    "moved": m * moved})
            # HBM traffic model: at fusion granularity, each op writes its
            # result and reads its operands once.
            if hbm.get(cname) and ins.shape is not None and ins.op not in skip_bytes_ops:
                b = _nbytes(*ins.shape)
                for o in ins.operands:
                    s = shapes.get(o)
                    if s is not None:
                        b += _nbytes(*s)
                bytes_hbm += m * b

    biggest.sort(key=lambda o: -o["moved"])
    return {
        "flops": flops,
        "collective_bytes": float(sum(coll_totals.values())),
        "collective_by_kind": coll_totals,
        "collective_counts": coll_counts,
        "bytes_accessed": bytes_hbm,  # fusion-granularity reads+writes
        "biggest_collectives": biggest[:10],
        "n_computations": len(comps),
        "entry": entry,
    }
