"""Serving launcher: batched prefill + decode with consensus-coordinated
model-version rollout.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.launch.mesh import make_host_mesh
from repro.models import zoo
from repro.runtime import spmd
from repro.runtime.controlplane import ControlPlane


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = registry.get(args.arch, reduced=args.reduced)
    mesh = make_host_mesh()
    model = zoo.build(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(args.seed))
    max_len = args.prompt_len + args.gen
    prefill_fn, decode_fn = spmd.build_serve_fns(model, mesh, max_len)

    control = ControlPlane(n_nodes=3, seed=args.seed)
    assert control.rollout(f"{cfg.name}@v1"), "rollout not committed"
    print(f"serving {cfg.name}@v1 (rollout committed via Fast Raft)")

    rng = np.random.RandomState(args.seed)
    if cfg.frontend is not None:
        prompt = {"embeddings": jnp.asarray(
            rng.randn(args.batch, args.prompt_len, cfg.d_model), jnp.float32)}
    else:
        prompt = {"tokens": jnp.asarray(
            rng.randint(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)}

    t0 = time.perf_counter()
    logits, cache = prefill_fn(params, prompt)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    tokens = jnp.argmax(logits, axis=-1)[:, None]
    outs = [tokens]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, cache = decode_fn(params, cache, {"tokens": tokens})
        tokens = jnp.argmax(logits, axis=-1)[:, None]
        outs.append(tokens)
    jax.block_until_ready(outs[-1])
    t_decode = time.perf_counter() - t0

    gen = np.concatenate([np.asarray(t) for t in outs], axis=1)
    print(f"prefill: {args.batch}x{args.prompt_len} tok in {t_prefill*1e3:.1f} ms")
    print(f"decode:  {args.gen-1} steps x {args.batch} seqs in {t_decode*1e3:.1f} ms "
          f"({(args.gen-1)*args.batch/max(t_decode,1e-9):.1f} tok/s)")
    print("sample generations (token ids):")
    for row in gen[: min(2, args.batch)]:
        print("  ", row.tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
