"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --steps 50 --global-batch 8 --seq-len 64 --ckpt-dir /tmp/ckpt

Full (non---reduced) configs target the production mesh and are exercised
through the dry-run on this CPU container; --reduced runs a real training
loop end-to-end (consensus control plane, checkpoints, fast-track commit
barrier) on the local device.
"""
from __future__ import annotations

import argparse
import json

import jax.numpy as jnp

from repro.configs import registry
from repro.optim.adamw import AdamWConfig
from repro.runtime.controlplane import ControlPlane
from repro.runtime.trainer import Trainer, TrainerConfig


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--track", choices=["fast", "classic"], default="fast")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--consensus-nodes", type=int, default=3,
                    help="control-plane group size (0 = no control plane)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = registry.get(args.arch, reduced=args.reduced)
    cfg = TrainerConfig(
        arch=arch,
        steps=args.steps,
        global_batch=args.global_batch,
        seq_len=args.seq_len,
        opt=AdamWConfig(lr=args.lr, warmup_steps=max(1, args.steps // 10),
                        total_steps=args.steps),
        track=args.track,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        seed=args.seed,
    )
    control = (
        ControlPlane(n_nodes=args.consensus_nodes, seed=args.seed)
        if args.consensus_nodes > 0
        else None
    )
    trainer = Trainer(cfg, control=control)
    logs = trainer.train()
    for l in logs[:: max(1, len(logs) // 10)]:
        print(json.dumps({k: round(v, 5) for k, v in l.items()}))
    print(f"final loss: {logs[-1]['loss']:.4f} "
          f"(from {logs[0]['loss']:.4f} over {len(logs)} steps)")
    if control is not None:
        s = control.metrics().summary()
        print("control plane:", {k: s[k] for k in
                                 ("n_committed", "commit_rate", "mean_latency")
                                 if k in s})
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
