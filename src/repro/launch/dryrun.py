import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512"
    # XLA:CPU-only workaround: the all-reduce-promotion pass CHECK-crashes
    # cloning reducers that layout assignment gave a copy root (our fused
    # psum tuples). Promotion is a CPU numerics nicety; TPU lowers the same
    # HLO without it. See DESIGN.md §Notes.
    " --xla_disable_hlo_passes=all-reduce-promotion"
)
# ^ MUST precede every other import (jax locks the device count on first
# init). Dry-run only — never set globally.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract the roofline terms from the compiled artifact.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Per cell this produces artifacts/dryrun/<mesh>/<arch>__<shape>.json with:
  memory_analysis, cost_analysis (per-device HLO FLOPs/bytes), the summed
  collective-bytes table parsed from the post-SPMD HLO, and timing. The
  roofline builder (benchmarks/roofline.py) reads these artifacts.

Success of this script for every cell on BOTH meshes is the multi-pod
dry-run deliverable: it proves the sharding config is coherent (no
mismatched specs, no OOM-at-compile, no unsupported collective).
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.shapes import SHAPES, ShapeConfig, applicable
from repro.launch.mesh import make_production_mesh
from repro.models import zoo
from repro.optim.adamw import AdamWConfig
from repro.runtime import sharding as shd
from repro.runtime import spmd

ARTIFACT_DIR = os.path.join("artifacts", "dryrun")

# ---------------------------------------------------------------- input specs


def input_specs(arch: str, shape_name: str, mesh) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no device
    allocation) for every model input of this (arch, shape) cell."""
    cfg = registry.get(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len

    def struct(shp, dtype, spec):
        return jax.ShapeDtypeStruct(shp, dtype, sharding=NamedSharding(mesh, spec))

    baxes = shd.batch_axes(mesh)
    bspec = shd.batch_spec("tokens", (B, S), mesh)
    b0 = bspec[0]

    if shape.kind == "train":
        batch = {}
        if cfg.frontend is not None:
            batch["embeddings"] = struct((B, S, cfg.d_model), jnp.bfloat16,
                                         P(b0, None, None))
        else:
            batch["tokens"] = struct((B, S), jnp.int32, P(b0, None))
        batch["labels"] = struct((B, S), jnp.int32, P(b0, None))
        batch["loss_mask"] = struct((B, S), jnp.float32, P(b0, None))
        return batch
    if shape.kind == "prefill":
        if cfg.frontend is not None:
            return {"embeddings": struct((B, S, cfg.d_model), jnp.bfloat16,
                                         P(b0, None, None))}
        return {"tokens": struct((B, S), jnp.int32, P(b0, None))}
    # decode: one new token against a cache of length S.
    db = shd.batch_spec("tokens", (B, 1), mesh)[0]
    return {"tokens": struct((B, 1), jnp.int32, P(db, None))}


def _shaped(tree, mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda leaf, spec: jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, spec)
        ),
        tree, spec_tree,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, P),
    )


# --------------------------------------------------------- collective parsing

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=?\s*"
)
_SHAPE_RE = re.compile(r"=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")
# Two textual formats: iota form `replica_groups=[G,S]<=[N]` (group size S)
# and explicit lists `replica_groups={{0,16,...},{1,17,...}}`.
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def parse_collectives(hlo_text: str, n_devices: int) -> Dict[str, Any]:
    """Sum per-device bytes moved by every collective in the post-SPMD HLO.

    Ring estimates per op (result shape R bytes, group size G):
      all-gather          R * (G-1)/G      (received)
      all-reduce          2R * (G-1)/G     (reduce-scatter + all-gather)
      reduce-scatter      R * (G-1)       (input is R*G, receives (G-1) shards)
      all-to-all          R * (G-1)/G
      collective-permute  R
    """
    totals: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    ops = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "= " not in line:
            continue
        kind = m.group(1)
        if f" {kind}(" not in line and f"{kind}-start(" not in line:
            continue
        sm = _SHAPE_RE.search(line)
        if not sm:
            continue
        dtype, dims = sm.group(1), sm.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        size = _DTYPE_BYTES[dtype]
        for d in dims.split(","):
            if d:
                size *= int(d)
        gm = _GROUPS_IOTA_RE.search(line)
        if gm:
            G = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            G = len(gl.group(1).split(",")) if gl else n_devices
        if kind == "all-gather":
            moved = size * (G - 1) / max(G, 1)
        elif kind == "all-reduce":
            moved = 2 * size * (G - 1) / max(G, 1)
        elif kind == "reduce-scatter":
            moved = size * (G - 1)
        elif kind == "all-to-all":
            moved = size * (G - 1) / max(G, 1)
        else:
            moved = size
        totals[kind] = totals.get(kind, 0.0) + moved
        counts[kind] = counts.get(kind, 0) + 1
        ops.append({"kind": kind, "result_bytes": size, "group": G, "moved": moved})
    biggest = sorted(ops, key=lambda o: -o["moved"])[:12]
    return {
        "bytes_by_kind": totals,
        "counts": counts,
        "total_bytes": float(sum(totals.values())),
        "n_ops": len(ops),
        "biggest_ops": biggest,
    }


# --------------------------------------------------------------- cell runner


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str,
               fsdp_stream: bool = True) -> Dict[str, Any]:
    cfg = registry.get(arch)
    shape = SHAPES[shape_name]
    model = zoo.build(cfg, dtype=jnp.bfloat16)
    t0 = time.time()

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        step_fn, state_shardings, _ = spmd.build_train_step(
            model, opt_cfg, mesh, track="fast", donate=True,
            fsdp_stream=fsdp_stream,
        )
        state_tpl = jax.eval_shape(
            lambda rng: spmd.make_train_state(model, opt_cfg, rng, False),
            jax.random.PRNGKey(0),
        )
        specs = spmd.state_specs(model, opt_cfg, mesh, False)
        state_structs = _shaped(state_tpl, mesh, specs)
        batch = input_specs(arch, shape_name, mesh)
        lowered = step_fn.lower(state_structs, batch)
    else:
        p_tpl = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        # Serving cells: TP-only parameter shardings (no FSDP gathers).
        p_specs = shd.tree_param_specs(p_tpl, mesh, fsdp=False)
        p_structs = _shaped(p_tpl, mesh, p_specs)
        batch = input_specs(arch, shape_name, mesh)
        if shape.kind == "prefill":
            fn = jax.jit(lambda p, b: model.prefill(p, b, shape.seq_len))
            lowered = fn.lower(p_structs, batch)
        else:  # decode
            cache_tpl = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len)
            )
            c_specs = shd.tree_cache_specs(cache_tpl, mesh)
            c_structs = _shaped(cache_tpl, mesh, c_specs)
            fn = jax.jit(model.decode_step, donate_argnums=(1,))
            lowered = fn.lower(p_structs, c_structs, batch)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # --- extract analyses
    try:
        mem = compiled.memory_analysis()
        mem_out = {
            k: int(getattr(mem, k))
            for k in dir(mem)
            if k.endswith("_bytes") or k.endswith("size_in_bytes")
            if isinstance(getattr(mem, k, None), (int, np.integer))
        } if mem is not None else {}
    except Exception as e:  # platform-dependent
        mem_out = {"error": str(e)}
    try:
        cost = compiled.cost_analysis() or {}
        cost_out = {k: float(v) for k, v in cost.items()
                    if isinstance(v, (int, float, np.floating)) and np.isfinite(float(v))}
    except Exception as e:
        cost_out = {"error": str(e)}

    t0 = time.time()
    hlo = compiled.as_text()
    coll = parse_collectives(hlo, n_devices=mesh.devices.size)
    from repro.launch import hlo_analysis
    deep = hlo_analysis.analyze(hlo, n_devices=mesh.devices.size)
    deep.pop("biggest_collectives", None)
    t_parse = time.time() - t0

    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_devices": int(mesh.devices.size),
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "timings_s": {"lower": t_lower, "compile": t_compile, "parse": t_parse},
        "memory_analysis": mem_out,
        "cost_analysis": cost_out,
        "collectives": coll,
        "hlo_analysis": deep,  # trip-count-aware (scan bodies x trips)
        "hlo_bytes": len(hlo),
    }


def run_cell(arch: str, shape_name: str, mesh_name: str,
             force: bool = False, fsdp_stream: bool = True,
             artifact_dir: Optional[str] = None) -> Optional[Dict[str, Any]]:
    cfg = registry.get(arch)
    shape = SHAPES[shape_name]
    outdir = os.path.join(artifact_dir or ARTIFACT_DIR, mesh_name)
    os.makedirs(outdir, exist_ok=True)
    path = os.path.join(outdir, f"{arch}__{shape_name}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    if not applicable(cfg, shape):
        result = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "skipped": f"{shape_name} requires sub-quadratic decode; "
                       f"{arch} is full-attention (see DESIGN.md)",
        }
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
        return result
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    print(f"[dryrun] {mesh_name}/{arch}/{shape_name}: lowering...", flush=True)
    try:
        result = lower_cell(arch, shape_name, mesh, mesh_name,
                            fsdp_stream=fsdp_stream)
        print(
            f"[dryrun] {mesh_name}/{arch}/{shape_name}: OK "
            f"compile={result['timings_s']['compile']:.1f}s "
            f"flops={result['cost_analysis'].get('flops', -1):.3g} "
            f"coll={result['collectives']['total_bytes']:.3g}B",
            flush=True,
        )
    except Exception as e:
        result = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
        print(f"[dryrun] {mesh_name}/{arch}/{shape_name}: FAIL {e}", flush=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.list_archs())
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="every (arch x shape)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-stream", action="store_true",
                    help="fsdp_stream=False baseline (whole-tree gather)")
    ap.add_argument("--out", default=None, help="artifact dir override")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in registry.list_archs():
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = 0
    for mesh_name in meshes:
        for arch, shape_name in cells:
            r = run_cell(arch, shape_name, mesh_name, force=args.force,
                         fsdp_stream=not args.no_stream, artifact_dir=args.out)
            if r and "error" in r:
                failures += 1
    print(f"[dryrun] done; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
